"""Quickstart: asynchronous personalized FL with EchoPFL in ~60 lines.

Twelve simulated mobile devices (mixed Jetson/RPi speed classes) train
personalized models on non-IID synthetic sensor data. The EchoPFL server
clusters them on the fly, aggregates every update (no stragglers dropped),
and broadcasts fresh cluster models on demand.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.fl.experiment import build_clients, build_strategy
from repro.fl.simulator import Simulator


def main() -> None:
    # 1. a federated task: 12 devices, 4 latent user groups, non-IID labels
    task, clients, init_params = build_clients(
        "har", num_clients=12, seed=0, latent_clusters=4,
    )
    print(f"task={task.name}: {task.num_clients} clients, "
          f"{task.num_classes} classes, dim={task.dim}")

    # 2. the EchoPFL coordination server (the paper's contribution)
    server = build_strategy("echopfl", init_params, clients, seed=0)

    # 3. event-driven asynchronous simulation (virtual time, real training)
    sim = Simulator(clients, server, eval_interval=120.0, target_acc=0.85, seed=0)
    report = sim.run(max_time=1800.0)

    # 4. what happened
    print("\n-- result --")
    for k, v in report.summary().items():
        print(f"{k:22s} {v}")
    stats = server.stats()
    print(f"{'clusters':22s} {stats['clusters']}")
    print(f"{'broadcasts':22s} {stats['broadcasts']} "
          f"(rnn-decided: {stats['rnn_broadcasts']}, of {stats['decisions']} decisions)")
    print(f"{'staleness q_max':22s} {stats['staleness']['q_max']}")
    print(f"{'merges/expansions':22s} {stats['merges']}/{stats['expansions']}")

    acc = np.mean(list(report.per_client_acc.values()))
    assert acc > 0.5, "quickstart should comfortably beat random"
    print("\nOK: per-client personalized accuracy "
          f"{acc:.1%} (vs {1 / task.num_classes:.1%} random)")


if __name__ == "__main__":
    main()
