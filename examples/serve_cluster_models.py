"""Serving example: batched decode from per-cluster personalized models.

After an EchoPFL run the server holds one model per cluster ("branches" in
the CI scheme). This example serves batched generation requests against the
right personalized model for each requester, using the fixed-size KV-cache
decode path (the same serve_step the dry-run lowers for decode_32k).

    PYTHONPATH=src python examples/serve_cluster_models.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.configs.base import reduced_config
from repro.core.server import EchoPFLServer
from repro.data.lm import token_stream
from repro.models import init_cache, init_params, make_serve_step, make_train_step
from repro.models.steps import TrainState, make_optimizer, make_prefill_step


def main() -> None:
    cfg = reduced_config(ARCH_REGISTRY["gemma2-2b"], d_model=64, periods=2)
    key = jax.random.PRNGKey(0)
    init = init_params(cfg, key)
    opt = make_optimizer(cfg)
    train = jax.jit(make_train_step(cfg))

    # --- quick federated phase: 4 clients, 2 latent token distributions ---
    server = EchoPFLServer(init, num_initial_clusters=2, seed=0)
    streams = [token_stream(cfg.vocab_size, seed=i % 2) for i in range(4)]
    states = [TrainState(init, opt.init(init), jnp.zeros((), jnp.int32)) for _ in range(4)]
    for rnd in range(40):
        cid = rnd % 4
        st = states[cid]._replace(params=server.model_for(cid))
        for _ in range(3):
            st, _ = train(st, next(streams[cid]))
        states[cid] = st
        server.handle_upload(cid, st.params, 0, 128, t=float(rnd))
    print(f"federated phase done: {server.stats()['clusters']} personalized clusters")

    # --- serving phase: requests routed to their cluster's model ----------
    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=1)

    requests = [
        {"client": 0, "prompt_len": 8, "gen": 16},
        {"client": 1, "prompt_len": 8, "gen": 16},
        {"client": 2, "prompt_len": 8, "gen": 16},
        {"client": 3, "prompt_len": 8, "gen": 16},
    ]
    # batch requests per cluster (one decode batch per personalized model)
    by_cluster: dict[int, list[dict]] = {}
    for r in requests:
        by_cluster.setdefault(server.clustering.assignment[r["client"]], []).append(r)

    rng = np.random.default_rng(0)
    for cluster_id, batch_reqs in sorted(by_cluster.items()):
        params = server.clustering.clusters[cluster_id].center
        B = len(batch_reqs)
        L = batch_reqs[0]["prompt_len"]
        gen = batch_reqs[0]["gen"]
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)))

        t0 = time.time()
        logits, pre_cache = prefill(params, {"tokens": prompts})
        # graft prefill cache into a fixed-size buffer with generation margin
        cache = init_cache(cfg, B, ctx_len=L, margin=gen + 8)
        def graft(fixed, pre):
            if fixed.shape == pre.shape:
                return pre
            axis = next(i for i, (a, b) in enumerate(zip(fixed.shape, pre.shape)) if a != b)
            pad = [(0, 0)] * fixed.ndim
            pad[axis] = (0, fixed.shape[axis] - pre.shape[axis])
            return jnp.pad(pre, pad)
        cache = jax.tree_util.tree_map(graft, cache, pre_cache)

        out_tokens = []
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        for _ in range(gen):
            out_tokens.append(np.asarray(tok))
            logits, cache = serve(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        dt = time.time() - t0
        toks = np.concatenate(out_tokens, axis=1)
        print(f"cluster {cluster_id}: served {B} reqs x {gen} tokens "
              f"in {dt:.2f}s ({B * gen / dt:.0f} tok/s) "
              f"sample={toks[0, :8].tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
