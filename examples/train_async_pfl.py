"""End-to-end driver: federated training of transformer clients with the
EchoPFL protocol + fault tolerance.

Each federated client is a reduced llama3.2-1b-family transformer (the same
config family as the production 1B model, scaled to CPU) training a causal
LM on its own synthetic token distribution. The EchoPFL server clusters the
clients by parameter distance, aggregates asynchronously, broadcasts on
demand, and checkpoints its full state (cluster centers, RNN predictor,
Top-K records) — the run can be killed and resumed.

    PYTHONPATH=src python examples/train_async_pfl.py [--steps 300] [--resume]
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, restore_pytree, save_pytree
from repro.configs import ARCH_REGISTRY
from repro.configs.base import reduced_config
from repro.core.server import EchoPFLServer
from repro.data.lm import token_stream
from repro.models import init_params, make_train_step
from repro.models.steps import TrainState, make_optimizer

CKPT_DIR = "experiments/train_async_pfl_ckpt"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(ARCH_REGISTRY["llama3.2-1b"], d_model=64, periods=2)
    key = jax.random.PRNGKey(0)
    init = init_params(cfg, key)
    opt = make_optimizer(cfg)
    train_step = jax.jit(make_train_step(cfg))

    # two latent "user groups" with different token distributions
    streams = [token_stream(cfg.vocab_size, seed=i % 2, batch=4, seq=32) for i in range(args.clients)]
    states = [TrainState(init, opt.init(init), jnp.zeros((), jnp.int32)) for _ in range(args.clients)]

    server = EchoPFLServer(init, num_initial_clusters=2, seed=0)
    ck = Checkpointer(CKPT_DIR, keep=2)
    start = 0
    if args.resume:
        from repro.checkpoint.checkpointer import latest_step

        step = latest_step(CKPT_DIR)
        if step is not None:
            d = os.path.join(CKPT_DIR, f"step_{step:010d}")
            _, extra = restore_pytree(d, like=None)  # manifest first: meta drives template
            template = {"server": server.state_template(extra["server_meta"])}
            tree, extra = restore_pytree(d, like=template)
            server.load_state(tree["server"], extra["server_meta"])
            start = step
            print(f"resumed server state at round {start}")

    t0 = time.time()
    losses = {i: [] for i in range(args.clients)}
    rng = np.random.default_rng(0)
    for rnd in range(start, args.steps):
        cid = int(rng.integers(args.clients))  # async: clients arrive in random order
        base = server.model_for(cid)
        st = states[cid]._replace(params=base)
        loss = None
        for _ in range(args.local_steps):
            st, metrics = train_step(st, next(streams[cid]))
            loss = float(metrics["loss"])
        states[cid] = st
        losses[cid].append(loss)
        downlinks = server.handle_upload(cid, st.params, 0, 128, t=time.time() - t0)
        for dl in downlinks:  # apply fresh models (unicast + broadcasts)
            states[dl.client_id] = states[dl.client_id]._replace(params=dl.params)
        if (rnd + 1) % 50 == 0:
            tree, meta = server.state_dict()
            ck.save(rnd + 1, {"server": tree}, extra={"server_meta": meta})
            mean_loss = np.mean([l[-1] for l in losses.values() if l])
            print(f"round {rnd+1:4d}: loss={mean_loss:.4f} "
                  f"clusters={server.stats()['clusters']} "
                  f"broadcasts={server.stats()['broadcasts']}")

    print("\n-- final --")
    first = {i: losses[i][0] for i in losses if losses[i]}
    last = {i: losses[i][-1] for i in losses if losses[i]}
    for i in sorted(first):
        print(f"client {i}: first_loss={first[i]:.4f} last_loss={last[i]:.4f}")
    assert all(last[i] < first[i] for i in last), "every client's LM loss must improve"
    a = server.clustering.assignment
    same_group = [a.get(i) for i in range(args.clients)]
    print(f"cluster assignment: {same_group} (clients with even/odd ids share token stats)")
    ck.close()


if __name__ == "__main__":
    main()
