"""Shared benchmark utilities: result tables, cluster-similarity metrics,
and the experiment grid the paper tables share."""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "experiments/bench")


def save_result(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)


def table(rows: list[dict], columns: list[str], title: str = "") -> str:
    if title:
        out = [f"== {title} =="]
    else:
        out = []
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in columns}
    out.append("  ".join(c.ljust(widths[c]) for c in columns))
    for r in rows:
        out.append("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in columns))
    return "\n".join(out)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.3f}" if abs(v) < 100 else f"{v:.1f}"
    return str(v)


def comembership(assign: dict, ids: list) -> np.ndarray:
    return np.array(
        [[assign.get(a) is not None and assign.get(a) == assign.get(b) for b in ids] for a in ids],
        float,
    )


def matrix_cosine(A: np.ndarray, B: np.ndarray) -> float:
    na, nb = np.linalg.norm(A), np.linalg.norm(B)
    if na == 0 or nb == 0:
        return 0.0
    return float((A * B).sum() / (na * nb))


def cluster_cosine(assign_a: dict, assign_b: dict, ids: list) -> float:
    """The paper's Fig. 11/12 similarity between two clusterings."""
    return matrix_cosine(comembership(assign_a, ids), comembership(assign_b, ids))


def assignment_of(strategy) -> dict:
    if hasattr(strategy, "clustering"):
        return dict(strategy.clustering.assignment)
    return dict(getattr(strategy, "assignment", {}))


def per_class_accuracy(report) -> dict[str, float]:
    """Mean accuracy per device class (slowest D5 ... fastest D4)."""
    by_class: dict[str, list[float]] = {}
    for cid, acc in report.per_client_acc.items():
        by_class.setdefault(report.per_client_class[cid], []).append(acc)
    return {k: float(np.mean(v)) for k, v in sorted(by_class.items())}


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
