"""Roofline table (deliverable g): per (arch x shape x mesh) the three terms
  compute_s    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory_s     = HLO_bytes / (chips x 819 GB/s HBM)
  collective_s = collective_bytes / (chips x 50 GB/s ICI)
read from the dry-run artifacts in experiments/dryrun/, plus the dominant
bottleneck and MODEL_FLOPS/HLO_FLOPs usefulness ratio.

Run ``python -m repro.launch.dryrun --all`` first (or let run.py do a quick
subset)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_result, table

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
OPT_DIR = os.environ.get("REPRO_DRYRUN_OPT_DIR", "experiments/dryrun_opt")


def load_records(mesh: str | None = None, directory: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory or DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run(quick: bool = False) -> dict:
    # prefer the optimized sweep when present; keep the paper-naive baseline
    # next to it for the before/after record (§Perf)
    use_opt = bool(glob.glob(os.path.join(OPT_DIR, "*pod16x16*.json")))
    recs = load_records(mesh="pod16x16", directory=OPT_DIR if use_opt else None)
    baseline = (
        {(r["arch"], r["shape"]): r for r in load_records(mesh="pod16x16")}
        if use_opt else {}
    )
    rows, skips = [], []
    for r in recs:
        if r["status"] == "SKIP":
            skips.append({"cell": f"{r['arch']} x {r['shape']}", "reason": r["reason"]})
            continue
        if r["status"] != "OK":
            rows.append({"arch": r["arch"], "shape": r["shape"], "bottleneck": "FAIL"})
            continue
        t = r["roofline"]
        dom = r["bottleneck"]
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        base = baseline.get((r["arch"], r["shape"]))
        base_dom = None
        if base and base.get("status") == "OK":
            bt = base["roofline"]
            base_dom = max(bt["compute_s"], bt["memory_s"], bt["collective_s"])
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "compute_s": t["compute_s"],
            "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "bottleneck": dom,
            "roofline_frac": t["compute_s"] / total if total else None,
            "useful_flops": r.get("model_flops_ratio"),
            "speedup_vs_naive": (base_dom / total) if (base_dom and total) else None,
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(table(rows, ["arch", "shape", "compute_s", "memory_s", "collective_s",
                       "bottleneck", "roofline_frac", "useful_flops", "speedup_vs_naive"],
                "Roofline terms per (arch x shape) on pod16x16 (256 chips)"
                + (" — OPTIMIZED (baseline ratio in last col)" if use_opt else " — naive baseline")))
    if skips:
        print(table(skips, ["cell", "reason"], "Documented skips"))
    out = {"rows": rows, "skips": skips}
    save_result("roofline", out)
    return out


if __name__ == "__main__":
    run()
