"""Client-fleet engine benchmark: loop vs fleet backends (REPRO_CLIENT).

Measures the three client-plane hot paths the fleet engine batches:

  * sync-round wall time — ``run_sync`` rounds where every selected
    client's local training is one fused vmapped-scan launch instead of
    O(clients x epochs) jit dispatches,
  * fleet-eval throughput — the simulator eval tick as one masked-accuracy
    launch instead of one ``evaluate`` dispatch (plus two host->device
    copies) per client,
  * dispatch flatness — fused launches per sync round stay O(1) as the
    fleet grows (the loop backend issues O(clients) dispatches).

``--json`` writes BENCH_client_fleet.json at the repo root so the perf
trajectory is tracked across PRs.

Usage:
    python benchmarks/bench_client_fleet.py [--clients 128] [--rounds 3] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import save_result, table  # noqa: E402
from repro.fl.experiment import build_clients, build_strategy  # noqa: E402
from repro.fl.simulator import Simulator  # noqa: E402


def _fresh_sim(num_clients: int, backend: str, seed: int = 0) -> Simulator:
    task, clients, init = build_clients("har", num_clients, seed=seed)
    strat = build_strategy("fedavg", init, clients, seed=seed)
    return Simulator(clients, strat, seed=seed, client_backend=backend)


def bench_sync_round(num_clients: int, rounds: int) -> dict:
    out = {}
    for backend in ("loop", "fleet"):
        _fresh_sim(num_clients, backend).run_sync(rounds=1)  # compile warmup
        sim = _fresh_sim(num_clients, backend)
        t0 = time.perf_counter()
        sim.run_sync(rounds=rounds)
        out[backend] = (time.perf_counter() - t0) / rounds
    out["speedup"] = out["loop"] / out["fleet"]
    return out


def bench_eval_tick(num_clients: int, reps: int = 10) -> dict:
    out = {}
    for backend in ("loop", "fleet"):
        sim = _fresh_sim(num_clients, backend)
        strat = sim.strategy
        init = strat.initial_models(sorted(sim.clients))
        sim._ensure_fleet(next(iter(init.values())))
        for cid, p in init.items():
            sim._set_model(sim.clients[cid], p)
        sim._evaluate(0.0)  # compile warmup
        t0 = time.perf_counter()
        for _ in range(reps):
            sim._evaluate(0.0)
        out[backend] = num_clients * reps / (time.perf_counter() - t0)  # client-evals/s
    out["speedup"] = out["fleet"] / out["loop"]
    return out


def bench_dispatch_flatness(sizes: tuple[int, ...], rounds: int = 2) -> list[dict]:
    """Fused launches per sync round under the fleet backend vs the
    dispatch count the loop backend would issue for the same round."""
    rows = []
    for n in sizes:
        sim = _fresh_sim(n, "fleet")
        sim.run_sync(rounds=rounds)
        epochs = next(iter(sim.clients.values())).local_epochs
        rows.append({
            "clients": n,
            "fleet_launches_per_round": sim._fleet.launches / rounds,
            "loop_dispatches_per_round": n * epochs + n,  # train epochs + evals
        })
    return rows


def run(quick: bool = False, clients: int = 128, rounds: int = 3, eval_reps: int = 10,
        json_out: bool = False) -> dict:
    if quick:
        clients, rounds, eval_reps = 32, 2, 4
    sync = bench_sync_round(clients, rounds)
    ev = bench_eval_tick(clients, eval_reps)
    flat = bench_dispatch_flatness(tuple(sorted({32, min(64, clients), clients})))

    print(table(
        [
            {"metric": "sync round (s)", "loop": sync["loop"], "fleet": sync["fleet"],
             "speedup": sync["speedup"]},
            {"metric": "eval (client-evals/s)", "loop": ev["loop"], "fleet": ev["fleet"],
             "speedup": ev["speedup"]},
        ],
        ["metric", "loop", "fleet", "speedup"],
        title=f"client fleet @ {clients} clients (har)",
    ))
    print(table(
        flat,
        ["clients", "fleet_launches_per_round", "loop_dispatches_per_round"],
        title="dispatch flatness (fused launches per sync round)",
    ))

    payload = {
        "clients": clients,
        "task": "har",
        "rounds": rounds,
        "sync_round_s": {"loop": sync["loop"], "fleet": sync["fleet"]},
        "sync_round_speedup": sync["speedup"],
        "eval_client_evals_per_s": {"loop": ev["loop"], "fleet": ev["fleet"]},
        "eval_speedup": ev["speedup"],
        "dispatch_flatness": flat,
    }
    save_result("client_fleet", payload)
    if json_out:
        path = os.path.join(REPO_ROOT, "BENCH_client_fleet.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"wrote {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--eval-reps", type=int, default=10)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true", help="write BENCH_client_fleet.json")
    args = ap.parse_args()
    run(quick=args.quick, clients=args.clients, rounds=args.rounds,
        eval_reps=args.eval_reps, json_out=args.json)


if __name__ == "__main__":
    main()
