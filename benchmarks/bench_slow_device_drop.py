"""Paper Fig. 2: dropping slow devices hurts clustering-based PFL far more
than single-model FL, because a cluster can lose most of its data.

Reproduction: 12 devices, 4 latent clusters; the 6 slow devices (D5) are
concentrated in two latent clusters. We compare (a) the fraction of *global*
data lost vs the fraction of the *affected clusters'* data lost, and (b)
realized accuracy on the slow devices when a strategy excludes them
(FedSEA-style dropping) vs EchoPFL which includes everyone."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.fl.experiment import build_clients, build_strategy
from repro.fl.simulator import Simulator


def run(quick: bool = False) -> dict:
    # device mix arranged so slow devices cluster together (paper's toy)
    task, clients, init = build_clients("image_recognition", 12, seed=0)
    # mark the 6 clients of two latent clusters as the slow group
    by_latent: dict[int, list] = {}
    for c in clients:
        by_latent.setdefault(c.data.latent_cluster, []).append(c)
    latent_sorted = sorted(by_latent, key=lambda k: -len(by_latent[k]))
    slow_ids = {c.client_id for k in latent_sorted[:2] for c in by_latent[k]}
    for c in clients:
        c.device_class = "D5" if c.client_id in slow_ids else "D3"

    total_n = sum(c.data.n for c in clients)
    slow_n = sum(c.data.n for c in clients if c.client_id in slow_ids)
    affected = [c for k in latent_sorted[:2] for c in by_latent[k]]
    affected_n = sum(c.data.n for c in affected)
    loss_global = slow_n / total_n
    loss_cluster = sum(
        c.data.n for c in affected if c.client_id in slow_ids
    ) / max(affected_n, 1)

    rows = [
        {"view": "single global model (FedAvg)", "data_lost_frac": loss_global},
        {"view": "affected PFL clusters (ClusterFL)", "data_lost_frac": loss_cluster},
    ]

    # realized accuracy: train excluding the slow group, then evaluate on it
    accs = {}
    for name in ("fedavg", "clusterfl", "echopfl"):
        kept = [c for c in clients if c.client_id not in slow_ids]
        strat = build_strategy(name, init, kept, seed=0)
        sim = Simulator(kept, strat, eval_interval=120, seed=0)
        sim.run(max_time=600 if quick else 1500, rounds=12)
        accs[f"{name}_excl_slow"] = float(
            np.mean([c.evaluate(strat.model_for(c.client_id) or init) for c in clients
                     if c.client_id in slow_ids])
        )
    # echopfl including everyone (its design point)
    strat = build_strategy("echopfl", init, clients, seed=0)
    sim = Simulator(clients, strat, eval_interval=120, seed=0)
    sim.run(max_time=600 if quick else 1500)
    accs["echopfl_incl_all"] = float(
        np.mean([c.evaluate(strat.model_for(c.client_id)) for c in clients
                 if c.client_id in slow_ids])
    )

    print(table(rows, ["view", "data_lost_frac"], "Fig.2 — data lost when 6 slow devices drop"))
    acc_rows = [{"setting": k, "slow_device_acc": v} for k, v in accs.items()]
    print(table(acc_rows, ["setting", "slow_device_acc"], "Fig.2b — realized slow-device accuracy"))
    out = {"data_loss": rows, "accuracy": accs}
    save_result("slow_device_drop", out)
    return out


if __name__ == "__main__":
    run()
