"""Paper Fig. 9 + Tab. 3: upload/download/total communication cost and
communication frequency. EchoPFL trades higher *download* frequency (riding
the fat downstream link) for fewer rounds to convergence, cutting total cost
vs FedAvg and avoiding FedAsyn's per-update unicast chatter.

:func:`run_compress` (registered as ``comm_compress``, ``--json`` writes
``BENCH_comm_compress.json`` at the repo root) is the MEASURED compressed
uplink sweep: the ``REPRO_UPLINK`` arms (none / EF-top-k / int8) run through
the live simulator billing — every upload crosses the wire at exact
``payload_bytes`` — at a fixed horizon, reporting total up/down bytes,
uploads/s, fixed-horizon accuracy, and the fused-codec launch counts that
stay flat as the fleet grows. The paper's ~37% comm-cost figure reproduces
on the unicast-symmetric FedAsyn ledger (uplink ~= half the bytes);
broadcast-heavy EchoPFL banks the same ~80% uplink-byte cut against a
downlink that dominates its ledger by design."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

import numpy as np

from benchmarks.common import save_result, table
from repro.fl.experiment import run_experiment

STRATEGIES = ["fedavg", "fedasyn", "fedsea", "echopfl"]


def run(quick: bool = False) -> dict:
    max_time = 1200 if quick else 3600
    rows = []
    raw = {}
    for name in STRATEGIES:
        _, _, strat, report = run_experiment(
            "image_recognition", name, num_clients=5 if quick else 20,
            max_time=max_time, rounds=40, seed=0, target_acc=0.85,
        )
        # the paper's metric is communication *to convergence*: an async
        # protocol that converged at t2t keeps training (and broadcasting)
        # afterwards, which must not be billed against it
        horizon = report.time_to_target if report.time_to_target is not None else report.duration
        up_b, down_b = report.bytes_until(horizon)
        dur_min = max(horizon / 60, 1e-9)
        rows.append({
            "strategy": name,
            "up_MB": up_b / 1e6,
            "down_MB": down_b / 1e6,
            "total_MB": (up_b + down_b) / 1e6,
            "up_per_min": report.up_events / (report.duration / 60),
            "down_per_min": report.down_events / (report.duration / 60),
            "t2t_min": None if report.time_to_target is None else report.time_to_target / 60,
            "acc": report.final_acc,
        })
        raw[name] = rows[-1]
    print(table(rows, ["strategy", "up_MB", "down_MB", "total_MB", "up_per_min",
                       "down_per_min", "t2t_min", "acc"],
                "Fig.9 / Tab.3 — communication cost to convergence"))

    fa, ep = raw["fedavg"], raw["echopfl"]
    fasy, fsea = raw["fedasyn"], raw["fedsea"]
    claims = {
        # FedAvg never reaches the target in this budget (its number is a
        # full-hour spend at ~0.48 acc); the like-for-like comparisons are
        # the async baselines, which EchoPFL beats decisively
        "comm_reduction_vs_fedasyn": 1 - ep["total_MB"] / fasy["total_MB"],
        "comm_reduction_vs_fedsea": 1 - ep["total_MB"] / fsea["total_MB"],
        "comm_vs_fedavg_nonconverged": ep["total_MB"] / fa["total_MB"],
        "acc_vs_fedavg": ep["acc"] - fa["acc"],
        "download_freq_ratio_vs_fedavg": ep["down_per_min"] / max(fa["down_per_min"], 1e-9),
        "upload_share_echopfl": ep["up_MB"] / ep["total_MB"],
        "upload_share_fedavg": fa["up_MB"] / fa["total_MB"],
    }
    print("claims:", {k: round(v, 3) for k, v in claims.items()})

    out = {"rows": rows, "claims": claims}
    save_result("comm_cost", out)
    return out


# ------------------------------------------------- measured compressed sweep
def _compress_arm(strategy: str, uplink, *, num_clients: int, max_time: float,
                  window: float, seed: int) -> dict:
    """One fixed-horizon coalesced run with the given REPRO_UPLINK arm:
    exact billed bytes, dense-equivalent bytes, wall-clock throughput, and
    the codec's fused launch count."""
    from repro.fl.experiment import build_clients, build_strategy
    from repro.fl.network import NetworkModel
    from repro.fl.simulator import Simulator

    task, clients, init = build_clients("har", num_clients, seed)
    strat = build_strategy(strategy, init, clients, seed=seed)
    sim = Simulator(
        clients, strat, network=NetworkModel(), eval_interval=120.0, seed=seed,
        coalesce_window=window, client_backend="fleet", uplink=uplink,
    )
    t0 = time.perf_counter()
    rep = sim.run(max_time=max_time)
    wall = time.perf_counter() - t0
    tail = float(np.mean([a for _, a in rep.curve[-5:]]))
    up = rep.extra.get("uplink") or {}
    return {
        "strategy": strategy,
        "uplink": uplink or "none",
        "up_MB": rep.up_bytes / 1e6,
        "down_MB": rep.down_bytes / 1e6,
        "total_MB": (rep.up_bytes + rep.down_bytes) / 1e6,
        "up_events": rep.up_events,
        "payload_bytes": up.get("payload_bytes"),
        "codec_launches": up.get("launches"),
        "uploads_per_s": rep.up_events / wall,
        "final_acc": rep.final_acc,
        "tail_acc": tail,
        "wall_s": wall,
    }


def run_compress(quick: bool = False, json_out: bool = False) -> dict:
    """Measured REPRO_UPLINK sweep at a fixed horizon (the comm-cost claim,
    end-to-end through the live billing instead of an analytical estimate)."""
    num_clients = 10 if quick else 20
    max_time = 1200.0 if quick else 3600.0
    window = 45.0
    rows = []
    for strategy in ("echopfl", "fedasyn"):
        for uplink in (None, "topk", "int8"):
            rows.append(_compress_arm(
                strategy, uplink, num_clients=num_clients, max_time=max_time,
                window=window, seed=0))
    print(table(rows, ["strategy", "uplink", "up_MB", "down_MB", "total_MB",
                       "up_events", "codec_launches", "uploads_per_s",
                       "final_acc", "tail_acc"],
                "REPRO_UPLINK sweep — measured compressed uplinks"))

    by = {(r["strategy"], r["uplink"]): r for r in rows}
    # fused-launch flatness: the same horizon at half the fleet issues a
    # comparable number of codec launches (launches track coalescing
    # windows, not clients) while upload events scale with the fleet
    small = _compress_arm("echopfl", "topk", num_clients=max(5, num_clients // 2),
                          max_time=max_time, window=window, seed=0)
    big = by[("echopfl", "topk")]
    launch_growth = big["codec_launches"] / max(small["codec_launches"], 1)
    event_growth = big["up_events"] / max(small["up_events"], 1)

    claims = {}
    for strategy in ("echopfl", "fedasyn"):
        base = by[(strategy, "none")]
        for mode in ("topk", "int8"):
            arm = by[(strategy, mode)]
            claims[f"{strategy}_{mode}_uplink_reduction"] = 1 - arm["up_MB"] / base["up_MB"]
            claims[f"{strategy}_{mode}_total_reduction"] = 1 - arm["total_MB"] / base["total_MB"]
            claims[f"{strategy}_{mode}_acc_delta"] = arm["tail_acc"] - base["tail_acc"]
    claims["launch_growth_at_2x_clients"] = launch_growth
    claims["event_growth_at_2x_clients"] = event_growth
    print("claims:", {k: round(v, 3) for k, v in claims.items()})

    payload = {
        "task": "har",
        "num_clients": num_clients,
        "horizon_s": max_time,
        "coalesce_window_s": window,
        "rows": rows,
        "launch_flatness": {"half_fleet": small, "launch_growth": launch_growth,
                            "event_growth": event_growth},
        "claims": claims,
    }
    save_result("comm_compress", payload)
    if json_out:
        path = os.path.join(REPO_ROOT, "BENCH_comm_compress.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"wrote {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="run the measured REPRO_UPLINK sweep instead of Fig.9/Tab.3")
    ap.add_argument("--json", action="store_true", help="write BENCH_comm_compress.json")
    args = ap.parse_args()
    if args.compress or args.json:
        run_compress(quick=args.quick, json_out=args.json)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()
