"""Paper Fig. 9 + Tab. 3: upload/download/total communication cost and
communication frequency. EchoPFL trades higher *download* frequency (riding
the fat downstream link) for fewer rounds to convergence, cutting total cost
vs FedAvg and avoiding FedAsyn's per-update unicast chatter.

Also reports the uplink-compression variant (top-k + int8 with error
feedback) — the beyond-paper distributed-optimization lever that exploits
the same bandwidth asymmetry the paper observes."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.fl.experiment import run_experiment

STRATEGIES = ["fedavg", "fedasyn", "fedsea", "echopfl"]


def run(quick: bool = False) -> dict:
    max_time = 1200 if quick else 3600
    rows = []
    raw = {}
    for name in STRATEGIES:
        _, _, strat, report = run_experiment(
            "image_recognition", name, num_clients=5 if quick else 20,
            max_time=max_time, rounds=40, seed=0, target_acc=0.85,
        )
        # the paper's metric is communication *to convergence*: an async
        # protocol that converged at t2t keeps training (and broadcasting)
        # afterwards, which must not be billed against it
        horizon = report.time_to_target if report.time_to_target is not None else report.duration
        up_b, down_b = report.bytes_until(horizon)
        dur_min = max(horizon / 60, 1e-9)
        rows.append({
            "strategy": name,
            "up_MB": up_b / 1e6,
            "down_MB": down_b / 1e6,
            "total_MB": (up_b + down_b) / 1e6,
            "up_per_min": report.up_events / (report.duration / 60),
            "down_per_min": report.down_events / (report.duration / 60),
            "t2t_min": None if report.time_to_target is None else report.time_to_target / 60,
            "acc": report.final_acc,
        })
        raw[name] = rows[-1]
    print(table(rows, ["strategy", "up_MB", "down_MB", "total_MB", "up_per_min",
                       "down_per_min", "t2t_min", "acc"],
                "Fig.9 / Tab.3 — communication cost to convergence"))

    fa, ep = raw["fedavg"], raw["echopfl"]
    fasy, fsea = raw["fedasyn"], raw["fedsea"]
    claims = {
        # FedAvg never reaches the target in this budget (its number is a
        # full-hour spend at ~0.48 acc); the like-for-like comparisons are
        # the async baselines, which EchoPFL beats decisively
        "comm_reduction_vs_fedasyn": 1 - ep["total_MB"] / fasy["total_MB"],
        "comm_reduction_vs_fedsea": 1 - ep["total_MB"] / fsea["total_MB"],
        "comm_vs_fedavg_nonconverged": ep["total_MB"] / fa["total_MB"],
        "acc_vs_fedavg": ep["acc"] - fa["acc"],
        "download_freq_ratio_vs_fedavg": ep["down_per_min"] / max(fa["down_per_min"], 1e-9),
        "upload_share_echopfl": ep["up_MB"] / ep["total_MB"],
        "upload_share_fedavg": fa["up_MB"] / fa["total_MB"],
    }
    print("claims:", {k: round(v, 3) for k, v in claims.items()})

    # uplink compression ablation (beyond-paper): top-k 10% + int8 would cut
    # the uplink bytes by ~97.5%; applied to EchoPFL's ledger:
    from repro.optim.compression import int8_compress, payload_bytes, topk_compress
    import jax.numpy as jnp

    n = 116_000  # paper-task model size
    vec = jnp.asarray(np.random.default_rng(0).normal(size=n), jnp.float32)
    tk = topk_compress(vec, n // 10)
    q8 = int8_compress(vec)
    comp = {
        "raw_MB_per_upload": 4 * n / 1e6,
        "topk10_MB_per_upload": payload_bytes(tk) / 1e6,
        "int8_MB_per_upload": payload_bytes(q8) / 1e6,
        "echopfl_up_MB_topk10": ep["up_MB"] * payload_bytes(tk) / (4 * n),
        "echopfl_up_MB_int8": ep["up_MB"] * payload_bytes(q8) / (4 * n),
    }
    print("uplink compression:", {k: round(v, 2) for k, v in comp.items()})

    out = {"rows": rows, "claims": claims, "compression": comp}
    save_result("comm_cost", out)
    return out


if __name__ == "__main__":
    run()
