"""Server upload throughput: device-resident plane vs. per-cluster pytrees.

Measures end-to-end ``handle_upload`` rate (assignment + staleness + CI push
+ aggregation + unicast materialization) for both storage backends across a
clients x clusters grid. The pytree path re-flattens and re-stacks every
cluster center per arriving upload; the plane path does one flatten, one
row gather, and the fused assign+lerp kernel — the gap widens with cluster
count, which is exactly the scaling dimension EchoPFL's refinement loop
grows (hm * C clusters held stably).

The broadcast predictor is disabled so the measurement isolates the
parameter-coordination hot path (the RNN decision cost is identical in
both backends); a secondary table reports the broadcast-on rate.

When more than one local device is visible, a third column measures the
row-sharded plane (``plane_sharded``): the same server with its row store
placed over a "plane" mesh spanning every local device. Note compute
placement is adaptive — at this grid's cluster counts the batched launches
stay below ``REPRO_PLANE_MESH_MIN_ROWS`` and run single-device against the
sharded storage; export ``REPRO_PLANE_MESH_MIN_ROWS=0`` to force the
per-shard kernel path (kernels/plane_sharded.py, exercised by the ci.sh
multi-device leg) into the measurement. On one CPU a multi-device mesh
needs a forced host platform, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.run --only server_throughput

    PYTHONPATH=src python -m benchmarks.run --only server_throughput
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, table
from repro.core.server import EchoPFLServer


def _model(dim_hidden: int):
    """MLP-shaped pytree, ~26k params at the default width (realistic ratio
    of leaf count to parameter count for the paper's on-device models)."""
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 6)
    h = dim_hidden
    return {
        "dense1": {"w": jax.random.normal(ks[0], (64, h)), "b": jnp.zeros((h,))},
        "dense2": {"w": jax.random.normal(ks[1], (h, h)), "b": jnp.zeros((h,))},
        "dense3": {"w": jax.random.normal(ks[2], (h, h)), "b": jnp.zeros((h,))},
        "head": {"w": jax.random.normal(ks[3], (h, 10)), "b": jnp.zeros((10,))},
    }


def _uploads(num_clients: int, num_clusters: int, n: int, template, seed=0):
    """Pre-generated upload stream: clients orbit well-separated anchors so
    the assignment paths exercise real multi-cluster distance math."""
    rng = np.random.default_rng(seed)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    stream = []
    for i in range(n):
        client = int(rng.integers(0, num_clients))
        anchor = 50.0 * (client % num_clusters) + float(rng.normal())
        upd = jax.tree_util.tree_unflatten(
            treedef, [leaf + anchor for leaf in leaves]
        )
        stream.append((client, upd))
    return stream


def _measure(backend: str, num_clients: int, num_clusters: int, *,
             enable_broadcast: bool, n_timed: int, template, mesh=None) -> float:
    srv = EchoPFLServer(
        template,
        num_initial_clusters=num_clusters,
        refine_every=10**9,  # refinement is a cold path; measured separately
        enable_broadcast=enable_broadcast,
        plane_backend=backend,
        # False pins the baseline columns to the single-device plane even if
        # REPRO_PLANE_MESH is exported in the environment
        plane_mesh=mesh if mesh is not None else False,
        seed=0,
    )
    # warm until every client has a plane row and capacity growth + jit
    # shapes have settled, so the timed window sees steady state only
    warm = _uploads(num_clients, num_clusters, max(64, 3 * num_clients), template, seed=1)
    for i, (client, upd) in enumerate(warm):
        srv.handle_upload(client, upd, 0, 8, t=float(i))
    stream = _uploads(num_clients, num_clusters, n_timed, template, seed=2)
    t0 = time.perf_counter()
    for i, (client, upd) in enumerate(stream):
        out = srv.handle_upload(client, upd, 0, 8, t=float(i))
    # block on the last downlink so device work is inside the window
    jax.block_until_ready(jax.tree_util.tree_leaves(out[-1].params))
    dt = time.perf_counter() - t0
    return n_timed / dt


def run(quick: bool = False) -> None:
    template = _model(64 if quick else 128)
    n_timed = 100 if quick else 300
    grid = [(16, 4), (64, 8)] if quick else [(16, 4), (64, 8), (64, 16), (128, 8)]
    plane_mesh = None
    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_plane_mesh

        plane_mesh = make_plane_mesh()
    cols = ["clients", "clusters", "pytree", "plane"]
    if plane_mesh is not None:
        cols.append("plane_sharded")
    rows = []
    for num_clients, num_clusters in grid:
        row = {"clients": num_clients, "clusters": num_clusters}
        for backend in ("pytree", "plane"):
            row[backend] = _measure(
                backend, num_clients, num_clusters,
                enable_broadcast=False, n_timed=n_timed, template=template,
            )
        if plane_mesh is not None:
            row["plane_sharded"] = _measure(
                "plane", num_clients, num_clusters,
                enable_broadcast=False, n_timed=n_timed, template=template,
                mesh=plane_mesh,
            )
        row["speedup"] = row["plane"] / row["pytree"]
        rows.append(row)
    title = "uploads/sec (broadcast predictor off — pure coordination path)"
    if plane_mesh is not None:
        title += f"; plane_sharded = row store over {plane_mesh.devices.size} devices"
    print(table(rows, cols + ["speedup"], title))

    bcast_rows = []
    for num_clients, num_clusters in grid[:2]:
        row = {"clients": num_clients, "clusters": num_clusters}
        for backend in ("pytree", "plane"):
            row[backend] = _measure(
                backend, num_clients, num_clusters,
                enable_broadcast=True, n_timed=n_timed, template=template,
            )
        row["speedup"] = row["plane"] / row["pytree"]
        bcast_rows.append(row)
    print(table(bcast_rows, ["clients", "clusters", "pytree", "plane", "speedup"],
                "uploads/sec (broadcast predictor on)"))

    save_result("server_throughput", {
        "coordination_only": rows,
        "with_broadcast": bcast_rows,
        "n_timed": n_timed,
    })


if __name__ == "__main__":
    run()
