"""LM-fleet benchmark: personalized-LM fine-tuning throughput as plane rows.

Two legs:

  * fleet scaling — ``run_lm_experiment`` sync rounds at growing fleet
    sizes, reporting uploads/sec through the simulator and trained
    tokens/sec through the vmapped LoRA-delta launches (the whole
    cohort's transformer fwd+bwd epochs are one fused scan launch),
  * model-axis plane ops — the server-side kernels at the LM delta row
    width, single-device vs an R×M ``(plane, model)`` mesh with
    ``REPRO_PLANE_MODEL_COMPUTE`` on and off. Runs in subprocesses with a
    forced 8-device host so the CPU CI tracks the dispatch overhead and
    TPU runs track the real speedup.

``--json`` writes BENCH_lm_fleet.json at the repo root so the perf
trajectory is tracked across PRs.

Usage:
    python benchmarks/bench_lm_fleet.py [--sizes 8,16,32] [--rounds 2] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import save_result, table  # noqa: E402

SEQ_LEN = 32
N_TRAIN = 8
LOCAL_EPOCHS = 2


def bench_fleet_scaling(sizes: tuple[int, ...], rounds: int) -> list[dict]:
    import jax

    from repro.fl.lm_task import default_lm_task, run_lm_experiment
    from repro.fl.simulator import model_bytes

    task = default_lm_task()
    delta_bytes = model_bytes(task.init_params(jax.random.PRNGKey(0)))
    kw = dict(seq_len=SEQ_LEN, n_train=N_TRAIN, n_test=2,
              local_epochs=LOCAL_EPOCHS, eval_interval=1e9)
    rows = []
    for n in sizes:
        run_lm_experiment("fedavg", num_clients=n, rounds=1, **kw)  # compile warmup
        t0 = time.perf_counter()
        _, _, _, rep = run_lm_experiment("fedavg", num_clients=n, rounds=rounds, **kw)
        wall = time.perf_counter() - t0
        trained_tokens = rep.up_events * N_TRAIN * SEQ_LEN * LOCAL_EPOCHS
        rows.append({
            "clients": n,
            "uploads_per_s": rep.up_events / wall,
            "tokens_per_s": trained_tokens / wall,
            "delta_kb": delta_bytes / 1024,
            "wall_s": wall,
        })
    return rows


_CHILD = textwrap.dedent("""
    import json, os, time
    import jax, jax.numpy as jnp
    from repro.kernels import ops

    R, K, D, reps = 512, 8, %(dim)d, %(reps)d
    xs = jax.random.normal(jax.random.PRNGKey(0), (R, D))
    cs = jax.random.normal(jax.random.PRNGKey(1), (K, D))
    mesh = None
    if os.environ.get("BENCH_MESH") == "1":
        from repro.launch.mesh import make_plane_mesh
        mesh = make_plane_mesh(len(jax.devices()) // 2, dim_shards=2)
    ops.l1_distance_pairwise(xs, cs, mesh=mesh).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        ops.l1_distance_pairwise(xs, cs, mesh=mesh).block_until_ready()
    print(json.dumps({"l1_us": (time.perf_counter() - t0) / reps * 1e6}))
""")


def bench_model_axis(dim: int, reps: int = 30) -> list[dict]:
    """Child-process timings of the pairwise-L1 plane kernel at the LM
    delta width: single device, R×M mesh with model-axis compute, and the
    same mesh with compute forced off (storage sharded, compute
    replicated)."""
    rows = []
    modes = [
        ("single-device", {}, "0"),
        ("mesh 4x2 model-compute on", {"REPRO_PLANE_MODEL_COMPUTE": "on"}, "1"),
        ("mesh 4x2 model-compute off", {"REPRO_PLANE_MODEL_COMPUTE": "off"}, "1"),
    ]
    for name, extra, mesh_on in modes:
        env = dict(os.environ)
        env.update(extra)
        env["BENCH_MESH"] = mesh_on
        env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
        if mesh_on == "1":
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                                + " --xla_force_host_platform_device_count=8")
        out = subprocess.run(
            [sys.executable, "-c", _CHILD % {"dim": dim, "reps": reps}],
            env=env, capture_output=True, text=True, timeout=600,
        )
        if out.returncode != 0:
            rows.append({"mode": name, "l1_us": None, "error": out.stderr[-300:]})
            continue
        rows.append({"mode": name, **json.loads(out.stdout.strip().splitlines()[-1])})
    return rows


def run(quick: bool = False, sizes: tuple[int, ...] = (8, 16, 32), rounds: int = 2,
        json_out: bool = False) -> dict:
    import jax

    from repro.fl.lm_task import default_lm_task

    if quick:
        sizes, rounds = (4, 8), 1

    task = default_lm_task()
    dim = sum(x.size for x in jax.tree_util.tree_leaves(task.init_params(jax.random.PRNGKey(0))))

    scaling = bench_fleet_scaling(tuple(sizes), rounds)
    model_axis = bench_model_axis(dim, reps=10 if quick else 30)

    print(table(scaling, ["clients", "uploads_per_s", "tokens_per_s", "delta_kb", "wall_s"],
                title=f"LM fleet scaling (tiny_lm, delta dim {dim})"))
    print(table(model_axis, ["mode", "l1_us"],
                title=f"plane pairwise-L1 @ rows of dim {dim}"))

    payload = {
        "base": task.cfg.name,
        "delta_dim": int(dim),
        "seq_len": SEQ_LEN,
        "n_train": N_TRAIN,
        "local_epochs": LOCAL_EPOCHS,
        "rounds": rounds,
        "fleet_scaling": scaling,
        "model_axis_l1": model_axis,
    }
    save_result("lm_fleet", payload)
    if json_out:
        path = os.path.join(REPO_ROOT, "BENCH_lm_fleet.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"wrote {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8,16,32")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true", help="write BENCH_lm_fleet.json")
    args = ap.parse_args()
    run(quick=args.quick, sizes=tuple(int(s) for s in args.sizes.split(",")),
        rounds=args.rounds, json_out=args.json)


if __name__ == "__main__":
    main()
