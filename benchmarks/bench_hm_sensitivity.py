"""Paper Fig. 16: robustness to the merge-trigger hyperparameter hm (the
maximum cluster count is hm x C)."""
from __future__ import annotations

from benchmarks.common import save_result, table
from repro.fl.experiment import run_experiment


def run(quick: bool = False) -> dict:
    max_time = 1500 if quick else 3600
    hms = [1.0, 2.0] if quick else [1.0, 1.5, 2.0, 3.0, 4.0]
    rows = []
    for hm in hms:
        _, _, strat, report = run_experiment(
            "image_recognition", "echopfl", num_clients=12 if quick else 20,
            max_time=max_time, seed=0, hm=hm,
        )
        st = strat.stats()
        rows.append({
            "hm": hm,
            "acc": report.final_acc,
            "t2t_min": None if report.time_to_target is None else report.time_to_target / 60,
            "final_clusters": st["clusters"],
            "merges": st["merges"],
        })
    print(table(rows, ["hm", "acc", "t2t_min", "final_clusters", "merges"],
                "Fig.16 — hm sensitivity (paper: robust, default hm=2)"))
    accs = [r["acc"] for r in rows]
    out = {"rows": rows, "acc_spread": max(accs) - min(accs)}
    save_result("hm_sensitivity", out)
    return out


if __name__ == "__main__":
    run()
