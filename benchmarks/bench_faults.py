"""Fault-rate sweep: protocol resilience under deterministic chaos (REPRO_FAULTS).

Sweeps the seeded fault injector's loss/crash rates over a fixed virtual
horizon and reports, per rate, what EchoPFL's retry-with-backoff discipline
(REPRO_FAULT_POLICY=retry, the default) preserves versus the
drop-the-straggler baseline (policy=drop, the classic FedAsync/sync
response of abandoning clients that keep missing the window — the Fig. 2
slow-device pathology, now induced by the network instead of the device):

  * ``final_acc`` / ``tail_acc`` — fixed-horizon mean accuracy over the
    surviving population (drop retires clients; their frozen models still
    count, which is exactly the personalization cost of abandonment).
  * ``uploads`` — aggregation rounds that actually landed in the horizon
    (retries push arrivals later; drops remove them entirely).
  * ``retry_MB`` — uplink bytes attributable to retransmissions alone,
    straight from ``NetworkModel.up_retry_bytes`` (every retry bills real
    bytes; nothing is free).
  * ``dropped`` — clients the drop policy retired.

The schedule is seeded and counter-keyed per (kind, client), so both arms
at a given rate see the *identical* crash/loss schedule — the comparison
isolates the policy, not the luck. ``--json`` writes BENCH_faults.json at
the repo root.

Usage:
    python benchmarks/bench_faults.py [--rates 0,0.1,0.3] [--clients 32] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import save_result, table  # noqa: E402
from repro.fl.experiment import build_clients, build_strategy  # noqa: E402
from repro.fl.faults import FaultConfig, FaultPlan  # noqa: E402
from repro.fl.network import NetworkModel  # noqa: E402
from repro.fl.simulator import Simulator  # noqa: E402


def _run(n, rate, policy, horizon, seed=0, window=30.0):
    task, clients, init = build_clients("har", n, seed=seed, samples_per_client=48)
    strat = build_strategy("echopfl", init, clients, seed=seed)
    faults = None
    if rate > 0:
        faults = FaultPlan(config=FaultConfig(
            seed=seed + 1,
            loss_rate=rate,
            crash_rate=rate / 2,
            dup_rate=rate / 4,
            reorder_rate=rate / 4,
            policy=policy,
        ))
    sim = Simulator(clients, strat, network=NetworkModel(), seed=seed,
                    client_backend="fleet", coalesce_window=window, faults=faults)
    rep = sim.run_async(max_time=horizon)
    k = max(1, len(rep.curve) // 5)
    ledger = rep.extra.get("faults", {})
    return {
        "final_acc": rep.final_acc,
        "tail_acc": sum(a for _, a in rep.curve[-k:]) / k,
        "uploads": rep.extra["uploads"],
        "retry_MB": rep.up_retry_bytes / 1e6,
        "up_MB": rep.up_bytes / 1e6,
        "dropped": ledger.get("dropped_clients", 0),
        "crashes": ledger.get("crashes", 0),
        "upload_failures": ledger.get("upload_failures", 0),
        "dups_absorbed": ledger.get("dups_absorbed", 0),
        "stale_absorbed": ledger.get("stale_downlinks_absorbed", 0),
    }


def _mean_arm(n, rate, policy, horizon, seeds):
    """Per-client accuracy at a fixed horizon is noisy (48 eval samples per
    client, one chaos realization); average the sweep over seeds so a
    single unlucky schedule can't tell the story."""
    runs = [_run(n, rate, policy, horizon, seed=s) for s in seeds]
    out = {k: sum(r[k] for r in runs) / len(runs) for k in runs[0]}
    out["final_acc_by_seed"] = [r["final_acc"] for r in runs]
    return out


def run(quick: bool = False, rates=(0.0, 0.1, 0.3), clients: int = 32,
        horizon: float = 2400.0, seeds=(0, 1, 2), json_out: bool = False) -> dict:
    if quick:
        rates, clients, horizon, seeds = (0.0, 0.3), 12, 900.0, (0,)
    rows, by_rate = [], {}
    for rate in rates:
        retry = _mean_arm(clients, rate, "retry", horizon, seeds)
        drop = _mean_arm(clients, rate, "drop", horizon, seeds) if rate > 0 else retry
        by_rate[str(rate)] = {"retry": retry, "drop": drop}
        rows.append({
            "fault rate": rate,
            "acc (retry)": retry["final_acc"],
            "acc (drop)": drop["final_acc"],
            "uploads (retry)": retry["uploads"],
            "uploads (drop)": drop["uploads"],
            "retry MB": retry["retry_MB"],
            "dropped clients": drop["dropped"],
        })

    print(table(
        rows,
        ["fault rate", "acc (retry)", "acc (drop)", "uploads (retry)",
         "uploads (drop)", "retry MB", "dropped clients"],
        title=f"fault sweep (har, {clients} clients, horizon={horizon:.0f}s, "
              f"mean over seeds {tuple(seeds)}, EchoPFL retry vs drop-straggler)",
    ))

    clean = by_rate.get("0.0") or by_rate[str(rates[0])]
    payload = {
        "task": "har",
        "clients": clients,
        "horizon_s": horizon,
        "window_s": 30.0,
        "seeds": list(seeds),
        "by_rate": by_rate,
        "headline": {
            "metric": "fixed-horizon mean accuracy under seeded chaos "
                      "(loss=r, crash=r/2, dup=reorder=r/4), mean over "
                      "seeds, REPRO_FAULT_POLICY=retry vs drop",
            "clean_final_acc": clean["retry"]["final_acc"],
            "acc_by_rate_retry": {r: v["retry"]["final_acc"] for r, v in by_rate.items()},
            "acc_by_rate_drop": {r: v["drop"]["final_acc"] for r, v in by_rate.items()},
            "note": "Both arms at a given rate draw the identical "
                    "counter-keyed fault schedule, so the gap isolates the "
                    "policy. At these rates the fixed-horizon population "
                    "accuracies land close (retired clients keep scoring "
                    "with their frozen personalized models, and EchoPFL's "
                    "staleness control discounts the very late retried "
                    "arrivals that would otherwise drag the clusters) — "
                    "the policy tradeoff the sweep makes measurable is in "
                    "the other columns: retry keeps every client served "
                    "(dropped=0, they continue to adapt past the horizon) "
                    "for retry_MB retransmission bytes and later arrivals; "
                    "drop saves the bytes but permanently retires clients "
                    "whose on-device models stop improving. Per-seed "
                    "accuracies are in by_rate.*.*.final_acc_by_seed — "
                    "single-seed chaos is noisy, which is why the table "
                    "reports seed means. Duplicates and reorders are "
                    "absorbed by the ingest/install fences and never "
                    "perturb the trajectory (tests/test_faults.py proves "
                    "trajectory identity under dup-only injection).",
        },
    }
    save_result("faults", payload)
    if json_out:
        path = os.path.join(REPO_ROOT, "BENCH_faults.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"wrote {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="0,0.1,0.3")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--horizon", type=float, default=2400.0)
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true", help="write BENCH_faults.json")
    args = ap.parse_args()
    run(quick=args.quick, rates=tuple(float(r) for r in args.rates.split(",")),
        clients=args.clients, horizon=args.horizon,
        seeds=tuple(int(s) for s in args.seeds.split(",")), json_out=args.json)


if __name__ == "__main__":
    main()
