"""Poison-rate sweep: ingest-guard defense vs unguarded collapse (REPRO_GUARD).

Sweeps the deterministic value-poison rates (REPRO_FAULT_POISON_*: NaN
injection, x1e3 magnitude blowup, sign flip on the post-codec upload)
over a fixed virtual horizon, guard off vs on, on BOTH async paths
(per-event and coalesced). Reports, per rate and arm:

  * ``final_acc`` / ``tail_acc`` — fixed-horizon population accuracy.
    The headline: unguarded ingest collapses at small poison rates (one
    NaN blended into a cluster center propagates through the echo
    broadcast to every member), while the guarded run tracks the clean
    curve.
  * ``quarantine`` — the guard ledger: per-reason rejections, clients
    escalated to quarantine/eviction, and center rollbacks taken from
    the snapshot ring.
  * ``nonfinite_centers`` — how many cluster centers ended the run
    corrupt (the negative control's smoking gun; always 0 under the
    guard).

Both arms at a given rate draw the identical counter-keyed poison
schedule — the comparison isolates the defense, not the luck. At rate 0
guard-on is bitwise-identical to guard-off (tests/test_guard.py pins
this); the sweep's rate-0 row is that claim made visible. ``--json``
writes BENCH_defense.json at the repo root.

Usage:
    python benchmarks/bench_defense.py [--rates 0,0.05,0.1] [--clients 16] [--json]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

import numpy as np  # noqa: E402

from benchmarks.common import save_result, table  # noqa: E402
from repro.fl.experiment import build_clients, build_strategy  # noqa: E402
from repro.fl.faults import FaultConfig, FaultPlan  # noqa: E402
from repro.fl.network import NetworkModel  # noqa: E402
from repro.fl.simulator import Simulator  # noqa: E402


def _nonfinite_centers(strat) -> int:
    cl = getattr(strat, "clustering", None)
    if cl is None:
        return 0
    bad = 0
    for c in cl.clusters.values():
        if cl.plane is not None:
            vec = np.asarray(c.center_vec)
        else:
            import jax

            vec = np.concatenate(
                [np.ravel(np.asarray(x)) for x in jax.tree_util.tree_leaves(c.center)]
            )
        bad += not np.isfinite(vec).all()
    return bad


def _run(n, rate, guard, horizon, seed=0, window=30.0):
    task, clients, init = build_clients("har", n, seed=seed, samples_per_client=48)
    strat = build_strategy("echopfl", init, clients, seed=seed)
    faults = None
    if rate > 0:
        # rate partitions across the three corruptions: half NaN (the
        # loudest), a quarter each blowup and sign flip (the quiet ones
        # the norm/dist statistics exist for)
        faults = FaultPlan(config=FaultConfig(
            seed=seed + 1,
            poison_nan_rate=rate / 2,
            poison_scale_rate=rate / 4,
            poison_sign_rate=rate / 4,
        ))
    sim = Simulator(clients, strat, network=NetworkModel(), seed=seed,
                    client_backend="fleet", coalesce_window=window, faults=faults,
                    guard="on" if guard else "off")
    rep = sim.run_async(max_time=horizon)
    k = max(1, len(rep.curve) // 5)
    tail = [a for _, a in rep.curve[-k:]]
    g = rep.extra.get("guard", {})
    f = rep.extra.get("faults", {})
    return {
        "final_acc": rep.final_acc,
        "tail_acc": sum(tail) / len(tail),
        "any_nan_acc": any(not math.isfinite(a) for _, a in rep.curve),
        "uploads": rep.extra["uploads"],
        "poisoned": f.get("poison_nan", 0) + f.get("poison_scale", 0) + f.get("poison_sign", 0),
        "nonfinite_centers": _nonfinite_centers(sim.strategy),
        "quarantine": {
            key: g.get(key, 0)
            for key in ("accepted", "rejected_nonfinite", "rejected_norm",
                        "rejected_dist", "rejected_quarantined", "rollbacks",
                        "quarantined_clients", "evicted_clients")
        } if g else None,
    }


def _mean_arm(n, rate, guard, horizon, seeds, window):
    runs = [_run(n, rate, guard, horizon, seed=s, window=window) for s in seeds]
    out = {}
    for key in ("final_acc", "tail_acc", "uploads", "poisoned", "nonfinite_centers"):
        vals = [r[key] for r in runs]
        # a NaN accuracy must not be averaged away: it IS the result
        out[key] = (float("nan") if any(isinstance(v, float) and not math.isfinite(v)
                                        for v in vals)
                    else sum(vals) / len(vals))
    out["any_nan_acc"] = any(r["any_nan_acc"] for r in runs)
    out["final_acc_by_seed"] = [r["final_acc"] for r in runs]
    if runs[0]["quarantine"] is not None:
        out["quarantine"] = {
            key: sum(r["quarantine"][key] for r in runs) / len(runs)
            for key in runs[0]["quarantine"]
        }
    return out


def run(quick: bool = False, rates=(0.0, 0.05, 0.1, 0.2), clients: int = 16,
        horizon: float = 1800.0, seeds=(0, 1, 2), json_out: bool = False) -> dict:
    if quick:
        rates, clients, horizon, seeds = (0.0, 0.1), 10, 900.0, (0,)
    windows = {"coalesced": 30.0, "per_event": 0.0}
    by_rate: dict = {}
    rows = []
    for rate in rates:
        entry: dict = {}
        for wname, window in windows.items():
            off = _mean_arm(clients, rate, False, horizon, seeds, window)
            on = (_mean_arm(clients, rate, True, horizon, seeds, window)
                  if rate > 0 or wname == "coalesced" else off)
            entry[wname] = {"guard_off": off, "guard_on": on}
        by_rate[str(rate)] = entry
        c = entry["coalesced"]
        rows.append({
            "poison rate": rate,
            "acc (off)": c["guard_off"]["final_acc"],
            "acc (on)": c["guard_on"]["final_acc"],
            "bad centers (off)": c["guard_off"]["nonfinite_centers"],
            "rejections (on)": (sum(
                v for k, v in c["guard_on"].get("quarantine", {}).items()
                if k.startswith("rejected")
            ) if c["guard_on"].get("quarantine") else 0),
            "rollbacks (on)": (c["guard_on"].get("quarantine") or {}).get("rollbacks", 0),
            "evicted (on)": (c["guard_on"].get("quarantine") or {}).get("evicted_clients", 0),
        })

    print(table(
        rows,
        ["poison rate", "acc (off)", "acc (on)", "bad centers (off)",
         "rejections (on)", "rollbacks (on)", "evicted (on)"],
        title=f"poison sweep (har, {clients} clients, horizon={horizon:.0f}s, "
              f"mean over seeds {tuple(seeds)}, coalesced window 30s; "
              "rate r = nan r/2 + scale r/4 + sign r/4)",
    ))

    clean = by_rate[str(rates[0])]["coalesced"]
    payload = {
        "task": "har",
        "clients": clients,
        "horizon_s": horizon,
        "seeds": list(seeds),
        "windows_s": windows,
        "by_rate": by_rate,
        "headline": {
            "metric": "fixed-horizon mean accuracy under seeded value poison "
                      "(nan=r/2, scale=r/4, sign=r/4 per delivered upload), "
                      "REPRO_GUARD off vs on, per-event and coalesced paths",
            "clean_final_acc": clean["guard_off"]["final_acc"],
            "acc_by_rate_off": {r: v["coalesced"]["guard_off"]["final_acc"]
                                for r, v in by_rate.items()},
            "acc_by_rate_on": {r: v["coalesced"]["guard_on"]["final_acc"]
                               for r, v in by_rate.items()},
            "note": "Unguarded ingest lets poisoned uploads blend straight "
                    "into shared cluster centers; the echo broadcast then "
                    "propagates the corruption to every member, so accuracy "
                    "collapses toward random (and nonfinite_centers > 0 "
                    "shows NaN physically reached the centers) at small "
                    "rates. The guard rejects non-finite uploads outright, "
                    "holds norm/dist outliers to per-cluster median+MAD "
                    "bounds, escalates repeat offenders to quarantine then "
                    "eviction, and rolls back any center whose post-blend "
                    "norm blows out — the guarded curve tracks the clean "
                    "one at a fraction of the poisoned accuracy loss. Both "
                    "arms share the identical counter-keyed poison "
                    "schedule; at rate 0 guard-on is bitwise-identical to "
                    "guard-off (tests/test_guard.py).",
        },
    }
    save_result("defense", payload)
    if json_out:
        path = os.path.join(REPO_ROOT, "BENCH_defense.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"wrote {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", default="0,0.05,0.1,0.2")
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--horizon", type=float, default=1800.0)
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true", help="write BENCH_defense.json")
    args = ap.parse_args()
    run(quick=args.quick, rates=tuple(float(r) for r in args.rates.split(",")),
        clients=args.clients, horizon=args.horizon,
        seeds=tuple(int(s) for s in args.seeds.split(",")), json_out=args.json)


if __name__ == "__main__":
    main()
