"""Paper Fig. 18/19: adaptation to local data-distribution shifts. Two
clients switch latent clusters mid-run (the case study's relabeling events);
EchoPFL's feedback-aware refinement should recover accuracy within a few
refinement rounds and move the clients to matching clusters."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.fl.experiment import build_clients, build_strategy
from repro.fl.simulator import Simulator


def run(quick: bool = False) -> dict:
    horizon = 2400 if quick else 4800
    shift_t = horizon / 2
    task, clients, init = build_clients("file_cleaning", 12, seed=0)
    strat = build_strategy("echopfl", init, clients, seed=0)
    sim = Simulator(clients, strat, eval_interval=60, seed=0)

    victims = [clients[0].client_id, clients[1].client_id]
    rng = np.random.default_rng(7)
    shifted = {"done": False}

    # run in two phases: before and after the shift
    orig_eval = sim._evaluate

    def eval_hook(t):
        if not shifted["done"] and t >= shift_t:
            for v in victims:
                new_cluster = (task.clients[v].latent_cluster + 1) % len(task.transforms)
                task.shift_client(v, new_cluster, rng)
            shifted["done"] = True
        return orig_eval(t)

    sim._evaluate = eval_hook
    report = sim.run(max_time=horizon)

    curve = report.curve
    victim_acc_end = float(np.mean([
        sim.clients[v].evaluate(strat.model_for(v)) for v in victims
    ]))
    # recovery time: first eval after shift where mean acc back within 3% of pre-shift
    pre = [a for t, a in curve if t < shift_t]
    pre_acc = float(np.mean(pre[-5:])) if pre else 0.0
    rec_t = None
    for t, a in curve:
        if t > shift_t and a >= pre_acc - 0.03:
            rec_t = t - shift_t
            break
    rows = [{
        "pre_shift_acc": pre_acc,
        "post_shift_min_acc": float(min(a for t, a in curve if t >= shift_t)),
        "final_acc": report.final_acc,
        "victim_final_acc": victim_acc_end,
        "recovery_s": rec_t,
    }]
    print(table(rows, list(rows[0]), "Fig.18/19 — drift adaptation (paper: recovers in 2-3 rounds)"))
    out = rows[0]
    save_result("drift_adaptation", out)
    return out


if __name__ == "__main__":
    run()
