"""Event-coalesced async pipeline benchmark (REPRO_ASYNC_COALESCE).

Measures async EchoPFL uploads/sec through three server paths:

  * ``loop / per-event`` — the seed async path (REPRO_CLIENT=loop, window
    off): one jit dispatch per local-training epoch per upload event, one
    per-upload server ingest, one heap event per downlink.
  * ``fleet / per-event`` — coalescing OFF under the (now default) batched
    client engine: row-sliced single-client training launches, still one
    Python/jit dispatch cycle per event.
  * ``fleet / coalesced`` — coalescing ON: each virtual-time window is one
    superstep — one fused row-sliced training launch for every round that
    finished in the window, one ``handle_uploads`` ingest scan for every
    arrival, one staged write + one batch event per broadcast fan-out.

The headline speedup is coalesced vs the seed per-event loop — the
user-visible gain of this round of work (client-plane default flip + event
coalescing). The on-vs-off ratio *within* the fleet backend is reported
alongside: it isolates the coalescing layer itself. With the batched
predictor chain (REPRO_PREDICTOR_BATCH, default on) the per-upload RNN
learn/decide dispatches that used to Amdahl-bound this ratio are fused
into one launch per window, and segments no longer cut at refinement
boundaries, so the remaining shared work is refinement sweeps and eval
ticks only.

The sweep also runs an equal-virtual-time ("fixed horizon") divergence
probe: both arms share the exact per-upload virtual-time trajectory (the
event schedule is model-independent), so an N-upload cap is already an
equal-time comparison — the probe demonstrates this by running both arms
to the same max_time over a several-times-longer horizon and reporting
per-arm upload counts and tail accuracy. Any final_acc gap at short caps
is the superstep time-shift through the transient climb, not a
divergence: the tails re-converge once past the climb.

Refinement probes every member of every cluster, so its period is scaled
with fleet size (``refine_every = clients // 4, floor 20``) to keep the
per-upload refinement share constant across the sweep — the same fraction
in every column, so ratios are unaffected.

``--json`` writes BENCH_async_coalesce.json at the repo root so the perf
trajectory is tracked across PRs.

Usage:
    python benchmarks/bench_async_coalesce.py [--clients 128,256,512] [--uploads 800] [--json]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import save_result, table  # noqa: E402
from repro.fl.experiment import build_clients, build_strategy  # noqa: E402
from repro.fl.network import NetworkModel  # noqa: E402
from repro.fl.simulator import Simulator  # noqa: E402


def _run(n, backend, window, max_uploads, refine_every, seed=0, max_time=None):
    task, clients, init = build_clients("har", n, seed=seed)
    strat = build_strategy("echopfl", init, clients, seed=seed)
    strat.refine_every = refine_every
    sim = Simulator(clients, strat, network=NetworkModel(), seed=seed,
                    client_backend=backend, coalesce_window=window)
    t0 = time.perf_counter()
    rep = sim.run_async(max_time=max_time if max_time is not None else 1e9,
                        max_uploads=max_uploads)
    dt = time.perf_counter() - t0
    groups = sim.coalesced_groups.get("upload_done", [])
    return {
        "uploads_per_s": rep.extra["uploads"] / dt,
        "uploads": rep.extra["uploads"],
        "wall_s": dt,
        "final_acc": rep.final_acc,
        "curve": [a for _, a in rep.curve],
        "end_t": rep.curve[-1][0] if rep.curve else 0.0,
        "mean_arrival_batch": (sum(groups) / len(groups)) if groups else 1.0,
    }


def _arm(n, backend, window, max_uploads, refine_every, reps):
    _run(n, backend, window, max_uploads, refine_every)  # full-length warmup (jit cache)
    runs = [_run(n, backend, window, max_uploads, refine_every) for _ in range(reps)]
    best = max(runs, key=lambda r: r["uploads_per_s"])
    best["uploads_per_s_median"] = statistics.median(r["uploads_per_s"] for r in runs)
    return best


def _fixed_horizon_probe(n, window, uploads, refine_every, mult):
    """Equal-virtual-time divergence probe (accuracy evidence, not perf).

    The coalesced arm runs ``mult``-times longer than the headline cap and
    its end time becomes the shared horizon H; the per-event arm then runs
    to ``max_time=H``. Both arms cover the same virtual time span by
    construction, and because the event schedule is model-independent they
    land near-identical upload counts — reported so the equal-time claim is
    checkable from the JSON. The longer horizon puts the transient climb
    behind the tail, where the superstep time-shift has washed out.
    """
    cap = uploads * mult
    on = _run(n, "fleet", window, cap, refine_every)
    horizon = on["end_t"]
    off = _run(n, "fleet", 0.0, cap * 4, refine_every, max_time=horizon)
    k = max(1, min(len(on["curve"]), len(off["curve"])) // 5)
    tail_on = sum(on["curve"][-k:]) / k
    tail_off = sum(off["curve"][-k:]) / k
    return {
        "horizon_s": horizon,
        "uploads": {"off": off["uploads"], "on": on["uploads"]},
        "final_acc": {"off": off["final_acc"], "on": on["final_acc"]},
        "final_acc_diff": abs(on["final_acc"] - off["final_acc"]),
        "tail_mean_acc": {"off": tail_off, "on": tail_on},
        "tail_mean_acc_diff": abs(tail_on - tail_off),
    }


def run(quick: bool = False, clients=(128, 256, 512), uploads: int = 800, window: float = 45.0,
        reps: int = 2, json_out: bool = False, fixed_horizon_mult: int = 4) -> dict:
    if quick:
        clients, uploads, reps, fixed_horizon_mult = (64,), 300, 1, 0
    rows, per_size = [], {}
    for n in clients:
        refine_every = max(20, n // 4)
        loop = _arm(n, "loop", 0.0, uploads, refine_every, reps)
        off = _arm(n, "fleet", 0.0, uploads, refine_every, reps)
        on = _arm(n, "fleet", window, uploads, refine_every, reps)
        # transient curves time-shift under the superstep semantics (the
        # pointwise max lands in the steep climb); the converged tail is
        # the accuracy claim, so report both
        acc_dev = max(
            abs(a - b) for a, b in zip(off["curve"], on["curve"])
        ) if off["curve"] and len(off["curve"]) == len(on["curve"]) else None
        k = max(1, len(off["curve"]) // 5)
        tail_dev = max(
            abs(a - b) for a, b in zip(off["curve"][-k:], on["curve"][-k:])
        ) if acc_dev is not None else None
        per_size[n] = {
            "refine_every": refine_every,
            "window_s": window,
            "loop_per_event_uploads_per_s": loop["uploads_per_s"],
            "fleet_per_event_uploads_per_s": off["uploads_per_s"],
            "fleet_coalesced_uploads_per_s": on["uploads_per_s"],
            "speedup_vs_seed_per_event": on["uploads_per_s"] / loop["uploads_per_s"],
            "speedup_on_vs_off": on["uploads_per_s"] / off["uploads_per_s"],
            "mean_arrival_batch": on["mean_arrival_batch"],
            "max_acc_curve_deviation_on_vs_off": acc_dev,
            "tail_acc_deviation_on_vs_off": tail_dev,
            "final_acc": {"off": off["final_acc"], "on": on["final_acc"]},
            "final_acc_diff": abs(on["final_acc"] - off["final_acc"]),
        }
        # Equal-virtual-time divergence evidence at the size where the
        # short-cap snapshot lands mid-climb (the smallest fleet sees the
        # fewest rounds per client at a fixed upload cap).
        if fixed_horizon_mult and n == min(clients):
            per_size[n]["fixed_horizon"] = _fixed_horizon_probe(
                n, window, uploads, refine_every, fixed_horizon_mult)
        rows.append({
            "clients": n,
            "loop/per-event": loop["uploads_per_s"],
            "fleet/per-event": off["uploads_per_s"],
            "fleet/coalesced": on["uploads_per_s"],
            "vs seed": per_size[n]["speedup_vs_seed_per_event"],
            "on vs off": per_size[n]["speedup_on_vs_off"],
            "batch": on["mean_arrival_batch"],
        })

    print(table(
        rows,
        ["clients", "loop/per-event", "fleet/per-event", "fleet/coalesced",
         "vs seed", "on vs off", "batch"],
        title=f"async uploads/sec (echopfl, har, window={window}s, {uploads} uploads)",
    ))

    payload = {
        "task": "har",
        "uploads": uploads,
        "window_s": window,
        "by_clients": per_size,
        "headline": {
            "metric": "async uploads/sec, coalesced (REPRO_ASYNC_COALESCE="
                      f"{window}) vs the seed per-event loop (REPRO_CLIENT=loop, window off)",
            "speedups_vs_seed_per_event": {
                str(n): per_size[n]["speedup_vs_seed_per_event"] for n in per_size
            },
            "speedups_on_vs_off_fleet": {
                str(n): per_size[n]["speedup_on_vs_off"] for n in per_size
            },
            "note": "REPRO_PREDICTOR_BATCH (default on) fuses the broadcast "
                    "predictor's per-upload RNN learn/decide into one batched "
                    "chain launch per window and lets segments stream through "
                    "refinement boundaries, removing the serial-RNN Amdahl "
                    "bound on on-vs-off. The parity suite "
                    "(tests/test_async_coalesce.py) proves bitwise "
                    "trajectories at degenerate windows on both kernel "
                    "backends; at real windows the virtual-time trajectory "
                    "and uplink billing stay exact while accuracy curves "
                    "time-shift through the transient climb. Short-cap "
                    "final_acc gaps (e.g. 128 clients at 800 uploads ~ 6 "
                    "rounds/client, mid-climb) are that time-shift, not "
                    "divergence: the fixed_horizon probe runs both arms to "
                    "the same virtual time over a longer horizon and their "
                    "tails re-converge (see by_clients.<n>.fixed_horizon).",
        },
    }
    save_result("async_coalesce", payload)
    if json_out:
        path = os.path.join(REPO_ROOT, "BENCH_async_coalesce.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"wrote {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="128,256,512")
    ap.add_argument("--uploads", type=int, default=800)
    ap.add_argument("--window", type=float, default=45.0)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fixed-horizon-mult", type=int, default=4,
                    help="horizon multiplier for the equal-virtual-time probe (0 disables)")
    ap.add_argument("--json", action="store_true", help="write BENCH_async_coalesce.json")
    args = ap.parse_args()
    run(quick=args.quick, clients=tuple(int(c) for c in args.clients.split(",")),
        uploads=args.uploads, window=args.window, reps=args.reps, json_out=args.json,
        fixed_horizon_mult=args.fixed_horizon_mult)


if __name__ == "__main__":
    main()
