"""Paper Tab. 5: parameter-level L1 vs feature-level KL divergence for the
real-time (per-arrival) clustering step. L1 compares flat parameter vectors;
KL requires a forward pass over a reference batch per (client, cluster) pair
— orders of magnitude slower on the per-upload critical path, which is why
EchoPFL uses L1 for incremental assignment and reserves distribution-level
signals for the periodic refinement."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, table
from repro.configs.paper_tasks import PAPER_TASKS
from repro.kernels import ops as K
from repro.models import mlp


def run(quick: bool = False) -> dict:
    cfg = PAPER_TASKS["image_recognition"]
    key = jax.random.PRNGKey(0)
    params_client = mlp.init_mlp(cfg, key)
    centers = [mlp.init_mlp(cfg, jax.random.PRNGKey(i + 1)) for i in range(4)]
    from repro.common.pytrees import tree_flat_vector

    u = tree_flat_vector(params_client)
    cmat = jnp.stack([tree_flat_vector(c) for c in centers])
    x_ref = jax.random.normal(jax.random.PRNGKey(9), (256, cfg.input_dim))

    # warm up jits
    K.l1_distance(u, cmat).block_until_ready()
    soft_c = mlp.predict_distributions(params_client, x_ref, cfg.num_classes)[1]

    reps = 20 if quick else 100
    t0 = time.perf_counter()
    for _ in range(reps):
        K.l1_distance(u, cmat).block_until_ready()
    l1_s = (time.perf_counter() - t0) / reps

    def kl_assign():
        p = mlp.predict_distributions(params_client, x_ref, cfg.num_classes)[1]
        outs = []
        for c in centers:  # one inference per candidate cluster
            q = mlp.predict_distributions(c, x_ref, cfg.num_classes)[1]
            outs.append(jnp.sum(p * (jnp.log(p + 1e-9) - jnp.log(q + 1e-9))))
        return jnp.stack(outs).block_until_ready()

    kl_assign()
    t0 = time.perf_counter()
    for _ in range(reps):
        kl_assign()
    kl_s = (time.perf_counter() - t0) / reps

    rows = [
        {"metric": "L1 (parameter, incremental)", "per_assignment_s": l1_s},
        {"metric": "KL (feature, per-arrival)", "per_assignment_s": kl_s},
        {"metric": "ratio", "per_assignment_s": kl_s / l1_s},
    ]
    print(table(rows, ["metric", "per_assignment_s"],
                "Tab.5 — distance-metric cost on the per-upload path"))
    out = {"l1_s": l1_s, "kl_s": kl_s, "ratio": kl_s / l1_s}
    save_result("distance_metrics", out)
    return out


if __name__ == "__main__":
    run()
