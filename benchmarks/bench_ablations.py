"""Paper Fig. 15: component ablations — EchoPFL without dynamic clustering
(degrades toward FedAvg) and without in-cluster broadcast (accuracy drop +
convergence slowdown)."""
from __future__ import annotations

from benchmarks.common import save_result, table
from repro.fl.experiment import run_experiment

VARIANTS = [
    ("echopfl (full)", dict()),
    ("w/o clustering", dict(enable_clustering=False)),
    ("w/o broadcast", dict(enable_broadcast=False)),
    ("fedavg (reference)", None),
]


def run(quick: bool = False) -> dict:
    max_time = 1500 if quick else 3600
    n = 12 if quick else 20
    rows = []
    for label, kw in VARIANTS:
        name = "fedavg" if kw is None else "echopfl"
        _, _, strat, report = run_experiment(
            "image_recognition", name, num_clients=n, max_time=max_time,
            rounds=40, seed=0, **(kw or {}),
        )
        st = strat.stats() if hasattr(strat, "stats") else {}
        stale = st.get("staleness", {})
        rows.append({
            "variant": label,
            "acc": report.final_acc,
            "t2t_min": None if report.time_to_target is None else report.time_to_target / 60,
            "q_max": stale.get("q_max"),
            "conv_proxy": stale.get("convergence_proxy"),
            "broadcasts": st.get("broadcasts"),
        })
    print(table(rows, ["variant", "acc", "t2t_min", "q_max", "conv_proxy", "broadcasts"],
                "Fig.15 — ablations (paper: w/o broadcast -8.09% acc, 1.8x time)"))
    out = {"rows": rows}
    save_result("ablations", out)
    return out


if __name__ == "__main__":
    run()
