"""Paper Tab. 1 + Fig. 8: accuracy vs training time across FL paradigms on
the three simulated tasks (IR / HAR / sound), with the paper's device mix
(20% D1, 20% D2, 20% D3, 40% D5). Reports mean accuracy, accuracy at the
slowest/fastest device class, time-to-target, and duration."""
from __future__ import annotations

import numpy as np

from benchmarks.common import per_class_accuracy, save_result, table
from repro.fl.experiment import run_experiment

STRATEGIES = ["fedavg", "oort", "fedasyn", "fedsea", "clusterfl", "echopfl", "standalone"]
TASKS = ["image_recognition", "har", "sound_detection"]
SPEED_ORDER = ["D5", "D1", "D2", "D3", "D4"]  # slowest -> fastest


def run(quick: bool = False) -> dict:
    tasks = TASKS[:1] if quick else TASKS
    seeds = [0] if quick else [0, 1]
    num_clients = 12 if quick else 20
    max_time = 1800 if quick else 3600

    rows = []
    for task in tasks:
        for strat_name in STRATEGIES:
            accs, slowest, fastest, t2t, dur = [], [], [], [], []
            for seed in seeds:
                _, _, strat, report = run_experiment(
                    task, strat_name, num_clients=num_clients,
                    max_time=max_time, rounds=40, seed=seed,
                )
                accs.append(report.final_acc)
                pc = per_class_accuracy(report)
                present = [c for c in SPEED_ORDER if c in pc]
                slowest.append(pc[present[0]])
                fastest.append(pc[present[-1]])
                t2t.append(report.time_to_target)
                dur.append(report.duration)
            rows.append({
                "task": task,
                "strategy": strat_name,
                "acc": float(np.mean(accs)),
                "acc_slowest": float(np.mean(slowest)),
                "acc_fastest": float(np.mean(fastest)),
                "t2t_min": None if any(t is None for t in t2t) else float(np.mean(t2t)) / 60,
                "dur_min": float(np.mean(dur)) / 60,
            })
    print(table(rows, ["task", "strategy", "acc", "acc_slowest", "acc_fastest", "t2t_min", "dur_min"],
                "Tab.1 / Fig.8 — accuracy vs training time"))

    # paper-claim checks (soft, reported not asserted)
    claims = {}
    for task in tasks:
        r = {row["strategy"]: row for row in rows if row["task"] == task}
        claims[task] = {
            "pfl_acc_gain_over_fedavg": r["echopfl"]["acc"] - r["fedavg"]["acc"],
            "echopfl_vs_clusterfl_acc": r["echopfl"]["acc"] - r["clusterfl"]["acc"],
            "echopfl_t2t_vs_clusterfl": (
                None if r["echopfl"]["t2t_min"] is None or r["clusterfl"]["t2t_min"] is None
                else 1 - r["echopfl"]["t2t_min"] / r["clusterfl"]["t2t_min"]
            ),
            "slow_device_gain_over_fedasyn": r["echopfl"]["acc_slowest"] - r["fedasyn"]["acc_slowest"],
        }
    out = {"rows": rows, "claims": claims}
    save_result("accuracy_time", out)
    return out


if __name__ == "__main__":
    run()
