"""Benchmark driver — one module per paper table/figure (DESIGN.md Sec. 7).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only name[,name]]

Writes JSON artifacts to experiments/bench/ and prints each table.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (
    bench_ablations,
    bench_accuracy_time,
    bench_async_coalesce,
    bench_client_fleet,
    bench_clustering_quality,
    bench_comm_cost,
    bench_comm_peaks,
    bench_defense,
    bench_distance_metrics,
    bench_drift_adaptation,
    bench_faults,
    bench_hm_sensitivity,
    bench_lm_fleet,
    bench_roofline,
    bench_server_throughput,
    bench_slow_device_drop,
)

BENCHES = {
    "accuracy_time": bench_accuracy_time.run,       # Tab.1 / Fig.8
    "slow_device_drop": bench_slow_device_drop.run, # Fig.2
    "comm_cost": bench_comm_cost.run,               # Fig.9 / Tab.3
    "comm_compress": bench_comm_cost.run_compress,  # REPRO_UPLINK measured sweep
    "comm_peaks": bench_comm_peaks.run,             # Fig.10
    "clustering_quality": bench_clustering_quality.run,  # Fig.11 / Fig.12
    "distance_metrics": bench_distance_metrics.run, # Tab.5
    "ablations": bench_ablations.run,               # Fig.15
    "hm_sensitivity": bench_hm_sensitivity.run,     # Fig.16
    "drift_adaptation": bench_drift_adaptation.run, # Fig.18 / Fig.19
    "roofline": bench_roofline.run,                 # deliverable (g)
    "server_throughput": bench_server_throughput.run,  # plane vs pytree hot path
    "client_fleet": bench_client_fleet.run,         # loop vs fleet client plane
    "async_coalesce": bench_async_coalesce.run,     # event-coalesced async pipeline
    "lm_fleet": bench_lm_fleet.run,                 # REPRO_TASK=lm throughput + model axis
    "faults": bench_faults.run,                     # chaos sweep: retry vs drop-straggler
    "defense": bench_defense.run,                   # poison sweep: guard off vs on
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes for smoke runs")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()

    names = list(BENCHES) if not args.only else [n.strip() for n in args.only.split(",")]
    failures = []
    for name in names:
        print(f"\n{'='*72}\n[{name}]")
        t0 = time.time()
        try:
            BENCHES[name](quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\n{'='*72}\ncompleted {len(names) - len(failures)}/{len(names)} benchmarks")
    if failures:
        raise SystemExit(f"failed: {failures}")


if __name__ == "__main__":
    main()
