"""Paper Fig. 11 + Fig. 12: EchoPFL's asynchronous dynamic clustering against
ClusterFL's synchronous full-information clustering at 120 clients, and
robustness of the result to the initial cluster count C."""
from __future__ import annotations

import numpy as np

from benchmarks.common import assignment_of, cluster_cosine, save_result, table
from repro.fl.experiment import run_experiment


def run(quick: bool = False) -> dict:
    n = 40 if quick else 120
    max_time = 1800 if quick else 3600

    _, clients_cf, cf, _ = run_experiment(
        "image_recognition", "clusterfl", num_clients=n, max_time=max_time, seed=0
    )
    ids = sorted(c.client_id for c in clients_cf)
    latent = {c.client_id: c.data.latent_cluster for c in clients_cf}
    cf_assign = assignment_of(cf)

    rows = []
    for c_init in ([2] if quick else [2, 3, 4, 6]):
        _, clients, ep, report = run_experiment(
            "image_recognition", "echopfl", num_clients=n, max_time=max_time,
            seed=0, num_clusters=c_init,
        )
        ep_assign = assignment_of(ep)
        rows.append({
            "init_C": c_init,
            "cos_vs_clusterfl": cluster_cosine(ep_assign, cf_assign, ids),
            "cos_vs_latent": cluster_cosine(ep_assign, latent, ids),
            "final_clusters": len(set(ep_assign.values())),
            "acc": report.final_acc,
            "t2t_min": None if report.time_to_target is None else report.time_to_target / 60,
        })
    rows.append({
        "init_C": "clusterfl(oracle)",
        "cos_vs_clusterfl": 1.0,
        "cos_vs_latent": cluster_cosine(cf_assign, latent, ids),
        "final_clusters": len(set(cf_assign.values())),
        "acc": None, "t2t_min": None,
    })
    print(table(rows, ["init_C", "cos_vs_clusterfl", "cos_vs_latent",
                       "final_clusters", "acc", "t2t_min"],
                f"Fig.11/12 — clustering quality ({n} clients; paper: cos up to 0.99)"))
    out = {"rows": rows, "num_clients": n}
    save_result("clustering_quality", out)
    return out


if __name__ == "__main__":
    run()
