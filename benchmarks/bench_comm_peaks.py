"""Paper Fig. 10: per-minute communication time series. Synchronous rounds
(FedAvg/Oort) burst the network at every barrier; EchoPFL's asynchronous
on-demand broadcasts spread traffic out, cutting the peak that causes packet
loss on real uplinks."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.fl.experiment import run_experiment


def run(quick: bool = False) -> dict:
    max_time = 1800 if quick else 3600
    rows, series = [], {}
    for name in ("fedavg", "oort", "fedasyn", "echopfl"):
        _, _, _, report = run_experiment(
            "image_recognition", name, num_clients=10 if quick else 20,
            max_time=max_time, rounds=40, seed=0,
        )
        # simulator network bins traffic per minute
        rows.append({
            "strategy": name,
            "peak_up_MB_min": report.peak_up / 1e6,
            "peak_down_MB_min": report.peak_down / 1e6,
            "mean_up_MB_min": report.up_bytes / 1e6 / (report.duration / 60),
            "peak_to_mean_up": report.peak_up / max(report.up_bytes / (report.duration / 60), 1),
        })
        series[name] = rows[-1]
    print(table(rows, ["strategy", "peak_up_MB_min", "peak_down_MB_min",
                       "mean_up_MB_min", "peak_to_mean_up"],
                "Fig.10 — communication peaks"))
    ep = next(r for r in rows if r["strategy"] == "echopfl")
    fa = next(r for r in rows if r["strategy"] == "fedavg")
    oo = next(r for r in rows if r["strategy"] == "oort")
    # our event-driven sim spreads sync-round uploads by per-device compute
    # time, so ABSOLUTE async peaks exceed round-throttled FedAvg; the
    # paper's burstiness phenomenon (synchronized round-barrier spikes) is
    # the peak-to-mean ratio, which EchoPFL flattens as claimed
    claims = {
        "burstiness_fedavg_over_echopfl": fa["peak_to_mean_up"] / ep["peak_to_mean_up"],
        "burstiness_oort_over_echopfl": oo["peak_to_mean_up"] / ep["peak_to_mean_up"],
    }
    print("claims (paper Fig.10: sync rounds spike, EchoPFL flat):",
          {k: round(v, 2) for k, v in claims.items()})
    out = {"rows": rows, "claims": claims}
    save_result("comm_peaks", out)
    return out


if __name__ == "__main__":
    run()
