"""Device-resident parameter plane: the server's hot matrix state.

EchoPFL's coordination layer is arithmetic over flattened parameter
vectors — L1 assignment distances (Eq. 1), mixed-rate center updates,
broadcast-gap norms, feedback probes. Keeping each of those vectors inside
a per-cluster pytree forces every arriving upload to re-flatten C pytrees
and re-stack them into a matrix (O(C * leaves) dispatches per upload).
Papaya-style async coordination only scales when that state is *already*
matrix-resident: one preallocated ``(capacity, dim)`` device buffer whose
rows are cluster centers, last-broadcast anchors, and per-client last
uploads, addressed through an explicit free-list.

Write-back is batched: row writes stage in a host-side dirty map (the
values are device arrays; only the row *bookkeeping* is host-side) and are
flushed into the buffer with a single scatter right before any batched
read (``rows``/``matrix``). Single-row reads are served straight from the
staging map, so ping-pong write/read of one row never touches the big
buffer. A batched producer of many rows (e.g. the client fleet refreshing
its evaluation-view rows after a broadcast) stages its whole ``(n, dim)``
batch with ONE :meth:`write_rows` call — the matrix is never sliced into
per-row values; flush applies staged matrices and then the per-row map,
later writes winning. Pytrees are materialized only at protocol
boundaries via the cached :class:`~repro.common.pytrees.FlattenSpec`
adapters.

The plane is a *generic* row store: the clustering layer keeps cluster
centers, broadcast anchors, and per-client last uploads in one plane, and
the client-fleet engine (:mod:`repro.fl.fleet`) keeps every simulated
device's model (plus its evaluation-view rows) in a second, independent
plane — separate instances are separate row namespaces, so fleet rows can
never collide with cluster rows.

Row-shard layout (fleet scale)
------------------------------
At the million-user north star the ``(capacity, dim)`` buffer outgrows one
accelerator's memory, so the plane optionally places it with a
``NamedSharding`` over a mesh (``launch.mesh.make_plane_mesh``): rows —
cluster centers, broadcast anchors, and per-client last uploads alike —
spread contiguously over the ``plane`` axis (device *i* owns rows
``[i*cap/S, (i+1)*cap/S)``), and the flat parameter dim may additionally
spread over a ``model`` axis when it divides. Capacity is rounded up to a
multiple of the row-shard count so every shard stays equal through
``_grow`` doublings, and the donated flush scatter preserves the placement
(re-pinned defensively if XLA ever drops it). Batched reads feed the
kernels in :mod:`repro.kernels.plane_sharded`, which run per-shard and
reduce across shards only where the protocol genuinely couples rows: an
``all_gather`` of per-shard distance vectors before an argmin, a one-hot
``psum`` to fetch the winning center row, and a ``psum`` of per-cluster
feedback segment sums. Everything per-row is bitwise-identical to the
single-device plane, so server trajectories do not depend on the mesh.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.common.pytrees import flatten_spec

PyTree = Any

# jitted vector helpers shared by the plane and the server hot path. The
# lerp is the canonical mixed-rate blend: ``t`` is static (folded exactly
# like the fused assign kernel folds its beta) and the two products are
# fenced apart (optimization_barrier) so XLA can never contract the
# mul-add into an FMA. Every path that blends a center — the assign
# kernel, this row lerp, the event-coalesced ingest scan — therefore emits
# the SAME two-op f32 expression regardless of surrounding fusion, which
# is what keeps batched and per-event server trajectories bitwise-equal.
import functools as _functools


@_functools.partial(jax.jit, static_argnames=("t",))
def lerp_vec(a, b, t):
    m1, m2 = jax.lax.optimization_barrier(((1.0 - t) * a, t * b))
    return m1 + m2


l1_vec = jax.jit(lambda a, b: jnp.sum(jnp.abs(a - b)))

# The flush scatter donates the buffer: without donation every row write-back
# would copy the whole (capacity, dim) plane, which scales with fleet size —
# exactly the O(capacity)-per-upload behavior the plane exists to avoid.
@_functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(buf, rows, vals):
    return buf.at[rows].set(vals)


@_functools.partial(jax.jit, donate_argnums=(0,))
def _set_row(buf, idx, vec):
    # single-row fast path: dynamic_update_slice lowers leaner than scatter
    return jax.lax.dynamic_update_slice_in_dim(buf, vec[None, :], idx, axis=0)


@jax.jit  # no donation: the output shape doubles, so aliasing is impossible
def _grow_buf(buf):
    return jnp.concatenate([buf, jnp.zeros_like(buf)], axis=0)


class ParameterPlane:
    """Preallocated ``(capacity, dim)`` row store for flat parameter vectors."""

    def __init__(
        self,
        template: PyTree,
        capacity: int = 32,
        dtype=jnp.float32,
        *,
        mesh: jax.sharding.Mesh | None = None,
        row_axis: str = "plane",
        dim_axis: str | None = "model",
    ):
        self.spec = flatten_spec(template, dtype)
        self.dim = self.spec.dim
        self.dtype = jnp.dtype(dtype)
        self.mesh = mesh
        self.row_axis = row_axis
        self.dim_axis = dim_axis
        self._row_shards = 1
        self._sharding: NamedSharding | None = None
        if mesh is not None and row_axis in mesh.axis_names:
            self._row_shards = mesh.shape[row_axis]
            dspec = (
                dim_axis
                if dim_axis is not None
                and dim_axis in mesh.axis_names
                and self.dim % mesh.shape[dim_axis] == 0
                else None
            )
            self._sharding = NamedSharding(mesh, PartitionSpec(row_axis, dspec))
            self._local_device = mesh.devices.flat[0]
            self._replicated = NamedSharding(mesh, PartitionSpec())
        capacity = max(1, int(capacity))
        # equal row shards, preserved through _grow doublings
        capacity = -(-capacity // self._row_shards) * self._row_shards
        self._buf = self._place(jnp.zeros((capacity, self.dim), self.dtype))
        self._free: list[int] = list(range(capacity - 1, -1, -1))
        self._used: set[int] = set()
        self._dirty: dict[int, jax.Array] = {}
        # bulk-staged (row_ids, {row: position}, (n, dim) matrix) groups
        # from write_rows; applied in order at flush, before the per-row
        # dirty map. The position dict keeps single-row reads O(1) while a
        # fleet-sized batch is staged.
        self._bulk: list[tuple[list[int], dict[int, int], jax.Array]] = []
        # incrementally-patched gather cache: XLA's row gather is slow on
        # CPU, and the hot path (`assign`) requests the same center-row set
        # every upload while only the aggregated row changes — so a cached
        # view is patched with a 1-row scatter instead of re-gathered.
        # Keyed (row_ids, domain): "local" views feed single-device compute,
        # "mesh" views are mesh-replicated operands for sharded launches.
        self._views: dict[tuple, jax.Array] = {}
        self._view_stale: dict[tuple, set] = {}

    # ------------------------------------------------------------- placement
    def _place(self, buf: jax.Array) -> jax.Array:
        """Pin ``buf`` to the plane's row sharding (no-op when unsharded or
        already placed — XLA propagates the sharding through the donated
        scatters, so this is a correctness guard, not a per-flush copy)."""
        if self._sharding is None or (
            hasattr(buf, "sharding")
            and buf.sharding.is_equivalent_to(self._sharding, buf.ndim)
        ):
            return buf
        return jax.device_put(buf, self._sharding)

    def _localize(self, x: jax.Array) -> jax.Array:
        """Land a small read (one row, a row-set view) on a single device.

        A slice/gather of the sharded buffer comes back *committed to the
        whole mesh*, which turns every downstream consumer — the fused
        assign kernel on an 8-row center view, a gap norm — into a
        full-mesh SPMD dispatch. Small batches belong on one device (the
        same economics as ``mesh_min_rows``); the sharded kernel launches
        reshard their operands on entry regardless (ops._to_mesh)."""
        if self._sharding is None:
            return x
        sharding = getattr(x, "sharding", None)
        if sharding is not None and sharding.device_set == {self._local_device}:
            return x
        return jax.device_put(x, self._local_device)

    # ---------------------------------------------------------------- sizing
    @property
    def capacity(self) -> int:
        return self._buf.shape[0]

    @property
    def num_allocated(self) -> int:
        return len(self._used)

    def _grow(self) -> None:
        old_cap = self.capacity
        self._buf = self._place(_grow_buf(self._buf))
        self._free.extend(range(2 * old_cap - 1, old_cap - 1, -1))

    # ------------------------------------------------------------ allocation
    def alloc(self, value: PyTree | jax.Array | None = None) -> int:
        """Claim a row; ``value`` (vector or pytree) seeds it, else zeros.

        Zero-seeding matters: freed rows keep their old bytes in the buffer,
        and a reader of a recycled row must never see the previous tenant.
        """
        if not self._free:
            self._grow()
        row = self._free.pop()
        self._used.add(row)
        if value is None:
            self._dirty[row] = jnp.zeros((self.dim,), self.dtype)
        else:
            self.write(row, value)
        return row

    def alloc_many(self, n: int) -> list[int]:
        """Claim ``n`` zero-seeded rows with ONE staged write (a single
        ``write_rows`` bookkeeping entry instead of ``n`` per-row stagings)
        — the fleet-sized allocation path: the uplink codec claiming a
        per-client anchor + residual row for every simulated device."""
        if n <= 0:
            return []
        while len(self._free) < n:
            self._grow()
        rows = [self._free.pop() for _ in range(n)]
        self._used.update(rows)
        self.write_rows(rows, jnp.zeros((n, self.dim), self.dtype))
        return rows

    def free(self, row: int) -> None:
        if row not in self._used:
            raise KeyError(f"row {row} is not allocated")
        self._used.discard(row)
        self._dirty.pop(row, None)
        self._free.append(row)
        for key in [k for k in self._views if row in self._view_stale[k] or row in k[0]]:
            del self._views[key], self._view_stale[key]

    # ----------------------------------------------------------------- io
    def as_vec(self, value: PyTree | jax.Array) -> jax.Array:
        """Coerce a 1-D vector or a pytree to a plane-dtype row vector."""
        if isinstance(value, jax.Array) and value.ndim == 1 and value.dtype == self.dtype:
            return value  # hot path: rows handed back to the plane verbatim
        if not isinstance(value, (dict, list, tuple)) and getattr(value, "ndim", None) == 1:
            return jnp.asarray(value, self.dtype)
        return self.spec.flatten(value)

    def write(self, row: int, value: PyTree | jax.Array) -> None:
        """Stage a row write (flushed lazily before the next batched read)."""
        if row not in self._used:
            raise KeyError(f"row {row} is not allocated")
        vec = self.as_vec(value)
        if vec.shape != (self.dim,):
            raise ValueError(f"expected ({self.dim},) vector, got {vec.shape}")
        # normalize the staging domain: a value coming back from a sharded
        # kernel launch is mesh-committed, and mixing that with local-device
        # rows in later jitted arithmetic is a placement error
        self._dirty[row] = self._localize(vec)
        for key in self._views:
            if row in key[0]:
                self._view_stale[key].add(row)

    def write_rows(self, row_ids: Sequence[int], matrix: jax.Array) -> None:
        """Stage a batched write: ``matrix[i]`` lands in ``row_ids[i]``.

        The matrix is staged *whole* — one host-side bookkeeping entry, no
        per-row device slicing — which is what keeps a batched producer of
        n rows (the fleet's eval-view refresh after a broadcast, a
        fleet-scale reassign sweep) at O(1) staging cost instead of O(n).
        Later writes to the same rows (either per-row or a later
        ``write_rows``) win at flush time. Duplicate ids within one call
        are rejected: the scatter's resolution order for duplicates is
        unspecified, so the staged read and the flushed buffer could
        disagree."""
        ids = [int(r) for r in row_ids]
        if len(set(ids)) != len(ids):
            raise ValueError("write_rows: duplicate row ids in one batch")
        for r in ids:
            if r not in self._used:
                raise KeyError(f"row {r} is not allocated")
        matrix = jnp.asarray(matrix, self.dtype)
        if matrix.shape != (len(ids), self.dim):
            raise ValueError(f"expected ({len(ids)}, {self.dim}) matrix, got {matrix.shape}")
        # per-row staged values for these rows are older than this matrix
        for r in ids:
            self._dirty.pop(r, None)
        if self._bulk:
            # keep the staging list bounded at one live matrix: cached-view
            # reads patch in place without flushing, so without this an
            # eval-tick producer would grow _bulk by one matrix per tick
            self.flush()
        self._bulk.append((ids, {r: i for i, r in enumerate(ids)}, self._localize(matrix)))
        id_set = set(ids)
        for key in self._views:
            hit = id_set.intersection(key[0])
            if hit:
                self._view_stale[key].update(hit)

    def flush(self) -> None:
        if not self._dirty and not self._bulk:
            return
        for ids, _, mat in self._bulk:
            self._buf = _scatter_rows(
                self._buf, jnp.asarray(ids, jnp.int32), self._replicate(mat)
            )
        self._bulk = []
        if not self._dirty:
            self._buf = self._place(self._buf)
            return
        order = sorted(self._dirty)
        if len(order) == 1:
            val = self._replicate(self._dirty[order[0]])
            self._buf = _set_row(self._buf, jnp.int32(order[0]), val)
        else:
            rows = jnp.asarray(order, jnp.int32)
            vals = self._replicate(jnp.stack([self._dirty[r] for r in order]))
            self._buf = _scatter_rows(self._buf, rows, vals)
        self._buf = self._place(self._buf)
        self._dirty.clear()

    def _replicate(self, v: jax.Array) -> jax.Array:
        """Move a staged value onto the mesh before it meets the sharded
        buffer in a jitted scatter (committed single-device operands and
        mesh-committed operands cannot share a jit)."""
        if self._sharding is None:
            return v
        return jax.device_put(v, self._replicated)

    def row(self, row: int) -> jax.Array:
        """Current ``(dim,)`` vector for one row (staged write wins)."""
        if row in self._dirty:
            return self._dirty[row]
        if row not in self._used:
            raise KeyError(f"row {row} is not allocated")
        for _, pos, mat in reversed(self._bulk):  # latest staged matrix wins
            p = pos.get(row)
            if p is not None:
                return self._localize(mat[p])
        return self._localize(self._buf[row])

    def _staged_rows(self, rs: list[int]) -> jax.Array:
        """(len(rs), dim) current values for ``rs``, preferring ONE gather
        from the live staged bulk matrix over per-row reads — this is what
        keeps a view patch after a fleet-wide ``write_rows`` at O(1)
        dispatches instead of one slice per stale row."""
        if self._bulk and not any(r in self._dirty for r in rs):
            _, pos, mat = self._bulk[-1]  # bounded: the only live matrix
            if all(r in pos for r in rs):
                sel = jnp.asarray([pos[r] for r in rs], jnp.int32)
                return self._localize(mat[sel])
        return jnp.stack([self.row(r) for r in rs])

    def _shard_rows(self, x: jax.Array) -> jax.Array:
        """Pin an ``(n, dim)`` row batch *sharded* over the plane's row axis
        (the operand form ``ops._to_mesh_rows`` passes through untouched)."""
        want = NamedSharding(self.mesh, PartitionSpec(self.row_axis, None))
        sharding = getattr(x, "sharding", None)
        if sharding is not None and sharding.is_equivalent_to(want, x.ndim):
            return x
        return jax.device_put(x, want)

    def take(self, row_ids: Sequence[int], *, on_mesh: bool | str = False) -> jax.Array:
        """Uncached ``(len(row_ids), dim)`` gather of the requested rows.

        Same placement semantics as :meth:`rows` (including the
        ``"shard"`` row-sharded form), but never touches the view cache: a
        caller gathering a *different* row set every call (a refine sweep's
        flagged members, a dissolve's victim uploads) must not evict the
        hot cached sets (the per-upload center matrix, the model-row bank,
        the eval-row bank)."""
        if len(row_ids) == 0:
            return jnp.zeros((0, self.dim), self.dtype)
        self.flush()
        view = self._buf[jnp.asarray(list(row_ids), jnp.int32)]
        if on_mesh == "shard" and self._sharding is not None:
            return self._shard_rows(view)
        return self._replicate(view) if on_mesh and self._sharding is not None else self._localize(view)

    def rows(self, row_ids: Sequence[int], *, on_mesh: bool | str = False) -> jax.Array:
        """Stacked ``(len(row_ids), dim)`` view of the requested rows.

        Repeat requests for the same row set (the per-upload center matrix)
        are served from a cached gather patched in place with the rows that
        changed since — O(changed_rows * dim), not O(len * dim). The
        returned array is a snapshot: valid until the same row set is
        requested again after a write.

        ``on_mesh`` asks for a mesh placement instead of the single local
        device — the operand forms the *sharded* kernel launches consume:
        ``True`` (or ``"replicate"``) replicates the view across the plane
        mesh (small operands: the center matrix every query row scores
        against); ``"shard"`` lands it sharded over the row axis (the
        fleet-scale row batch — a reassign/dissolve sweep over thousands of
        upload rows — which must never round-trip through one local device
        on exactly the path sharding exists to relieve). Either form is
        cached and patched exactly like the local view. Ignored (plain
        local view) when the plane is unsharded.
        """
        if len(row_ids) == 0:
            return jnp.zeros((0, self.dim), self.dtype)
        if self._sharding is None:
            on_mesh = False
        ids = tuple(row_ids)
        if on_mesh == "shard":
            key = (ids, "shard")
            place = self._replicate  # patch values enter like flush scatters
        elif on_mesh:
            key = (ids, "mesh")
            place = self._replicate
        else:
            key = (ids, "local")
            place = lambda v: v
        view = self._views.pop(key, None)  # pop + reinsert: move-to-end on hit
        if view is not None:
            stale = self._view_stale[key]
            if stale:
                if len(stale) == 1:
                    (r,) = stale
                    view = _set_row(view, jnp.int32(ids.index(r)), place(self.row(r)))
                else:
                    stale_list = list(stale)
                    pos = [ids.index(r) for r in stale_list]
                    vals = place(self._staged_rows(stale_list))
                    view = _scatter_rows(view, jnp.asarray(pos, jnp.int32), vals)
                if on_mesh == "shard":  # guard: the donated patch scatter
                    view = self._shard_rows(view)  # must not drop the placement
                stale.clear()
            self._views[key] = view
            return view
        self.flush()
        view = self._buf[jnp.asarray(list(ids), jnp.int32)]
        if on_mesh == "shard":
            view = self._shard_rows(view)
        else:
            view = self._replicate(view) if on_mesh else self._localize(view)
        if len(self._views) >= 4:  # tiny LRU cache: hot sets only. Insertion
            # order is recency order (hits reinsert), so the head is the
            # true LRU victim — a burst of cold reads can no longer evict
            # the hot per-upload center set just because it was cached first.
            oldest = next(iter(self._views))
            del self._views[oldest], self._view_stale[oldest]
        self._views[key] = view
        self._view_stale[key] = set()
        return view

    def matrix(self) -> jax.Array:
        """The full backing buffer (flushed). Never-allocated rows are
        zeros; *freed* rows keep their last tenant's bytes until realloc
        (``alloc`` zero-seeds, so ``row``/``rows`` of live rows never
        expose them). A snapshot view: valid until the next write-back
        donates the buffer."""
        self.flush()
        return self._buf

    # ------------------------------------------------------------ arithmetic
    def lerp_row(self, row: int, value: PyTree | jax.Array, t: float) -> None:
        """row <- (1 - t) * row + t * value (the async mixing step)."""
        self.write(row, lerp_vec(self.row(row), self.as_vec(value), t))

    def copy_row(self, src: int, dst: int) -> None:
        self.write(dst, self.row(src))

    def l1_rows(self, a: int, b: int) -> jax.Array:
        return l1_vec(self.row(a), self.row(b))

    # ------------------------------------------------------------- adapters
    def from_pytree(self, tree: PyTree) -> jax.Array:
        return self.spec.flatten(tree)

    def to_pytree(self, row: int) -> PyTree:
        return self.spec.unflatten(self.row(row))

    def vec_to_pytree(self, vec: jax.Array) -> PyTree:
        return self.spec.unflatten(vec)
