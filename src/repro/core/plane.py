"""Device-resident parameter plane: the server's hot matrix state.

EchoPFL's coordination layer is arithmetic over flattened parameter
vectors — L1 assignment distances (Eq. 1), mixed-rate center updates,
broadcast-gap norms, feedback probes. Keeping each of those vectors inside
a per-cluster pytree forces every arriving upload to re-flatten C pytrees
and re-stack them into a matrix (O(C * leaves) dispatches per upload).
Papaya-style async coordination only scales when that state is *already*
matrix-resident: one preallocated ``(capacity, dim)`` device buffer whose
rows are cluster centers, last-broadcast anchors, and per-client last
uploads, addressed through an explicit free-list.

Write-back is batched: row writes stage in a host-side dirty map (the
values are device arrays; only the row *bookkeeping* is host-side) and are
flushed into the buffer with a single scatter right before any batched
read (``rows``/``matrix``). Single-row reads are served straight from the
staging map, so ping-pong write/read of one row never touches the big
buffer. Pytrees are materialized only at protocol boundaries via the
cached :class:`~repro.common.pytrees.FlattenSpec` adapters.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.common.pytrees import flatten_spec

PyTree = Any

# jitted vector helpers shared by the plane and the server hot path
lerp_vec = jax.jit(lambda a, b, t: (1.0 - t) * a + t * b)
l1_vec = jax.jit(lambda a, b: jnp.sum(jnp.abs(a - b)))

# The flush scatter donates the buffer: without donation every row write-back
# would copy the whole (capacity, dim) plane, which scales with fleet size —
# exactly the O(capacity)-per-upload behavior the plane exists to avoid.
import functools as _functools


@_functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(buf, rows, vals):
    return buf.at[rows].set(vals)


@_functools.partial(jax.jit, donate_argnums=(0,))
def _set_row(buf, idx, vec):
    # single-row fast path: dynamic_update_slice lowers leaner than scatter
    return jax.lax.dynamic_update_slice_in_dim(buf, vec[None, :], idx, axis=0)


@jax.jit  # no donation: the output shape doubles, so aliasing is impossible
def _grow_buf(buf):
    return jnp.concatenate([buf, jnp.zeros_like(buf)], axis=0)


class ParameterPlane:
    """Preallocated ``(capacity, dim)`` row store for flat parameter vectors."""

    def __init__(self, template: PyTree, capacity: int = 32, dtype=jnp.float32):
        self.spec = flatten_spec(template, dtype)
        self.dim = self.spec.dim
        self.dtype = jnp.dtype(dtype)
        capacity = max(1, int(capacity))
        self._buf = jnp.zeros((capacity, self.dim), self.dtype)
        self._free: list[int] = list(range(capacity - 1, -1, -1))
        self._used: set[int] = set()
        self._dirty: dict[int, jax.Array] = {}
        # incrementally-patched gather cache: XLA's row gather is slow on
        # CPU, and the hot path (`assign`) requests the same center-row set
        # every upload while only the aggregated row changes — so a cached
        # view is patched with a 1-row scatter instead of re-gathered.
        self._views: dict[tuple, jax.Array] = {}
        self._view_stale: dict[tuple, set] = {}

    # ---------------------------------------------------------------- sizing
    @property
    def capacity(self) -> int:
        return self._buf.shape[0]

    @property
    def num_allocated(self) -> int:
        return len(self._used)

    def _grow(self) -> None:
        old_cap = self.capacity
        self._buf = _grow_buf(self._buf)
        self._free.extend(range(2 * old_cap - 1, old_cap - 1, -1))

    # ------------------------------------------------------------ allocation
    def alloc(self, value: PyTree | jax.Array | None = None) -> int:
        """Claim a row; ``value`` (vector or pytree) seeds it, else zeros.

        Zero-seeding matters: freed rows keep their old bytes in the buffer,
        and a reader of a recycled row must never see the previous tenant.
        """
        if not self._free:
            self._grow()
        row = self._free.pop()
        self._used.add(row)
        if value is None:
            self._dirty[row] = jnp.zeros((self.dim,), self.dtype)
        else:
            self.write(row, value)
        return row

    def free(self, row: int) -> None:
        if row not in self._used:
            raise KeyError(f"row {row} is not allocated")
        self._used.discard(row)
        self._dirty.pop(row, None)
        self._free.append(row)
        for key in [k for k in self._views if row in self._view_stale[k] or row in k]:
            del self._views[key], self._view_stale[key]

    # ----------------------------------------------------------------- io
    def as_vec(self, value: PyTree | jax.Array) -> jax.Array:
        """Coerce a 1-D vector or a pytree to a plane-dtype row vector."""
        if isinstance(value, jax.Array) and value.ndim == 1 and value.dtype == self.dtype:
            return value  # hot path: rows handed back to the plane verbatim
        if not isinstance(value, (dict, list, tuple)) and getattr(value, "ndim", None) == 1:
            return jnp.asarray(value, self.dtype)
        return self.spec.flatten(value)

    def write(self, row: int, value: PyTree | jax.Array) -> None:
        """Stage a row write (flushed lazily before the next batched read)."""
        if row not in self._used:
            raise KeyError(f"row {row} is not allocated")
        vec = self.as_vec(value)
        if vec.shape != (self.dim,):
            raise ValueError(f"expected ({self.dim},) vector, got {vec.shape}")
        self._dirty[row] = vec
        for key in self._views:
            if row in key:
                self._view_stale[key].add(row)

    def flush(self) -> None:
        if not self._dirty:
            return
        order = sorted(self._dirty)
        if len(order) == 1:
            self._buf = _set_row(self._buf, jnp.int32(order[0]), self._dirty[order[0]])
        else:
            rows = jnp.asarray(order, jnp.int32)
            vals = jnp.stack([self._dirty[r] for r in order])
            self._buf = _scatter_rows(self._buf, rows, vals)
        self._dirty.clear()

    def row(self, row: int) -> jax.Array:
        """Current ``(dim,)`` vector for one row (staged write wins)."""
        if row in self._dirty:
            return self._dirty[row]
        if row not in self._used:
            raise KeyError(f"row {row} is not allocated")
        return self._buf[row]

    def rows(self, row_ids: Sequence[int]) -> jax.Array:
        """Stacked ``(len(row_ids), dim)`` view of the requested rows.

        Repeat requests for the same row set (the per-upload center matrix)
        are served from a cached gather patched in place with the rows that
        changed since — O(changed_rows * dim), not O(len * dim). The
        returned array is a snapshot: valid until the same row set is
        requested again after a write.
        """
        if len(row_ids) == 0:
            return jnp.zeros((0, self.dim), self.dtype)
        key = tuple(row_ids)
        view = self._views.get(key)
        if view is not None:
            stale = self._view_stale[key]
            if stale:
                if len(stale) == 1:
                    (r,) = stale
                    view = _set_row(view, jnp.int32(key.index(r)), self.row(r))
                else:
                    pos = [key.index(r) for r in stale]
                    vals = jnp.stack([self.row(r) for r in stale])
                    view = _scatter_rows(view, jnp.asarray(pos, jnp.int32), vals)
                self._views[key] = view
                stale.clear()
            return view
        self.flush()
        view = self._buf[jnp.asarray(list(key), jnp.int32)]
        if len(self._views) >= 4:  # tiny LRU-ish cache: hot sets only
            oldest = next(iter(self._views))
            del self._views[oldest], self._view_stale[oldest]
        self._views[key] = view
        self._view_stale[key] = set()
        return view

    def matrix(self) -> jax.Array:
        """The full backing buffer (flushed); rows not allocated are zeros.
        A snapshot view: valid until the next write-back donates the buffer."""
        self.flush()
        return self._buf

    # ------------------------------------------------------------ arithmetic
    def lerp_row(self, row: int, value: PyTree | jax.Array, t: float) -> None:
        """row <- (1 - t) * row + t * value (the async mixing step)."""
        self.write(row, lerp_vec(self.row(row), self.as_vec(value), t))

    def copy_row(self, src: int, dst: int) -> None:
        self.write(dst, self.row(src))

    def l1_rows(self, a: int, b: int) -> jax.Array:
        return l1_vec(self.row(a), self.row(b))

    # ------------------------------------------------------------- adapters
    def from_pytree(self, tree: PyTree) -> jax.Array:
        return self.spec.flatten(tree)

    def to_pytree(self, row: int) -> PyTree:
        return self.spec.unflatten(self.row(row))

    def vec_to_pytree(self, vec: jax.Array) -> PyTree:
        return self.spec.unflatten(vec)
