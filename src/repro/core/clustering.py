"""Data-aware dynamic client clustering (paper Sec. 4).

Three mechanisms:
  * on-arrival initial assignment (Sec. 4.2): the first C arrivals seed the
    centers; later arrivals go to the nearest center by L1 parameter
    distance (Eq. 1) — computed by the Pallas streaming kernel on TPU.
  * feedback (Sec. 4.3.1): chi-squared(F_pred, F_true) x Var(S_soft)
    (Eq. 2/3) de-confounds clustering error from training stage.
  * refinement (Sec. 4.3.2/4.3.3): merging via Algorithm-1 optimization-
    direction attention; expansion peels the worst-feedback 20% of a cluster
    into a new cluster seeded by transfer from the old center, whose members
    do head-only fine-tuning until the next merge.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import tree_flat_vector, tree_lerp, tree_unflatten_vector
from repro.kernels import ops as K

PyTree = Any


@dataclasses.dataclass
class Cluster:
    cluster_id: int
    center: PyTree
    version: int = 0  # bumped on every aggregation into this cluster
    members: set = dataclasses.field(default_factory=set)
    partial_finetune: set = dataclasses.field(default_factory=set)  # expansion mode clients
    pf_round: int = -1  # refine round in which partial_finetune was imposed
    last_broadcast_version: int = 0
    last_broadcast_center: PyTree | None = None

    @property
    def size(self) -> int:
        return len(self.members)


class DynamicClustering:
    """Server-side cluster registry with incremental init + refinement."""

    def __init__(self, num_initial: int, mix_rate: float = 0.5, hm: float = 2.0):
        self.num_initial = num_initial
        self.mix_rate = mix_rate
        self.hm = hm  # merge trigger: merge when count >= hm * num_initial
        self.clusters: dict[int, Cluster] = {}
        self._next_id = 0
        self.assignment: dict[Any, int] = {}
        self.merges = 0
        self.expansions = 0
        self.peel_counts: dict[Any, int] = {}  # anti-churn: cap per-client peels
        self._last_expand_round: dict[int, int] = {}

    # ------------------------------------------------------------------ init
    def _new_cluster(self, center: PyTree) -> Cluster:
        c = Cluster(cluster_id=self._next_id, center=center)
        c.last_broadcast_center = center
        self.clusters[self._next_id] = c
        self._next_id += 1
        return c

    # -------------------------------------------------------------- assign
    def assign(self, client_id, update: PyTree, switch_margin: float = 0.1) -> tuple[int, bool]:
        """On-arrival assignment (Eq. 1). Returns (cluster_id, is_new_cluster).

        ``switch_margin`` adds hysteresis: a client only leaves its current
        cluster when another center is at least that much (relatively) closer.
        Without it, aggregated centers drift toward the global parameter mean
        and sweep every client into one blob (centroid attraction) — the
        paper's refinement loop then thrashes expand/merge to undo it.
        """
        prev = self.assignment.get(client_id)
        if prev is not None and client_id in self.clusters[prev].partial_finetune:
            return prev, False  # expansion members stay put until next merge
        if len(self.clusters) < self.num_initial:
            c = self._new_cluster(update)
            self._move(client_id, c.cluster_id)
            return c.cluster_id, True
        cids = sorted(self.clusters)
        u = tree_flat_vector(update)
        centers = jnp.stack([tree_flat_vector(self.clusters[c].center) for c in cids])
        dists = np.asarray(K.l1_distance(u, centers))
        cid = cids[int(np.argmin(dists))]
        if prev is not None and prev in self.clusters and prev != cid:
            d_prev = dists[cids.index(prev)]
            if dists[cids.index(cid)] > (1.0 - switch_margin) * d_prev:
                cid = prev  # not decisively closer: stay
        self._move(client_id, cid)
        return cid, False

    def _move(self, client_id, cid: int) -> None:
        prev = self.assignment.get(client_id)
        if prev is not None and prev in self.clusters:
            self.clusters[prev].members.discard(client_id)
            self.clusters[prev].partial_finetune.discard(client_id)
        self.clusters[cid].members.add(client_id)
        self.assignment[client_id] = cid

    # ----------------------------------------------------------- aggregate
    def aggregate(self, cid: int, update: PyTree, weight: float | None = None) -> None:
        """Asynchronous in-cluster aggregation: v_c <- (1-b) v_c + b u.

        EchoPFL deliberately does NOT decay b by staleness — slow devices'
        knowledge is preserved (Challenge #2); broadcast handles staleness.
        """
        c = self.clusters[cid]
        b = self.mix_rate if weight is None else weight
        c.center = tree_lerp(c.center, update, b)
        c.version += 1

    # -------------------------------------------------------------- merging
    def should_merge(self) -> bool:
        # hm * C is the *maximized* cluster count (Sec. 7.4.4): merge only
        # when it is exceeded, so the system can stably hold hm*C clusters.
        return len(self.clusters) > self.hm * self.num_initial

    def merge_pair(
        self,
        cid_a: int,
        cid_b: int,
        local_train_fn: Callable[[PyTree], PyTree],
    ) -> int:
        """Algorithm 1: attention-weighted, training-free merge. The larger
        cluster's center is the main model; ``local_train_fn`` performs the
        one local training pass that yields the posterior direction."""
        a, b = self.clusters[cid_a], self.clusters[cid_b]
        main, aux = (a, b) if a.size >= b.size else (b, a)
        v_m = tree_flat_vector(main.center)
        v_aux = tree_flat_vector(aux.center)
        v_trained = tree_flat_vector(local_train_fn(main.center))
        merged_vec = K.merge_attention(v_m, v_aux, v_trained)
        merged = tree_unflatten_vector(merged_vec, main.center)

        main.center = merged
        main.version += 1
        for client in list(aux.members):
            self._move(client, main.cluster_id)
        main.partial_finetune.clear()  # merge lifts the partial-finetune restriction
        del self.clusters[aux.cluster_id]
        self.merges += 1
        return main.cluster_id

    def nearest_pair(self, min_version: int = 2, close_frac: float | None = 0.5) -> tuple[int, int] | None:
        """Closest pair of centers by L1 — the merge candidates.

        Freshly-expanded clusters (version < min_version) are exempt while
        any mature pair exists: an expansion child starts at L1 = 0 from its
        parent and would otherwise be merged back before differentiating.

        A pair only qualifies when its distance is below ``close_frac`` of
        the median inter-center distance: merging is for *redundant*
        clusters, and folding two genuinely distinct centers just because
        capacity was reached re-creates the blob that expansion undid."""
        cids = sorted(self.clusters)
        mature = [c for c in cids if self.clusters[c].version >= min_version]
        if len(mature) >= 2:
            cids = mature
        if len(cids) < 2:
            return None
        vecs = jnp.stack([tree_flat_vector(self.clusters[c].center) for c in cids])
        dmat = np.zeros((len(cids), len(cids)))
        for i in range(len(cids)):
            dmat[i] = np.asarray(K.l1_distance(vecs[i], vecs))
        off = dmat[~np.eye(len(cids), dtype=bool)]
        median = float(np.median(off))
        np.fill_diagonal(dmat, np.inf)
        i, j = np.unravel_index(np.argmin(dmat), dmat.shape)
        if close_frac is not None and len(cids) > 2 and dmat[i, j] > close_frac * median:
            return None  # nothing redundant enough to fold
        return (cids[i], cids[j])

    # ------------------------------------------------------- reassignment
    def reassign_poor_fits(
        self, feedbacks: dict[int, dict[Any, float]], uploads: dict[Any, PyTree]
    ) -> int:
        """Feedback-corrective reassignment: a member whose feedback is poor
        may simply belong to *another existing* cluster (initial assignment
        is fast but errorful — Sec. 4.2.2). Before spawning new clusters,
        move such members to a decisively closer center, bypassing the
        assignment hysteresis. Returns the number of moves."""
        if len(self.clusters) < 2:
            return 0
        cids = sorted(self.clusters)
        centers = jnp.stack([tree_flat_vector(self.clusters[c].center) for c in cids])
        moves = 0
        for cid, fb in feedbacks.items():
            if cid not in self.clusters or len(fb) < 2:
                continue
            med = float(np.median(list(fb.values())))
            for m, g in fb.items():
                if g <= 2.0 * (med + 1e-12) or m not in uploads:
                    continue
                if m in self.clusters[cid].partial_finetune:
                    continue
                u = tree_flat_vector(uploads[m])
                d = np.asarray(K.l1_distance(u, centers))
                best = cids[int(np.argmin(d))]
                if best != cid and d[cids.index(best)] < 0.9 * d[cids.index(cid)]:
                    self._move(m, best)
                    moves += 1
        return moves

    # ------------------------------------------------------------ expansion
    def expand(
        self,
        cid: int,
        feedbacks: dict[Any, float],
        frac: float = 0.2,
        uploads: dict[Any, PyTree] | None = None,
        refine_round: int = 0,
    ) -> int | None:
        """Sec. 4.3.3: clients whose feedback ranks in the worst ``frac`` of
        their cluster split into a new cluster and enter head-only
        fine-tuning mode until the next merging refinement.

        The child center realizes the paper's "transfer learning upon the
        original cluster": it starts from the mean of the peeled members'
        own uploads — which *are* the original center fine-tuned on the
        drifted local data — so the new cluster is immediately separable
        from its parent instead of being reabsorbed at the next merge."""
        c = self.clusters[cid]
        if self._last_expand_round.get(cid, -10) >= refine_round - 1:
            return None  # cooldown: let the last split differentiate first
        members = [m for m in c.members if m in feedbacks]
        if len(members) < 3:
            return None
        ranked = sorted(members, key=lambda m: feedbacks[m])  # ascending: low = good fit
        n_bad = max(1, int(len(ranked) * frac))
        median = feedbacks[ranked[len(ranked) // 2]]
        worst = feedbacks[ranked[-1]]
        if worst <= 1e-9 or worst < 2.0 * (median + 1e-12):
            return None  # cluster fits its members uniformly — nothing to split
        # peel the worst-20%, but only members that are individually poor
        # fits and not serial peel victims (inherent outliers stay put)
        bad = [
            m for m in ranked[-n_bad:]
            if feedbacks[m] > 1.5 * (median + 1e-12) and self.peel_counts.get(m, 0) < 3
        ]
        if not bad:
            return None
        seeds = [uploads[m] for m in bad if uploads and m in uploads]
        if seeds:
            seed_center = seeds[0]
            for i, s in enumerate(seeds[1:], start=2):
                seed_center = tree_lerp(seed_center, s, 1.0 / i)  # running mean
        else:
            seed_center = c.center
        new = self._new_cluster(seed_center)
        for client in bad:
            self._move(client, new.cluster_id)
            new.partial_finetune.add(client)
            self.peel_counts[client] = self.peel_counts.get(client, 0) + 1
        new.pf_round = refine_round
        self._last_expand_round[cid] = refine_round
        self._last_expand_round[new.cluster_id] = refine_round
        self.expansions += 1
        return new.cluster_id

    # ------------------------------------------------------------- helpers
    def membership_matrix(self, client_ids: list) -> np.ndarray:
        """Boolean collaboration matrix (Fig. 11): M[i, j] = same cluster."""
        n = len(client_ids)
        out = np.zeros((n, n), bool)
        for i, a in enumerate(client_ids):
            for j, b in enumerate(client_ids):
                out[i, j] = (
                    self.assignment.get(a) is not None
                    and self.assignment.get(a) == self.assignment.get(b)
                )
        return out
