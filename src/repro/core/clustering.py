"""Data-aware dynamic client clustering (paper Sec. 4).

Three mechanisms:
  * on-arrival initial assignment (Sec. 4.2): the first C arrivals seed the
    centers; later arrivals go to the nearest center by L1 parameter
    distance (Eq. 1) — computed by the Pallas streaming kernel on TPU.
  * feedback (Sec. 4.3.1): chi-squared(F_pred, F_true) x Var(S_soft)
    (Eq. 2/3) de-confounds clustering error from training stage.
  * refinement (Sec. 4.3.2/4.3.3): merging via Algorithm-1 optimization-
    direction attention; expansion peels the worst-feedback 20% of a cluster
    into a new cluster seeded by transfer from the old center, whose members
    do head-only fine-tuning until the next merge.

Two storage backends, selected by ``REPRO_PLANE`` (or the ``backend``
argument): ``plane`` (default) keeps every center and broadcast anchor as a
row of a device-resident :class:`~repro.core.plane.ParameterPlane`, so the
hot path — assignment distances, the mixed-rate blend, merge candidate
search — runs on stacked flat matrices with no per-upload pytree
flattening; ``pytree`` is the original per-cluster-pytree path, kept
bit-compatible for parity testing and as the benchmark baseline. Both
backends apply identical fp32 arithmetic, so cluster assignments match
exactly.

The plane backend can additionally shard its row store over a device mesh
(``REPRO_PLANE_MESH`` knob, or an explicit ``mesh`` argument): the batched
kernels then run per row-shard with cross-shard reductions only at the
argmin/segment-sum points (see kernels/plane_sharded.py). Per-row
arithmetic is unchanged, so sharded and single-device planes take
identical assignment/merge decisions on the same upload stream.
"""
from __future__ import annotations

import os
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import tree_flat_vector, tree_lerp, tree_unflatten_vector
from repro.core.plane import ParameterPlane
from repro.kernels import ops as K

PyTree = Any


def default_backend() -> str:
    return os.environ.get("REPRO_PLANE", "plane").lower()


class Cluster:
    """One cluster branch. ``center`` and ``last_broadcast_center`` are live
    pytree views; in plane mode they materialize on demand from plane rows
    (cached until the row changes), so the matrices stay device-resident
    and pytrees only exist at protocol boundaries."""

    def __init__(
        self,
        cluster_id: int,
        center: PyTree | None = None,
        *,
        plane: ParameterPlane | None = None,
        row: int | None = None,
        bcast_row: int | None = None,
    ):
        self.cluster_id = cluster_id
        self.version = 0  # bumped on every aggregation into this cluster
        self.members: set = set()
        self.partial_finetune: set = set()  # expansion mode clients
        self.pf_round = -1  # refine round in which partial_finetune was imposed
        self.last_broadcast_version = 0
        self._plane = plane
        self._row = row
        self._bcast_row = bcast_row
        self._center_cache: PyTree | None = None
        self._bcast_cache: PyTree | None = None
        self._center_tree: PyTree | None = center if plane is None else None
        self._bcast_tree: PyTree | None = None
        # last-known-good snapshot ring (ingest-guard rollback): plane rows
        # or pytrees, written at broadcast time, consumed by rollback()
        self._snap_rows: list[int] | None = None
        self._snap_trees: list[PyTree | None] | None = None
        self._snap_cursor = 0
        self._snap_count = 0

    @property
    def size(self) -> int:
        return len(self.members)

    # --------------------------------------------------------- pytree views
    @property
    def center(self) -> PyTree:
        if self._plane is None:
            return self._center_tree
        if self._center_cache is None:
            self._center_cache = self._plane.to_pytree(self._row)
        return self._center_cache

    @center.setter
    def center(self, value: PyTree) -> None:
        if self._plane is None:
            self._center_tree = value
        else:
            self._plane.write(self._row, value)
            self._center_cache = None

    @property
    def last_broadcast_center(self) -> PyTree:
        if self._plane is None:
            return self._bcast_tree
        if self._bcast_cache is None:
            self._bcast_cache = self._plane.to_pytree(self._bcast_row)
        return self._bcast_cache

    @last_broadcast_center.setter
    def last_broadcast_center(self, value: PyTree) -> None:
        if self._plane is None:
            self._bcast_tree = value
        else:
            self._plane.write(self._bcast_row, value)
            self._bcast_cache = None

    # ----------------------------------------------------- plane-mode views
    @property
    def center_vec(self):
        """Flat center vector (plane mode): a device row, no tree traversal."""
        return self._plane.row(self._row)

    @property
    def broadcast_vec(self):
        return self._plane.row(self._bcast_row)

    def set_center_vec(self, vec) -> None:
        self._plane.write(self._row, vec)
        self._center_cache = None

    def snapshot_broadcast(self) -> None:
        """Record the current center as the broadcast anchor (row copy in
        plane mode — the center pytree is never materialized for this).
        With a snapshot ring attached the broadcast moment also files the
        center as a last-known-good rollback point: a center only reaches
        here after passing the guard's post-blend check, so the ring holds
        exactly the states the defense layer is willing to return to."""
        if self._plane is None:
            self._bcast_tree = self._center_tree
        else:
            self._plane.copy_row(self._row, self._bcast_row)
            self._bcast_cache = None
        self._push_snapshot()

    # ------------------------------------------------- guard snapshot ring
    def ensure_snapshot_ring(self, depth: int) -> None:
        """Allocate the last-known-good ring (idempotent; guard attach may
        retrofit rings onto clusters restored from a checkpoint)."""
        if depth <= 0 or self._snap_rows is not None or self._snap_trees is not None:
            return
        if self._plane is not None:
            self._snap_rows = [self._plane.alloc() for _ in range(depth)]
        else:
            self._snap_trees = [None] * depth
        self._snap_cursor = 0
        self._snap_count = 0

    def _push_snapshot(self) -> None:
        ring = self._snap_rows if self._plane is not None else self._snap_trees
        if ring is None:
            return
        if self._plane is not None:
            self._plane.copy_row(self._row, self._snap_rows[self._snap_cursor])
        else:
            self._snap_trees[self._snap_cursor] = self._center_tree
        self._snap_cursor = (self._snap_cursor + 1) % len(ring)
        self._snap_count = min(self._snap_count + 1, len(ring))

    def rollback(self) -> bool:
        """Restore the center from the newest *finite* ring entry (newest
        to oldest, then the broadcast anchor as the final fallback — every
        cluster has one from birth, so late detection can always recover
        unless every recorded state is itself corrupt). Returns whether a
        restore happened; the caller bumps the version, records the event
        on the CI branch, and re-broadcasts on demand."""
        candidates: list[Any] = []
        ring = self._snap_rows if self._plane is not None else self._snap_trees
        if ring is not None and self._snap_count:
            n = len(ring)
            for back in range(1, self._snap_count + 1):
                candidates.append(ring[(self._snap_cursor - back) % n])
        candidates.append(self._bcast_row if self._plane is not None else self._bcast_tree)
        for cand in candidates:
            if self._plane is not None:
                if not bool(np.isfinite(np.asarray(self._plane.row(cand))).all()):
                    continue  # this snapshot is itself corrupt: go older
                self._plane.copy_row(cand, self._row)
                self._center_cache = None
            else:
                if cand is None or not bool(
                    np.isfinite(np.asarray(tree_flat_vector(cand))).all()
                ):
                    continue
                self._center_tree = cand
            return True
        return False

    def release(self) -> None:
        """Return this cluster's plane rows to the free list."""
        if self._plane is not None:
            self._plane.free(self._row)
            self._plane.free(self._bcast_row)
            for r in self._snap_rows or ():
                self._plane.free(r)


class DynamicClustering:
    """Server-side cluster registry with incremental init + refinement."""

    def __init__(
        self,
        num_initial: int,
        mix_rate: float = 0.5,
        hm: float = 2.0,
        backend: str | None = None,
        mesh: Any | None = None,
    ):
        self.num_initial = num_initial
        self.mix_rate = mix_rate
        self.hm = hm  # merge trigger: merge when count >= hm * num_initial
        self.backend = (backend or default_backend()).lower()
        if self.backend not in ("plane", "pytree"):
            raise ValueError(f"REPRO_PLANE backend must be plane|pytree, got {self.backend}")
        # mesh=None defers to the REPRO_PLANE_MESH env knob; mesh=False
        # forces the single-device plane even when the knob is set (the
        # benchmark baseline must not silently go sharded under ci.sh env)
        if mesh is False:
            mesh = None
        elif mesh is None and self.backend == "plane":
            from repro.launch.mesh import plane_mesh_from_env

            mesh = plane_mesh_from_env()  # default None: single-device plane
        self.mesh = mesh if self.backend == "plane" else None
        # Below this many batched rows the collectives cost more than they
        # save and one device runs the launch faster — the row *store* stays
        # sharded either way (that is the memory win); only compute
        # placement adapts. 0 forces sharded compute (parity tests).
        self.mesh_min_rows = int(os.environ.get("REPRO_PLANE_MESH_MIN_ROWS", "128"))
        self.plane: ParameterPlane | None = None  # built from the first center's structure
        # >0 when an ingest guard is attached: every cluster carries that
        # many last-known-good snapshot rows for center rollback. 0 (the
        # default) allocates nothing — guard-off pays nothing.
        self.snapshot_ring = 0
        self.clusters: dict[int, Cluster] = {}
        self._next_id = 0
        self.assignment: dict[Any, int] = {}
        self.merges = 0
        self.expansions = 0
        self.peel_counts: dict[Any, int] = {}  # anti-churn: cap per-client peels
        self._last_expand_round: dict[int, int] = {}
        # assign-time flatten + fused blend, reused by the same upload's
        # aggregate call: (update object, argmin cluster, u vec, blended vec,
        # center version the blend was computed from). The update itself is
        # held (not its id()) so a recycled object address can never alias a
        # stale cache entry.
        self._pending: tuple[Any, int | None, Any, Any, int] | None = None

    # ------------------------------------------------------------------ init
    def _ensure_plane(self, template: PyTree) -> None:
        if self.backend == "plane" and self.plane is None:
            self.plane = ParameterPlane(
                template, capacity=max(8, 4 * self.num_initial), mesh=self.mesh
            )

    def _kernel_mesh_kwargs(self, nrows: int) -> dict:
        """Static mesh kwargs for a batched kernel launch over ``nrows``
        sharded rows. Empty when the plane is unsharded — or when the batch
        is too small to amortize the cross-shard collectives (see
        ``mesh_min_rows``) — so the single-device dispatch stays untouched
        and a sharded plane is never slower than an unsharded one on small
        fleets."""
        if self.plane is None or self.plane.mesh is None or nrows < self.mesh_min_rows:
            return {}
        return {
            "mesh": self.plane.mesh,
            "axis": self.plane.row_axis,
            "dim_axis": self.plane.dim_axis,
        }

    def _new_cluster(self, center: PyTree) -> Cluster:
        """``center`` may be a pytree or (plane mode) an already-flat row."""
        if self.backend == "plane":
            self._ensure_plane(center)
            row = self.plane.alloc(center)
            bcast_row = self.plane.alloc()
            self.plane.copy_row(row, bcast_row)
            c = Cluster(
                cluster_id=self._next_id, plane=self.plane, row=row, bcast_row=bcast_row
            )
        else:
            c = Cluster(cluster_id=self._next_id, center=center)
            c.last_broadcast_center = center
        c.ensure_snapshot_ring(self.snapshot_ring)
        self.clusters[self._next_id] = c
        self._next_id += 1
        return c

    def restore_cluster(self, cid: int, center: PyTree, bcast_center: PyTree) -> Cluster:
        """Rebuild one cluster from checkpointed pytrees (elastic restart)."""
        if self.backend == "plane":
            self._ensure_plane(center)
            row = self.plane.alloc(center)
            bcast_row = self.plane.alloc(bcast_center)
            c = Cluster(cluster_id=cid, plane=self.plane, row=row, bcast_row=bcast_row)
        else:
            c = Cluster(cluster_id=cid, center=center)
            c.last_broadcast_center = bcast_center
        c.ensure_snapshot_ring(self.snapshot_ring)
        self.clusters[cid] = c
        return c

    def drop_cluster(self, cid: int) -> None:
        self.clusters.pop(cid).release()

    def reset(self) -> None:
        """Drop every cluster (and return its plane rows) before a restore."""
        for c in self.clusters.values():
            c.release()
        self.clusters = {}

    # -------------------------------------------------------------- assign
    def upload_vec(self, update: PyTree):
        """Flat view of ``update`` (plane mode), reusing the assign-time
        flatten when this is the same object ``assign`` just processed."""
        p = self._pending
        if p is not None and p[0] is update:
            return p[2]
        self._ensure_plane(update)
        u = self.plane.from_pytree(update)
        self._pending = (update, None, u, None, -1)
        return u

    def assign(self, client_id, update: PyTree, switch_margin: float = 0.1) -> tuple[int, bool]:
        """On-arrival assignment (Eq. 1). Returns (cluster_id, is_new_cluster).

        ``switch_margin`` adds hysteresis: a client only leaves its current
        cluster when another center is at least that much (relatively) closer.
        Without it, aggregated centers drift toward the global parameter mean
        and sweep every client into one blob (centroid attraction) — the
        paper's refinement loop then thrashes expand/merge to undo it.
        """
        prev = self.assignment.get(client_id)
        if prev is not None and client_id in self.clusters[prev].partial_finetune:
            return prev, False  # expansion members stay put until next merge
        if self.backend == "plane":
            return self._assign_plane(client_id, update, switch_margin, prev)
        if len(self.clusters) < self.num_initial:
            c = self._new_cluster(update)
            self._move(client_id, c.cluster_id)
            return c.cluster_id, True
        cids = sorted(self.clusters)
        u = tree_flat_vector(update)
        centers = jnp.stack([tree_flat_vector(self.clusters[c].center) for c in cids])
        dists = np.asarray(K.l1_distance(u, centers))
        cid = cids[int(np.argmin(dists))]
        if prev is not None and prev in self.clusters and prev != cid:
            d_prev = dists[cids.index(prev)]
            if dists[cids.index(cid)] > (1.0 - switch_margin) * d_prev:
                cid = prev  # not decisively closer: stay
        self._move(client_id, cid)
        return cid, False

    def _assign_plane(self, client_id, update, switch_margin, prev) -> tuple[int, bool]:
        """Plane hot path: one flatten, one row gather, one fused kernel.

        ``assign_and_lerp`` returns the distances, the argmin, *and* the
        mixed-rate blend against the winning center — if the upcoming
        ``aggregate`` targets that same cluster (the common case), the
        center update is already computed and is written back as a single
        staged row."""
        self._ensure_plane(update)
        u = self.plane.from_pytree(update)
        if len(self.clusters) < self.num_initial:
            self._pending = (update, None, u, None, -1)
            c = self._new_cluster(u)
            self._move(client_id, c.cluster_id)
            return c.cluster_id, True
        cids = sorted(self.clusters)
        kw = self._kernel_mesh_kwargs(len(cids))
        centers = self.plane.rows([self.clusters[c]._row for c in cids], on_mesh=bool(kw))
        dists_d, _amin, blended = K.assign_and_lerp(u, centers, self.mix_rate, **kw)
        dists = np.asarray(dists_d)  # one host sync; argmin re-read from it
        cid = cids[int(np.argmin(dists))]
        # the blend is only valid against the center version it was computed
        # from; aggregate() re-checks under the branch write lock
        self._pending = (update, cid, u, blended, self.clusters[cid].version)
        if prev is not None and prev in self.clusters and prev != cid:
            d_prev = dists[cids.index(prev)]
            if dists[cids.index(cid)] > (1.0 - switch_margin) * d_prev:
                cid = prev  # not decisively closer: stay
        self._move(client_id, cid)
        return cid, False

    def _move(self, client_id, cid: int) -> None:
        prev = self.assignment.get(client_id)
        if prev is not None and prev in self.clusters:
            self.clusters[prev].members.discard(client_id)
            self.clusters[prev].partial_finetune.discard(client_id)
        self.clusters[cid].members.add(client_id)
        self.assignment[client_id] = cid

    # ----------------------------------------------------------- aggregate
    def aggregate(self, cid: int, update: PyTree, weight: float | None = None) -> None:
        """Asynchronous in-cluster aggregation: v_c <- (1-b) v_c + b u.

        EchoPFL deliberately does NOT decay b by staleness — slow devices'
        knowledge is preserved (Challenge #2); broadcast handles staleness.
        """
        c = self.clusters[cid]
        b = self.mix_rate if weight is None else weight
        if self.backend == "plane":
            p = self._pending
            # the fused blend only applies if the center is still at the
            # version assign saw — a concurrent push (this method runs under
            # the branch write lock) or an intervening merge falls back to a
            # live lerp so no aggregation is ever overwritten
            if (
                p is not None and p[0] is update and p[1] == cid
                and weight is None and c.version == p[4]
            ):
                c.set_center_vec(p[3])  # fused assign+lerp result: free update
            else:
                u = p[2] if p is not None and p[0] is update else self.upload_vec(update)
                self.plane.lerp_row(c._row, u, b)
                c._center_cache = None
            self._pending = None
        else:
            c.center = tree_lerp(c.center, update, b)
        c.version += 1

    # -------------------------------------------------------------- merging
    def should_merge(self) -> bool:
        # hm * C is the *maximized* cluster count (Sec. 7.4.4): merge only
        # when it is exceeded, so the system can stably hold hm*C clusters.
        return len(self.clusters) > self.hm * self.num_initial

    def merge_pair(
        self,
        cid_a: int,
        cid_b: int,
        local_train_fn: Callable[[PyTree], PyTree],
    ) -> int:
        """Algorithm 1: attention-weighted, training-free merge. The larger
        cluster's center is the main model; ``local_train_fn`` performs the
        one local training pass that yields the posterior direction."""
        a, b = self.clusters[cid_a], self.clusters[cid_b]
        main, aux = (a, b) if a.size >= b.size else (b, a)
        if self.backend == "plane":
            v_m = self.plane.row(main._row)
            v_aux = self.plane.row(aux._row)
            v_trained = self.plane.from_pytree(local_train_fn(main.center))
            main.set_center_vec(K.merge_attention(v_m, v_aux, v_trained))
        else:
            v_m = tree_flat_vector(main.center)
            v_aux = tree_flat_vector(aux.center)
            v_trained = tree_flat_vector(local_train_fn(main.center))
            merged_vec = K.merge_attention(v_m, v_aux, v_trained)
            main.center = tree_unflatten_vector(merged_vec, main.center)

        main.version += 1
        for client in list(aux.members):
            self._move(client, main.cluster_id)
        main.partial_finetune.clear()  # merge lifts the partial-finetune restriction
        self.drop_cluster(aux.cluster_id)
        self.merges += 1
        return main.cluster_id

    def nearest_pair(self, min_version: int = 2, close_frac: float | None = 0.5) -> tuple[int, int] | None:
        """Closest pair of centers by L1 — the merge candidates.

        Freshly-expanded clusters (version < min_version) are exempt while
        any mature pair exists: an expansion child starts at L1 = 0 from its
        parent and would otherwise be merged back before differentiating.

        A pair only qualifies when its distance is below ``close_frac`` of
        the median inter-center distance: merging is for *redundant*
        clusters, and folding two genuinely distinct centers just because
        capacity was reached re-creates the blob that expansion undid."""
        cids = sorted(self.clusters)
        mature = [c for c in cids if self.clusters[c].version >= min_version]
        if len(mature) >= 2:
            cids = mature
        if len(cids) < 2:
            return None
        if self.backend == "plane":
            kw = self._kernel_mesh_kwargs(len(cids))
            vecs = self.plane.rows([self.clusters[c]._row for c in cids], on_mesh=bool(kw))
            dmat = np.asarray(K.l1_distance_pairwise(vecs, vecs, **kw))
        else:
            vecs = jnp.stack([tree_flat_vector(self.clusters[c].center) for c in cids])
            dmat = np.zeros((len(cids), len(cids)))
            for i in range(len(cids)):
                dmat[i] = np.asarray(K.l1_distance(vecs[i], vecs))
        off = dmat[~np.eye(len(cids), dtype=bool)]
        median = float(np.median(off))
        dmat = dmat.copy()
        np.fill_diagonal(dmat, np.inf)
        i, j = np.unravel_index(np.argmin(dmat), dmat.shape)
        if close_frac is not None and len(cids) > 2 and dmat[i, j] > close_frac * median:
            return None  # nothing redundant enough to fold
        return (cids[i], cids[j])

    # ------------------------------------------------------- reassignment
    def reassign_poor_fits(
        self, feedbacks: dict[int, dict[Any, float]], uploads: dict[Any, Any]
    ) -> int:
        """Feedback-corrective reassignment: a member whose feedback is poor
        may simply belong to *another existing* cluster (initial assignment
        is fast but errorful — Sec. 4.2.2). Before spawning new clusters,
        move such members to a decisively closer center, bypassing the
        assignment hysteresis. Returns the number of moves.

        ``uploads`` maps client -> last upload: pytrees in pytree mode,
        plane row indices in plane mode (where all flagged members probe
        every center in a single pairwise launch).
        """
        if len(self.clusters) < 2:
            return 0
        cids = sorted(self.clusters)
        flagged: list[tuple[Any, int]] = []
        for cid, fb in feedbacks.items():
            if cid not in self.clusters or len(fb) < 2:
                continue
            med = float(np.median(list(fb.values())))
            for m, g in fb.items():
                if g <= 2.0 * (med + 1e-12) or m not in uploads:
                    continue
                if m in self.clusters[cid].partial_finetune:
                    continue
                flagged.append((m, cid))
        if not flagged:
            return 0
        moves = 0
        if self.backend == "plane":
            kw = self._kernel_mesh_kwargs(len(flagged))
            U = self._upload_matrix(uploads, [m for m, _ in flagged], on_mesh="shard" if kw else False)
            centers = self.plane.rows(
                [self.clusters[c]._row for c in cids], on_mesh=bool(kw)
            )
            D = np.asarray(K.l1_distance_pairwise(U, centers, **kw))
            for (m, cid), d in zip(flagged, D):
                best = cids[int(np.argmin(d))]
                if best != cid and d[cids.index(best)] < 0.9 * d[cids.index(cid)]:
                    self._move(m, best)
                    moves += 1
            return moves
        centers = jnp.stack([tree_flat_vector(self.clusters[c].center) for c in cids])
        for m, cid in flagged:
            u = tree_flat_vector(uploads[m])
            d = np.asarray(K.l1_distance(u, centers))
            best = cids[int(np.argmin(d))]
            if best != cid and d[cids.index(best)] < 0.9 * d[cids.index(cid)]:
                self._move(m, best)
                moves += 1
        return moves

    # ------------------------------------------------------------ expansion
    def expand(
        self,
        cid: int,
        feedbacks: dict[Any, float],
        frac: float = 0.2,
        uploads: dict[Any, Any] | None = None,
        refine_round: int = 0,
    ) -> int | None:
        """Sec. 4.3.3: clients whose feedback ranks in the worst ``frac`` of
        their cluster split into a new cluster and enter head-only
        fine-tuning mode until the next merging refinement.

        The child center realizes the paper's "transfer learning upon the
        original cluster": it starts from the mean of the peeled members'
        own uploads — which *are* the original center fine-tuned on the
        drifted local data — so the new cluster is immediately separable
        from its parent instead of being reabsorbed at the next merge.

        ``uploads`` holds pytrees in pytree mode, plane rows in plane mode.
        """
        c = self.clusters[cid]
        if self._last_expand_round.get(cid, -10) >= refine_round - 1:
            return None  # cooldown: let the last split differentiate first
        members = [m for m in c.members if m in feedbacks]
        if len(members) < 3:
            return None
        ranked = sorted(members, key=lambda m: feedbacks[m])  # ascending: low = good fit
        n_bad = max(1, int(len(ranked) * frac))
        median = feedbacks[ranked[len(ranked) // 2]]
        worst = feedbacks[ranked[-1]]
        if worst <= 1e-9 or worst < 2.0 * (median + 1e-12):
            return None  # cluster fits its members uniformly — nothing to split
        # peel the worst-20%, but only members that are individually poor
        # fits and not serial peel victims (inherent outliers stay put)
        bad = [
            m for m in ranked[-n_bad:]
            if feedbacks[m] > 1.5 * (median + 1e-12) and self.peel_counts.get(m, 0) < 3
        ]
        if not bad:
            return None
        if self.backend == "plane":
            have = [m for m in bad if uploads and m in uploads]
            if have:
                vecs = self._upload_matrix(uploads, have)
                seed_center = vecs[0]
                for i in range(1, len(have)):  # same running mean as pytree path
                    t = 1.0 / (i + 1)
                    seed_center = (1.0 - t) * seed_center + t * vecs[i]
            else:
                seed_center = self.plane.row(c._row)
        else:
            seeds = [uploads[m] for m in bad if uploads and m in uploads]
            if seeds:
                seed_center = seeds[0]
                for i, s in enumerate(seeds[1:], start=2):
                    seed_center = tree_lerp(seed_center, s, 1.0 / i)  # running mean
            else:
                seed_center = c.center
        new = self._new_cluster(seed_center)
        for client in bad:
            self._move(client, new.cluster_id)
            new.partial_finetune.add(client)
            self.peel_counts[client] = self.peel_counts.get(client, 0) + 1
        new.pf_round = refine_round
        self._last_expand_round[cid] = refine_round
        self._last_expand_round[new.cluster_id] = refine_round
        self.expansions += 1
        return new.cluster_id

    # ------------------------------------------------------------- helpers
    def _upload_matrix(self, uploads: dict, keys: list, on_mesh: bool | str = False) -> Any:
        """Stack clients' last uploads into (len(keys), dim). Values may be
        plane row indices (the server's plane-mode store), flat vectors, or
        pytrees (direct API use / tests) — rows take the one-gather path.
        ``on_mesh="shard"`` serves a fleet-scale sweep (reassign/dissolve
        over many upload rows) sharded over the plane mesh's row axis, so a
        mesh-committed plane never funnels the batch through one device."""
        vals = [uploads[m] for m in keys]
        if vals and all(isinstance(v, (int, np.integer)) for v in vals):
            # one-shot row set (flagged members change every sweep): the
            # uncached gather, so the hot cached views survive refinement
            return self.plane.take(vals, on_mesh=on_mesh)
        return jnp.stack([self.plane.as_vec(v) for v in vals])

    def membership_matrix(self, client_ids: list) -> np.ndarray:
        """Boolean collaboration matrix (Fig. 11): M[i, j] = same cluster."""
        n = len(client_ids)
        out = np.zeros((n, n), bool)
        for i, a in enumerate(client_ids):
            for j, b in enumerate(client_ids):
                out[i, j] = (
                    self.assignment.get(a) is not None
                    and self.assignment.get(a) == self.assignment.get(b)
                )
        return out
