"""Simulated mobile client: local training, feedback computation, and the
device latency model. In the threaded CI mode the same object runs inside a
worker thread; in the event-driven simulator its timing methods feed the
virtual clock."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import ClientDataset
from repro.models import mlp

PyTree = Any


@dataclasses.dataclass
class SimClient:
    client_id: int
    data: ClientDataset
    num_classes: int
    device_class: str
    round_time_fn: Any  # () -> seconds of local compute
    local_epochs: int = 5
    lr: float = 0.1

    # protocol state
    model: PyTree | None = None
    base_version: int = 0
    cluster_id: int | None = None
    partial_finetune: bool = False

    def local_train(self, params: PyTree | None = None) -> tuple[PyTree, Any]:
        """One local training round. The returned loss is a *device scalar*
        (no forced host sync); call ``float()`` on it only if you actually
        need the value on the host."""
        p = params if params is not None else self.model
        x = jnp.asarray(self.data.x_train)
        y = jnp.asarray(self.data.y_train)
        return mlp.local_train(
            p, x, y, epochs=self.local_epochs, lr=self.lr, head_only=self.partial_finetune
        )

    def evaluate(self, params: PyTree | None = None) -> float:
        p = params if params is not None else self.model
        if p is None:
            return 0.0
        return float(mlp.evaluate(p, jnp.asarray(self.data.x_test), jnp.asarray(self.data.y_test)))

    def feedback_inputs(self, params: PyTree) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(F_pred, F_true, S_soft) on the local training set (Eq. 2/3)."""
        f_pred, s_soft = mlp.predict_distributions(
            params, jnp.asarray(self.data.x_train), self.num_classes
        )
        f_true = self.data.label_histogram(self.num_classes)
        return np.asarray(f_pred), f_true.astype(np.float32), np.asarray(s_soft)

    def compute_time(self) -> float:
        return float(self.round_time_fn())
