"""Simulated mobile client: local training, feedback computation, and the
device latency model. In the threaded CI mode the same object runs inside a
worker thread; in the event-driven simulator its timing methods feed the
virtual clock.

The workload itself lives behind the client's
:class:`~repro.fl.tasks.PersonalizationTask` (``task`` field): ``None``
means the paper's default MLP task. The task is a constructor-time value,
not an env lookup — a client's task must match the data it was built with,
so only fleet *builders* consult ``REPRO_TASK``."""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

PyTree = Any


@dataclasses.dataclass
class SimClient:
    client_id: int
    data: Any
    num_classes: int
    device_class: str
    round_time_fn: Any  # () -> seconds of local compute
    local_epochs: int = 5
    lr: float = 0.1

    # protocol state
    model: PyTree | None = None
    base_version: int = 0
    cluster_id: int | None = None
    partial_finetune: bool = False
    task: Any = None  # PersonalizationTask; None -> the default MLP task

    def _task(self):
        if self.task is None:
            from repro.fl.tasks import MLP_TASK

            self.task = MLP_TASK
        return self.task

    def local_train(self, params: PyTree | None = None) -> tuple[PyTree, Any]:
        """One local training round. The returned loss is a *device scalar*
        (no forced host sync); call ``float()`` on it only if you actually
        need the value on the host."""
        p = params if params is not None else self.model
        return self._task().local_train(
            p, self.data, epochs=self.local_epochs, lr=self.lr,
            head_only=self.partial_finetune,
        )

    def evaluate(self, params: PyTree | None = None) -> float:
        p = params if params is not None else self.model
        if p is None:
            return 0.0
        return self._task().evaluate(p, self.data)

    def feedback_inputs(self, params: PyTree) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(F_pred, F_true, S_soft) on the local training set (Eq. 2/3)."""
        return self._task().feedback_inputs(params, self.data, self.num_classes)

    def compute_time(self) -> float:
        return float(self.round_time_fn())
