"""Staleness accounting (paper Sec. 5.1).

Staleness of an update = (cluster-model version at aggregation time) -
(version the client trained from). The paper's convergence-rate proxy is
O(sqrt(Q_max * Q_avg)) after Koloskova et al.; on-demand broadcast exists
precisely to pull Q_max down (a broadcast resets the base version of every
in-cluster client to current, so in-flight staleness stops accumulating).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class StalenessTracker:
    count: int = 0
    total: float = 0.0
    q_max: int = 0

    def record(self, staleness: int) -> None:
        if staleness < 0:
            raise ValueError(f"negative staleness {staleness}: version bookkeeping bug")
        self.count += 1
        self.total += staleness
        self.q_max = max(self.q_max, staleness)

    @property
    def q_avg(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def convergence_proxy(self) -> float:
        """O(sqrt(Q_max * Q_avg)) — lower is better. A run that never saw
        staleness (no records, or every record zero) reports exactly 0.0;
        the 1e-12 floor only guards the mixed case where one factor is zero
        by rounding, not a genuinely staleness-free run."""
        if self.count == 0 or (self.q_max == 0 and self.q_avg == 0.0):
            return 0.0
        return math.sqrt(max(self.q_max, 1e-12) * max(self.q_avg, 1e-12))

    def snapshot(self) -> dict:
        return {"q_max": self.q_max, "q_avg": self.q_avg, "n": self.count,
                "convergence_proxy": self.convergence_proxy}
