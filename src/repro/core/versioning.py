"""CI-based client-server version control (paper Sec. 6).

Clusters are *branches*; client updates are *pushes*; broadcast checks are
*pulls*. Multi-thread safety comes from a readers-writer lock per branch:
many concurrent pulls, exclusive pushes — exactly the paper's conflict-
resolution mechanism ("multi-thread and read-write locks to resolve
conflicts among personalized branches").
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

PyTree = Any


class RWLock:
    """Writer-preferring readers-writer lock."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self):
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self):
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self):
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self):
        with self._cond:
            self._writer = False
            self._cond.notify_all()


@dataclasses.dataclass
class Commit:
    version: int
    author: Any
    timestamp: float
    message: str


class Branch:
    def __init__(self, name: str, model: PyTree):
        self.name = name
        self._model = model
        self._version = 0
        self._lock = RWLock()
        self.log: list[Commit] = [Commit(0, "server", time.time(), "branch created")]

    def pull(self, have_version: int | None = None) -> tuple[PyTree, int] | None:
        """Fetch (model, version); None if caller is already current."""
        self._lock.acquire_read()
        try:
            if have_version is not None and have_version >= self._version:
                return None
            return self._model, self._version
        finally:
            self._lock.release_read()

    def push(self, author, merge_fn: Callable[[PyTree], PyTree], message: str = "") -> int:
        """Atomically apply ``merge_fn`` (e.g. async aggregation) to the head."""
        self._lock.acquire_write()
        try:
            self._model = merge_fn(self._model)
            self._version += 1
            self.log.append(Commit(self._version, author, time.time(), message))
            return self._version
        finally:
            self._lock.release_write()

    @property
    def version(self) -> int:
        self._lock.acquire_read()
        try:
            return self._version
        finally:
            self._lock.release_read()


class ModelRepo:
    """Branch registry with repo-level lock for branch create/delete/merge."""

    def __init__(self):
        self._branches: dict[str, Branch] = {}
        self._lock = threading.RLock()

    def branch(self, name: str, model: PyTree | None = None) -> Branch:
        with self._lock:
            if name not in self._branches:
                if model is None:
                    raise KeyError(f"branch {name!r} does not exist and no model given")
                self._branches[name] = Branch(name, model)
            return self._branches[name]

    def delete(self, name: str) -> None:
        with self._lock:
            self._branches.pop(name, None)

    def merge_branches(self, dst: str, src: str, merge_fn: Callable[[PyTree, PyTree], PyTree]) -> Branch:
        """Merge src into dst atomically (both write-locked via push)."""
        with self._lock:
            src_b = self._branches[src]
            dst_b = self._branches[dst]
            src_model, _ = src_b.pull()
            dst_b.push("server", lambda head: merge_fn(head, src_model), f"merge {src}")
            self.delete(src)
            return dst_b

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._branches)
