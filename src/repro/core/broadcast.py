"""In-cluster on-demand model broadcast (paper Sec. 5).

Decision rule: broadcast iff the predicted next model change exceeds the
accumulated change since the last broadcast,
    L1(v_hat^{t+1}, v^t)  >  L1(v^t, v_bcast^t).
Ground truth for training the predictor (Eq. 4):
    h = L1(v_c^{t-1}, v_bcast^{t-1}) - L1(v_c^{t-1}, v_c^t) >= 0  -> broadcast.

A small 2x128-unit vanilla RNN consumes the cluster's Top-K recent
L1-change records (K proportional to cluster size; we store change degrees,
not model weights, to save memory — Sec. 5.2.1) and emits P(broadcast).
It is pre-trained on 1200 synthetic historical states and fine-tuned online
on every realized ground truth. Predictor state follows the maintenance
rules of Sec. 5.2.2 under cluster expansion/merging.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
HIDDEN = 128
NUM_LAYERS = 2


def predictor_batch_enabled() -> bool:
    """``REPRO_PREDICTOR_BATCH`` knob: batch the per-cluster predictor
    learn/decide chains of a coalesced window into one fused launch
    (default on). ``0`` / ``off`` keeps the per-upload serial dispatches —
    the parity arm ci.sh exercises."""
    spec = os.environ.get("REPRO_PREDICTOR_BATCH", "1").strip().lower()
    return spec not in ("", "0", "off", "none", "no")


# ---------------------------------------------------------------- RNN model
def init_rnn(key: jax.Array, hidden: int = HIDDEN) -> PyTree:
    ks = jax.random.split(key, 2 * NUM_LAYERS + 1)
    params = {}
    dim_in = 1
    for layer in range(NUM_LAYERS):
        params[f"wx{layer}"] = jax.random.normal(ks[2 * layer], (dim_in, hidden)) / np.sqrt(dim_in)
        params[f"wh{layer}"] = jax.random.normal(ks[2 * layer + 1], (hidden, hidden)) / np.sqrt(hidden)
        params[f"b{layer}"] = jnp.zeros((hidden,))
        dim_in = hidden
    params["w_out"] = jax.random.normal(ks[-1], (hidden, 2)) / np.sqrt(hidden)
    params["b_out"] = jnp.zeros((2,))
    return params


@jax.jit
def rnn_logits(params: PyTree, seq: jax.Array) -> jax.Array:
    """seq: (T, 1) normalized change records -> (2,) [no-bcast, bcast] logits."""
    x = seq
    for layer in range(NUM_LAYERS):
        h0 = jnp.zeros((params[f"wh{layer}"].shape[0],))

        def step(h, x_t, l=layer):
            h_new = jnp.tanh(x_t @ params[f"wx{l}"] + h @ params[f"wh{l}"] + params[f"b{l}"])
            return h_new, h_new

        _, hs = jax.lax.scan(step, h0, x)
        x = hs
    return hs[-1] @ params["w_out"] + params["b_out"]


@jax.jit
def _rnn_sgd(params: PyTree, seq: jax.Array, label: jax.Array, lr: jax.Array) -> tuple[PyTree, jax.Array]:
    def loss_fn(p):
        logits = rnn_logits(p, seq)
        return -jax.nn.log_softmax(logits)[label]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads), loss


@jax.jit
def _rnn_want(params: PyTree, seq: jax.Array) -> jax.Array:
    """Fused forward + argmax decision: one dispatch per broadcast decision
    instead of a logits launch plus two eager argmax/compare dispatches.
    Same logits, same first-index argmax tie-breaking — bitwise-identical
    decisions to the unfused form."""
    return jnp.argmax(rnn_logits(params, seq)) == 1


# ----------------------------------------------------- batched chain bodies
# Predictors carry different Top-K window lengths (k = max(top_k, size at
# creation)), so a batched launch front-pads every sequence to one common
# length and tells the RNN where the real window starts. Holding h at zero
# for t < start makes step `start` see exactly the serial initial state, so
# every arithmetic op on valid steps consumes the same values as the
# exact-k form — the trajectory stays bitwise-identical (the padded steps
# contribute exact zeros to the scan-transposed gradient accumulation).
def _rnn_logits_masked(params: PyTree, seq: jax.Array, start: jax.Array) -> jax.Array:
    """seq: (T, 1) front-padded records; rows with t < start are padding."""
    tpos = jnp.arange(seq.shape[0])
    x = seq
    for layer in range(NUM_LAYERS):
        h0 = jnp.zeros((params[f"wh{layer}"].shape[0],))

        def step(h, inp, l=layer):
            x_t, t = inp
            h_new = jnp.tanh(x_t @ params[f"wx{l}"] + h @ params[f"wh{l}"] + params[f"b{l}"])
            h_new = jnp.where(t >= start, h_new, jnp.zeros_like(h_new))
            return h_new, h_new

        # NOTE: no scan unroll here — unrolling refuses the serial op
        # schedule (XLA fuses the unrolled bodies differently) and breaks
        # the bitwise match with rnn_logits that predictor_chain guarantees
        _, hs = jax.lax.scan(step, h0, (x, tpos))
        x = hs
    return hs[-1] @ params["w_out"] + params["b_out"]


def _rnn_sgd_masked(
    params: PyTree, seq: jax.Array, label: jax.Array, lr: jax.Array, start: jax.Array
) -> PyTree:
    def loss_fn(p):
        return -jax.nn.log_softmax(_rnn_logits_masked(p, seq, start))[label]

    _, grads = jax.value_and_grad(loss_fn)(params)
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def rnn_chain_step(params: PyTree, pre: jax.Array, post: jax.Array, label: jax.Array,
                   learn_gate: jax.Array, decide_gate: jax.Array, lr: jax.Array,
                   start: jax.Array) -> tuple[PyTree, jax.Array]:
    """One upload's predictor work: gated SGD step on the pre-observe window,
    then the gated broadcast decision on the post-observe window. The scan
    body of :func:`repro.kernels.ops.predictor_chain`.

    The gates are ``lax.cond``s, not post-hoc selects: inside a (non-vmapped)
    scan a cond stays a real conditional, so learn-only steps skip the
    decision forward, decide-only steps skip the whole SGD, and the pad
    steps the caller appends for shape bucketing cost one branch dispatch
    instead of a full RNN forward+backward. With a post-hoc ``where`` the
    packed chain paid ~2.5x the serial path's arithmetic and lost the
    batching win on CPU."""
    params = jax.lax.cond(
        learn_gate,
        lambda p: _rnn_sgd_masked(p, pre, label, lr, start),
        lambda p: p,
        params,
    )
    want = jax.lax.cond(
        decide_gate,
        lambda p: jnp.argmax(_rnn_logits_masked(p, post, start)) == 1,
        lambda p: jnp.asarray(False),
        params,
    )
    return params, want


def build_seq(records: list, k: int) -> np.ndarray:
    """Normalized (k, 1) change-record window from a records list — the
    single source of truth for both the per-predictor serial path
    (:meth:`BroadcastPredictor._seq`) and the batched window planner, which
    replays record evolution host-side and must produce bit-identical
    operands."""
    rec = records[-k:]
    rec = [0.0] * (k - len(rec)) + rec  # zero-pad (expansion reset rule)
    norm = max(max((abs(r) for r in rec), default=0.0), 1e-12)  # match pretraining
    return np.asarray(rec, np.float32)[:, None] / norm


# ------------------------------------------------------------- per-cluster
@dataclasses.dataclass
class BroadcastPredictor:
    """Per-cluster predictor state: Top-K records + RNN weights."""

    params: PyTree
    k: int = 10
    records: list = dataclasses.field(default_factory=list)  # recent L1 change degrees
    active: bool = True  # deactivated right after expansion (Sec. 5.2.2)
    scale: float = 1.0  # running normalizer for change degrees
    decisions: int = 0
    broadcasts: int = 0

    def observe(self, change: float) -> None:
        self.records.append(float(change))
        self.records = self.records[-max(self.k, 1):]
        self.scale = 0.9 * self.scale + 0.1 * max(abs(change), 1e-12)

    def _seq(self) -> np.ndarray:
        """Normalized (k, 1) change-record window, built host-side in numpy.

        This runs on every online learn AND every RNN decision — per upload
        on the server hot path — so it must not cost device dispatches. The
        previous jnp version paid three (asarray, reshape, divide) before
        the RNN launch even started. The numpy form is bitwise-identical:
        float32 array ops with a weak python-float norm divide the same way
        under NumPy 2 promotion as under jax, and the jit boundary uploads
        the 10-float array in the same dispatch as the RNN itself."""
        return build_seq(self.records, self.k)

    def decide(self, accumulated_gap: float, fallback_threshold: float = 1.0) -> bool:
        """RNN decision; when inactive (fresh expansion) never broadcast."""
        self.decisions += 1
        if not self.active:
            self.active = True  # one suppressed decision, then resume
            return False
        if len(self.records) < 2:  # cold start: rule-based fallback
            want = accumulated_gap > fallback_threshold * self.scale
        else:
            want = bool(_rnn_want(self.params, self._seq()))
        if want:
            self.broadcasts += 1
        return want

    def learn(self, label: int, lr: float = 1e-2):
        """Online fine-tune on the realized ground truth (Eq. 4). Returns
        the loss as a *device scalar* — this runs once per upload on the
        server hot path, and forcing a host readback here would stall the
        dispatch pipeline; call ``float()`` on it if you need the value."""
        self.params, loss = _rnn_sgd(self.params, self._seq(), jnp.asarray(label), jnp.asarray(lr))
        return loss


# ------------------------------------------------------------ maintenance
def predictor_for_expansion(parent: BroadcastPredictor, change_of_new_client: float) -> BroadcastPredictor:
    """Expansion rules: reset records to the new client (+zero pad), inherit
    RNN weights, deactivate broadcast (center is already fresh)."""
    child = BroadcastPredictor(params=parent.params, k=parent.k, scale=parent.scale)
    child.records = [float(change_of_new_client)]
    child.active = False
    return child


def predictor_for_merge(a: BroadcastPredictor, b: BroadcastPredictor) -> BroadcastPredictor:
    """Merge rules: resample Top-K records proportional to each side's
    record variance (prioritize larger weight changes), distill the two RNNs
    (weight-space average — the training-free analogue of Sec. 4.3.2 used
    for the predictor), and force an immediate broadcast (handled by caller).
    """
    va = float(np.var(a.records)) if len(a.records) > 1 else 0.0
    vb = float(np.var(b.records)) if len(b.records) > 1 else 0.0
    total = va + vb
    k = max(a.k, b.k)
    if total <= 0:
        n_a = min(len(a.records), k // 2)
    else:
        n_a = int(round(k * va / total))
    n_a = min(n_a, len(a.records))
    n_b = min(k - n_a, len(b.records))
    rec_a = sorted(a.records, key=abs)[-n_a:] if n_a else []
    rec_b = sorted(b.records, key=abs)[-n_b:] if n_b else []
    merged_params = jax.tree_util.tree_map(lambda x, y: 0.5 * (x + y), a.params, b.params)
    out = BroadcastPredictor(params=merged_params, k=k, scale=max(a.scale, b.scale))
    out.records = rec_a + rec_b
    return out


# -------------------------------------------------------------- pretraining
def pretrain_rnn(key: jax.Array, k: int = 10, num_states: int = 1200, lr: float = 5e-3) -> PyTree:
    """Pre-train on synthetic historical states (Sec. 5.2.1): decaying change
    sequences labeled by the paper's h() rule applied to a simulated L1 walk."""
    params = init_rnn(key)
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    for _ in range(num_states):
        decay = rng.uniform(0.6, 1.5)  # reversed one-step ratio spans (0.67, 1.67)
        base = rng.uniform(0.5, 2.0)
        noise = rng.uniform(0.02, 0.3)
        seq = base * decay ** np.arange(k) * (1 + noise * rng.standard_normal(k))
        seq = np.abs(seq)[::-1]  # oldest -> newest (one-step ratio is 1/decay)
        accumulated = float(np.sum(seq[-3:]))
        predicted_next = float(seq[-1] / decay)
        # Sec. 5.2.1 text rule: broadcast iff the predicted next model change
        # exceeds the accumulated recent change level ("broadcasts more
        # frequently given notable model changes; less frequently otherwise").
        # The 1.15 margin keeps flat/converged sequences on the "hold" side —
        # steady-state training shouldn't re-broadcast every aggregation.
        label = 1 if predicted_next > 1.15 * accumulated / 3 else 0
        scale = max(float(np.max(seq)), 1e-9)
        x = jnp.asarray(seq / scale, jnp.float32)[:, None]
        params, _ = _rnn_sgd(params, x, jnp.asarray(label), jnp.asarray(lr))
    return params
