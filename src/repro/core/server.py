"""The EchoPFL server: asynchronous PFL coordination with on-demand
broadcast (the paper's core contribution, Secs. 3-6 wired together).

Per arriving update:
  1. assign/confirm cluster (on-arrival L1 clustering, Eq. 1),
  2. record staleness (never decay/drop — Challenge #2),
  3. aggregate into the cluster branch (CI push, RW-locked),
  4. update the cluster's Top-K change records and online fine-tune the
     predictor on the realized ground truth (Eq. 4),
  5. unicast the fresh center back to the uploader (prompt CI feedback),
  6. RNN broadcast decision: maybe broadcast to the *other* in-cluster
     members (the "echo" — rides the fat downstream link),
  7. periodically: feedback-aware refinement (expand bad fits, merge when
     cluster count reaches hm x C via Algorithm 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import tree_flat_vector, tree_l1
from repro.core.broadcast import (
    BroadcastPredictor,
    build_seq,
    predictor_batch_enabled,
    predictor_for_expansion,
    predictor_for_merge,
    pretrain_rnn,
)
from repro.core.clustering import DynamicClustering
from repro.core.plane import l1_vec
from repro.core.staleness import StalenessTracker
from repro.core.versioning import ModelRepo
from repro.kernels import ops as K

PyTree = Any


@dataclasses.dataclass
class _PredictorPlan:
    """Resolved predictor work for one refinement sub-window: per-step
    broadcast outcomes and the chain launch's final RNN weights, written
    back at window end (before any refine can inherit them)."""

    wants: dict  # step index -> planned decide() outcome
    new_params: dict  # cid -> batched-chain final RNN params (device)


@dataclasses.dataclass
class Downlink:
    client_id: Any
    params: PyTree
    version: int
    cluster_id: int
    reason: str  # "unicast" | "broadcast"


class EchoPFLServer:
    name = "echopfl"
    is_synchronous = False

    def __init__(
        self,
        init_params: PyTree,
        *,
        num_initial_clusters: int = 2,
        mix_rate: float = 0.25,
        hm: float = 2.0,
        top_k: int = 10,
        refine_every: int = 20,
        feedback_fn: Callable[[Any, PyTree], tuple[np.ndarray, np.ndarray, np.ndarray]] | None = None,
        local_train_fn: Callable[[PyTree], PyTree] | None = None,
        pretrain_key: jax.Array | None = None,
        enable_clustering: bool = True,
        enable_broadcast: bool = True,
        plane_backend: str | None = None,
        plane_mesh: Any | None = None,
        seed: int = 0,
    ):
        self.init_params = init_params
        self.clustering = DynamicClustering(
            num_initial_clusters,
            mix_rate=mix_rate,
            hm=hm,
            backend=plane_backend,
            mesh=plane_mesh,
        )
        self.repo = ModelRepo()
        self.staleness = StalenessTracker()
        self.top_k = top_k
        self.refine_every = refine_every
        self.feedback_fn = feedback_fn
        # optional batched probe: called with [(member, center), ...] and
        # returns pre-stacked (F_pred, F_true, S_soft) — one launch for the
        # whole pair list. The simulator's fleet engine installs its
        # ``feedback_many`` here; when unset, pairs probe via feedback_fn.
        self.feedback_batch_fn: Callable[[list], tuple] | None = None
        # optional uplink codec (REPRO_UPLINK): attached by the simulator so
        # the per-client anchor/residual rows ride this server's checkpoints
        self.uplink_codec = None
        self._pending_uplink_state: tuple | None = None
        # optional ingest guard (REPRO_GUARD): attached by the simulator.
        # None (the default) keeps every guard hook inert — the ingest
        # launches compile without stats and no snapshot rings allocate.
        self.guard = None
        self.local_train_fn = local_train_fn
        self.enable_clustering = enable_clustering
        self.enable_broadcast = enable_broadcast
        self._uploads = 0
        self._decisions = 0  # cumulative (predictor objects are replaced on refine)
        self._rnn_broadcasts = 0
        self._refine_round = 0
        self.last_uploads: dict[Any, PyTree] = {}  # pytree mode: client -> last update
        self._upload_rows: dict[Any, int] = {}  # plane mode: client -> plane row
        self.last_cluster_feedback_mean: dict[int, float] = {}
        self._rng = np.random.default_rng(seed)
        key = pretrain_key if pretrain_key is not None else jax.random.PRNGKey(seed)
        self._rnn_init = pretrain_rnn(key) if enable_broadcast else None
        self.predictors: dict[int, BroadcastPredictor] = {}
        self.client_versions: dict[Any, tuple[int, int]] = {}  # cid -> (cluster, version)
        self.events: list[dict] = []

    # ------------------------------------------------------------ protocol
    def initial_models(self, client_ids: list) -> dict[Any, PyTree]:
        return {cid: self.init_params for cid in client_ids}

    def model_for(self, client_id) -> PyTree:
        cid = self.clustering.assignment.get(client_id)
        if cid is None:
            return self.init_params
        return self.clustering.clusters[cid].center

    def attach_uplink_codec(self, codec) -> None:
        """Adopt the simulator's uplink codec: its anchors/residuals become
        part of :meth:`state_dict`/:meth:`load_state`. A restore that ran
        BEFORE the codec existed (load_state then start the run) stashed the
        codec section; it is replayed into the codec here."""
        self.uplink_codec = codec
        if codec is not None and self._pending_uplink_state is not None:
            codec.load_state(*self._pending_uplink_state)
            self._pending_uplink_state = None

    def attach_guard(self, guard) -> None:
        """Adopt the simulator's ingest guard
        (:class:`~repro.fl.guard.IngestGuard`): enables the post-blend
        center-norm check (late poison detection) and equips every
        cluster — present and future — with a last-known-good snapshot
        ring so a detection can roll the center back and re-broadcast.
        The retrofit loop covers clusters restored from a checkpoint
        before the guard attached (kill + restore under chaos)."""
        self.guard = guard
        if guard is None:
            return
        self.clustering.snapshot_ring = guard.cfg.snapshot_ring
        for c in self.clustering.clusters.values():
            c.ensure_snapshot_ring(guard.cfg.snapshot_ring)

    def _predictor(self, cluster_id: int) -> BroadcastPredictor:
        if cluster_id not in self.predictors:
            size = self.clustering.clusters[cluster_id].size
            self.predictors[cluster_id] = BroadcastPredictor(
                params=self._rnn_init, k=max(self.top_k, size)
            )
        return self.predictors[cluster_id]

    def handle_upload(
        self, client_id, params: PyTree, base_version: int, n_samples: int, t: float
    ) -> list[Downlink]:
        self._uploads += 1
        out: list[Downlink] = []

        # 1. cluster assignment (or the single global "cluster" in ablation)
        if self.enable_clustering:
            cid, created = self.clustering.assign(client_id, params)
        else:
            if not self.clustering.clusters:
                self.clustering._new_cluster(self.init_params)
            cid, created = 0, False
            self.clustering._move(client_id, 0)
        cluster = self.clustering.clusters[cid]
        plane = self.clustering.plane
        if plane is None:
            self.last_uploads[client_id] = params
        else:
            # plane mode: the last upload lives in a plane row (staged write;
            # flushed in one scatter at the next batched read), reusing the
            # flatten `assign` already did for this same object
            row = self._upload_rows.get(client_id)
            if row is None:
                row = self._upload_rows[client_id] = plane.alloc()
            plane.write(row, self.clustering.upload_vec(params))
        # the branch head is only materialized on branch creation; in plane
        # mode it tracks the flat row (the protocol never pulls it back)
        try:
            branch = self.repo.branch(f"cluster/{cid}")
        except KeyError:
            branch = self.repo.branch(
                f"cluster/{cid}", cluster.center if plane is None else cluster.center_vec
            )

        # 2. staleness bookkeeping (all updates included, none dropped)
        base_cluster, base_ver = self.client_versions.get(client_id, (cid, 0))
        if base_cluster == cid:
            staleness = max(0, cluster.version - base_ver)
        elif base_cluster in self.clustering.clusters:
            # reassigned client: staleness is measured against the branch it
            # actually trained from, not the whole history of the new branch
            staleness = max(0, self.clustering.clusters[base_cluster].version - base_ver)
        else:
            # base branch was merged away; the merge broadcast refreshed
            # every member, so only post-broadcast aggregations are stale
            staleness = max(0, cluster.version - cluster.last_broadcast_version)
        self.staleness.record(staleness)

        # 3. aggregate = CI push into the branch
        pred = self._predictor(cid) if self.enable_broadcast else None
        if pred is not None:  # the pre-update center only feeds the predictor
            prev_center = cluster.center if plane is None else cluster.center_vec

        def merge_fn(head):
            self.clustering.aggregate(cid, params)
            c = self.clustering.clusters[cid]
            return c.center if plane is None else c.center_vec
        branch.push(client_id, merge_fn, f"upload from {client_id} (staleness {staleness})")

        # 3b. late poison detection (guard only): a non-finite or
        # MAD-blown post-blend center norm vetoes the blend — roll back
        # to the last-known-good snapshot and re-broadcast. The corrupt
        # blend never feeds the predictor, and the uploader learns the
        # restored center through the recovery broadcast.
        if self.guard is not None and not self.guard.center_ok(
            cid, self._center_norm(cluster)
        ):
            out.extend(self._rollback_center(cluster, branch, client_id))
            if self._uploads % self.refine_every == 0:
                out.extend(self._refine())
            return out

        # 4. Top-K change record + online fine-tune on the ground-truth
        #    label for the previous decision (Eq. 4)
        if pred is not None:
            if plane is None:
                change = float(tree_l1(cluster.center, prev_center))
            else:
                change = float(l1_vec(cluster.center_vec, prev_center))
            if plane is None:
                gap_before = float(tree_l1(prev_center, cluster.last_broadcast_center))
            else:
                gap_before = float(l1_vec(prev_center, cluster.broadcast_vec))
            # Ground truth for the decision made before this upload (Eq. 4,
            # with the sign read per the Sec. 5.2.1 text rule): the realized
            # model change exceeding the accumulated gap since the last
            # broadcast means the broadcast was warranted.
            label = 1 if change > gap_before else 0
            if pred.records:
                pred.learn(label)
            pred.observe(change)

        # 5. unicast fresh center to the uploader
        out.append(Downlink(client_id, cluster.center, cluster.version, cid, "unicast"))
        self.client_versions[client_id] = (cid, cluster.version)

        # 6. on-demand broadcast to the rest of the cluster
        if pred is not None and cluster.size > 1:
            if plane is None:
                gap = float(tree_l1(cluster.center, cluster.last_broadcast_center))
            else:
                gap = float(l1_vec(cluster.center_vec, cluster.broadcast_vec))
            self._decisions += 1
            if pred.decide(gap):
                self._rnn_broadcasts += 1
                out.extend(self._broadcast(cluster, exclude={client_id}))

        # 7. periodic refinement
        if self._uploads % self.refine_every == 0:
            out.extend(self._refine())
        return out

    # ------------------------------------------------------- batched ingest
    def handle_uploads(self, batch: list[tuple]) -> list[list[Downlink]]:
        """Batched ingest of concurrently-arrived uploads (the event-coalesced
        async path): ``batch`` is a list of ``handle_upload`` argument tuples
        ``(client_id, params, base_version, n_samples, t)`` in event order.
        Returns one downlink list per upload, exactly what N sequential
        ``handle_upload`` calls would return.

        Uploads are processed in *segments* of consecutive distinct clients:
        each segment's cluster assignment + mixed-rate blends run as ONE
        fused scan launch (``kernels.ops.ingest_chain`` —
        sequential-equivalent: step j scores against the centers already
        blended by steps < j), and the host replays only the per-upload
        protocol bookkeeping (staleness, CI branch pushes, predictor
        bookkeeping, downlink construction) from the precomputed
        statistics. Predictor learn/decide work is itself batched into one
        fused RNN chain launch per refinement sub-window
        (``REPRO_PREDICTOR_BATCH``; see :meth:`_plan_predictor_window`).

        Refinement no longer cuts segments: the chain launch speculatively
        spans refine boundaries, and after each mid-segment refine the
        replay revalidates the launch's assumptions (cluster set unchanged,
        per-upload prev/forced indices still correct). A refine that moved
        clients, lifted partial-finetune pins, or changed the cluster set
        invalidates the remainder, which simply relaunches from live state.
        Remaining segment boundaries — a repeated client, the seeding
        phase, the pytree backend — fall back to the per-upload path, so
        trajectories are identical to the unbatched loop by construction."""
        out: list[list[Downlink]] = []
        i, n = 0, len(batch)
        while i < n:
            cl = self.clustering
            if (
                cl.plane is None
                or not self.enable_clustering
                or len(cl.clusters) < cl.num_initial
            ):
                out.append(self.handle_upload(*batch[i]))
                i += 1
                continue
            # segment: consecutive distinct clients
            seen: set = set()
            j = i
            while j < n and batch[j][0] not in seen:
                seen.add(batch[j][0])
                j += 1
            if j - i < 2:
                out.append(self.handle_upload(*batch[i]))
                i += 1
                continue
            seg_out, consumed = self._handle_upload_segment(batch[i:j])
            out.extend(seg_out)
            i += consumed
        return out

    def _handle_upload_segment(self, seg: list[tuple]) -> tuple[list[list[Downlink]], int]:
        """One fused-launch segment of :meth:`handle_uploads` (plane mode).

        Returns ``(downlink lists, uploads consumed)``: a mid-segment
        refinement that invalidates the speculative launch (moved clients,
        lifted pins, changed cluster set) stops the replay right after the
        refine; the caller relaunches the remainder from live state."""
        cl = self.clustering
        plane = cl.plane
        cid_order = sorted(cl.clusters)
        pos = {c: k for k, c in enumerate(cid_order)}
        S = len(seg)

        # one flatten per upload, one stacked write into the upload rows
        # (the same vectors the per-event path writes one at a time)
        U = jnp.stack([plane.from_pytree(item[1]) for item in seg])
        upload_rows = []
        for item in seg:
            row = self._upload_rows.get(item[0])
            if row is None:
                row = self._upload_rows[item[0]] = plane.alloc()
            upload_rows.append(row)
        plane.write_rows(upload_rows, U)

        prev_idx, forced_idx = [], []
        for item in seg:
            prev = cl.assignment.get(item[0])
            alive = prev is not None and prev in cl.clusters
            pf = alive and item[0] in cl.clusters[prev].partial_finetune
            prev_idx.append(pos[prev] if alive else -1)
            forced_idx.append(pos[prev] if pf else -1)

        P = 1 << (S - 1).bit_length()  # pad the scan length: O(log window) jit cache
        valid = [True] * S + [False] * (P - S)
        if P != S:
            U = jnp.concatenate([U, jnp.broadcast_to(U[:1], (P - S, U.shape[1]))])
            prev_idx += [-1] * (P - S)
            forced_idx += [-1] * (P - S)

        C0 = plane.rows([cl.clusters[c]._row for c in cid_order])
        B0 = plane.rows([cl.clusters[c]._bcast_row for c in cid_order])
        Cn = len(cid_order)
        Cp = 1 << (Cn - 1).bit_length()  # pow2-padded: O(log clusters) jit cache
        if Cp != Cn:
            zpad = jnp.zeros((Cp - Cn, C0.shape[1]), C0.dtype)
            C0 = jnp.concatenate([C0, zpad])
            B0 = jnp.concatenate([B0, zpad])
        guard = self.guard
        res = K.ingest_chain(
            U, C0, B0, prev_idx, forced_idx, valid,
            beta=cl.mix_rate, num_centers=Cn, with_stats=guard is not None,
        )
        # ONE host sync for the whole segment (stats + blended rows: the
        # per-upload center writes re-enter the plane as staged host rows).
        # The guard's post-blend center norms ride the same launch and sync.
        if guard is not None:
            cids_d, blended_d, change_d, gb_d, ga_d, cn_d = res
            cids_np, change_np, gb_np, ga_np, cnorm_np, blended = jax.device_get(
                (cids_d[:S], change_d[:S], gb_d[:S], ga_d[:S], cn_d[:S], blended_d[:S])
            )
        else:
            cids_d, blended_d, change_d, gb_d, ga_d = res
            cids_np, change_np, gb_np, ga_np, blended = jax.device_get(
                (cids_d[:S], change_d[:S], gb_d[:S], ga_d[:S], blended_d[:S])
            )
            cnorm_np = None
        blended = np.asarray(blended)
        blended.flags.writeable = False  # unicast payloads are views of this

        step_cids = [cid_order[int(cids_np[j])] for j in range(S)]
        out: list[list[Downlink]] = []
        last_vec: dict[int, Any] = {}  # cid -> live center row (host, np)
        bcast_np: dict[int, Any] = {}  # cid -> anchor moved mid-segment (np)
        batch_pred = self.enable_broadcast and predictor_batch_enabled()
        j0 = 0
        while j0 < S:
            # predictor sub-window: up to and including the next refine
            # boundary — a refine's predictor maintenance (expansion/merge
            # inheritance) must see RNN weights as of refine time, so the
            # fused chain launch never crosses it
            until_refine = self.refine_every - (self._uploads % self.refine_every)
            j1 = min(S, j0 + until_refine)
            # guard pre-walk: consume the fused launch's post-blend center
            # norms in step order BEFORE planning predictor work — on a
            # clean window this records exactly what per-step checks would
            # (all-accept, plan untouched); a detection at step f voids the
            # speculative launch from f on, so the window falls back to the
            # serial predictor path and the replay aborts right after f
            guard_fail = None
            if cnorm_np is not None:
                for jj in range(j0, j1):
                    if not guard.center_ok(step_cids[jj], float(cnorm_np[jj])):
                        guard_fail = jj
                        break
            plan = (
                self._plan_predictor_window(
                    seg, j0, j1, step_cids, forced_idx,
                    change_np, gb_np, ga_np, blended, bcast_np, last_vec,
                )
                if batch_pred and guard_fail is None
                else None
            )
            for j in range(j0, j1):
                client_id, params, base_version, n_samples, t = seg[j]
                self._uploads += 1
                msgs: list[Downlink] = []
                cid = step_cids[j]
                cluster = cl.clusters[cid]
                if forced_idx[j] < 0:  # partial-finetune members stay put, no move
                    cl._move(client_id, cid)
                try:
                    branch = self.repo.branch(f"cluster/{cid}")
                except KeyError:
                    branch = self.repo.branch(f"cluster/{cid}", cluster.center_vec)

                # staleness bookkeeping — identical to handle_upload
                base_cluster, base_ver = self.client_versions.get(client_id, (cid, 0))
                if base_cluster == cid:
                    staleness = max(0, cluster.version - base_ver)
                elif base_cluster in cl.clusters:
                    staleness = max(0, cl.clusters[base_cluster].version - base_ver)
                else:
                    staleness = max(0, cluster.version - cluster.last_broadcast_version)
                self.staleness.record(staleness)

                pred = self._predictor(cid) if self.enable_broadcast else None
                new_vec = blended[j]

                def merge_fn(head, cluster=cluster, vec=new_vec):
                    cluster.set_center_vec(vec)
                    cluster.version += 1
                    return cluster.center_vec

                branch.push(client_id, merge_fn, f"upload from {client_id} (staleness {staleness})")

                if j == guard_fail:
                    # the carried center matrix is corrupt from this step
                    # on: roll back, hand the remainder back for a relaunch
                    # from the restored live state (same abort discipline as
                    # a refine that invalidates the speculative launch)
                    msgs.extend(self._rollback_center(cluster, branch, client_id))
                    if self._uploads % self.refine_every == 0:
                        msgs.extend(self._refine())
                    out.append(msgs)
                    cl._pending = None
                    return out, j + 1

                if pred is not None:
                    change = float(change_np[j])
                    if plan is None:
                        b_moved = bcast_np.get(cid)
                        if b_moved is not None:
                            # an intra-window broadcast moved this cluster's
                            # anchor: the precomputed gap is stale. The anchor
                            # AND the pre-blend center are both host rows we
                            # already hold (the broadcast step's blended row),
                            # so the recompute is pure numpy — no device
                            # round-trip per upload.
                            gap_before = float(np.abs(last_vec[cid] - b_moved).sum(dtype=np.float32))
                        else:
                            gap_before = float(gb_np[j])
                        label = 1 if change > gap_before else 0
                        if pred.records:
                            pred.learn(label)
                    # with a plan, the fused chain launch already applied the
                    # SGD steps on host-exact labels; only the record window
                    # bookkeeping happens per upload
                    pred.observe(change)

                # unicast payload: host-side numpy views of the blended row we
                # already synced — bitwise the center the per-event path would
                # materialize, with zero device dispatches
                msgs.append(
                    Downlink(client_id, plane.spec.unflatten_np(new_vec), cluster.version, cid, "unicast")
                )
                self.client_versions[client_id] = (cid, cluster.version)

                if pred is not None and cluster.size > 1:
                    self._decisions += 1
                    if plan is None:
                        b_moved = bcast_np.get(cid)
                        if b_moved is not None:
                            gap = float(np.abs(new_vec - b_moved).sum(dtype=np.float32))
                        else:
                            gap = float(ga_np[j])
                        want = pred.decide(gap)
                    else:
                        # mirror BroadcastPredictor.decide with the planned
                        # outcome — counters and the one-suppressed-decision
                        # activation stay host-exact
                        pred.decisions += 1
                        if not pred.active:
                            pred.active = True
                            want = False
                        else:
                            want = plan.wants[j]
                        if want:
                            pred.broadcasts += 1
                    if want:
                        self._rnn_broadcasts += 1
                        msgs.extend(self._broadcast(cluster, exclude={client_id}))
                        bcast_np[cid] = new_vec  # snapshot_broadcast just copied it
                last_vec[cid] = new_vec

                if j == j1 - 1 and plan is not None:
                    # write the fused chain's final RNN weights back before a
                    # refine can inherit them (expansion/merge maintenance)
                    for wcid, wparams in plan.new_params.items():
                        self.predictors[wcid].params = wparams
                if self._uploads % self.refine_every == 0:
                    msgs.extend(self._refine())
                    out.append(msgs)
                    if j + 1 < S and not self._segment_continuation_valid(
                        seg, j + 1, cid_order, prev_idx, forced_idx
                    ):
                        # the refine changed what the speculative launch
                        # assumed: hand the remainder back for a relaunch
                        cl._pending = None
                        return out, j + 1
                else:
                    out.append(msgs)
            j0 = j1
        cl._pending = None  # the fused path never uses the assign-time cache
        return out, S

    def _segment_continuation_valid(
        self, seg: list[tuple], start: int, cid_order: list, prev_idx: list, forced_idx: list
    ) -> bool:
        """Did a mid-segment refine leave the speculative chain launch valid
        for the remaining uploads? The launch fixed (a) the cluster set and
        its center/anchor rows and (b) each upload's prev/forced index.
        Expansion, merge and dissolve all change the cluster set (and every
        center write rides on those), so (a) catches them; feedback
        reassignment and partial-finetune lifts change (b)."""
        cl = self.clustering
        if sorted(cl.clusters) != cid_order:
            return False
        pos = {c: k for k, c in enumerate(cid_order)}
        for j in range(start, len(seg)):
            client = seg[j][0]
            prev = cl.assignment.get(client)
            alive = prev is not None and prev in cl.clusters
            pf = alive and client in cl.clusters[prev].partial_finetune
            if prev_idx[j] != (pos[prev] if alive else -1):
                return False
            if forced_idx[j] != (pos[prev] if pf else -1):
                return False
        return True

    def _plan_predictor_window(
        self, seg, j0, j1, step_cids, forced_idx,
        change_np, gb_np, ga_np, blended, bcast_np, last_vec,
    ) -> "_PredictorPlan | None":
        """Plan one refinement sub-window's predictor work as one fused RNN
        chain launch per touched cluster (``kernels.ops.predictor_chain``).

        The serial path pays two jit dispatches plus a blocking want-sync
        per upload. All of that work is a deterministic function of state
        we already hold on the host: record windows evolve by the synced
        ``change`` stats alone, gates (learn: records nonempty; decide:
        cluster size > 1 with active/cold-start kinds) are
        decision-independent, and only the Eq. 4 *labels* and the
        cold-start fallback decisions depend on broadcast anchors that
        intra-window decisions may move. A structure pass replays
        membership + record evolution without touching live state, and
        the label/decision circularity is resolved IN-SCAN: within a
        window a cluster's anchor can only be its pre-window anchor or
        the blended vector of an earlier fired step of the same chain, so
        the planner precomputes each step's label (and each cold-start
        fallback decision) for every possible "last fired position" with
        exact host float64 arithmetic, and the chain's scan carries the
        fired position and gathers from those rows. Every step executes
        once; one decision sync per window covers all clusters.

        Inactive (post-expansion) decisions need no device work and are
        computed host-side, mirroring :meth:`BroadcastPredictor.decide`;
        the final host ``resolve`` replay under the synced RNN decisions
        recomputes fallback fires with the same float64 rules the tables
        were built from, keeping the returned bookkeeping host-exact.
        """
        cl = self.clustering

        # ---- structure pass: decision-independent step data -------------
        sim_size: dict[int, int] = {}
        sim_assign: dict[Any, int] = {}
        pstate: dict[int, dict] = {}  # cid -> simulated predictor state

        def size_of(c):
            return sim_size.get(c, cl.clusters[c].size)

        def pred_of(c):
            ps = pstate.get(c)
            if ps is None:
                live = self.predictors.get(c)
                if live is not None:
                    ps = {
                        "records": list(live.records), "scale": live.scale,
                        "active": live.active, "k": live.k, "params": live.params,
                    }
                else:  # _predictor() creates at first touch, k from live size
                    ps = {
                        "records": [], "scale": 1.0, "active": True,
                        "k": max(self.top_k, size_of(c)), "params": self._rnn_init,
                    }
                pstate[c] = ps
            return ps

        steps = []
        for j in range(j0, j1):
            client = seg[j][0]
            cid = step_cids[j]
            if forced_idx[j] < 0:  # mirror cl._move's size effects
                prev = sim_assign.get(client, cl.assignment.get(client))
                if prev != cid:
                    if prev is not None and prev in cl.clusters:
                        sim_size[prev] = size_of(prev) - 1
                    sim_size[cid] = size_of(cid) + 1
                sim_assign[client] = cid
            ps = pred_of(cid)
            change = float(change_np[j])
            learn_gate = len(ps["records"]) > 0
            seq_pre = build_seq(ps["records"], ps["k"]) if learn_gate else None
            # observe(), host-exact
            ps["records"].append(change)
            ps["records"] = ps["records"][-max(ps["k"], 1):]
            ps["scale"] = 0.9 * ps["scale"] + 0.1 * max(abs(change), 1e-12)
            kind, seq_post = "none", None
            if size_of(cid) > 1:
                if not ps["active"]:
                    kind = "inactive"
                    ps["active"] = True
                elif len(ps["records"]) < 2:
                    kind = "fallback"
                else:
                    kind = "rnn"
                    seq_post = build_seq(ps["records"], ps["k"])
            steps.append({
                "j": j, "cid": cid, "change": change, "learn": learn_gate,
                "seq_pre": seq_pre, "kind": kind, "seq_post": seq_post,
                "scale": ps["scale"],
            })

        # ---- label/decision resolution under a set of RNN outcomes ------
        def resolve(rnn_wants: dict) -> tuple[dict, dict]:
            anchors = dict(bcast_np)
            lastv = dict(last_vec)
            labels: dict[int, int] = {}
            wants: dict[int, bool] = {}
            for st in steps:
                j, cid = st["j"], st["cid"]
                a = anchors.get(cid)
                if a is not None:
                    gap_before = float(np.abs(lastv[cid] - a).sum(dtype=np.float32))
                else:
                    gap_before = float(gb_np[j])
                labels[j] = 1 if st["change"] > gap_before else 0
                want = False
                if st["kind"] == "fallback":
                    if a is not None:
                        gap = float(np.abs(blended[j] - a).sum(dtype=np.float32))
                    else:
                        gap = float(ga_np[j])
                    want = gap > 1.0 * st["scale"]  # decide()'s fallback rule
                elif st["kind"] == "rnn":
                    want = bool(rnn_wants.get(j, False))
                wants[j] = want
                if want:
                    anchors[cid] = blended[j]
                lastv[cid] = blended[j]
            return labels, wants

        # ---- fused launch: in-scan label/decision resolution ------------
        # A chain covers every step of a cluster that learns, decides via
        # the RNN, or decides via the cold-start fallback — the latter two
        # can fire a broadcast and move the anchor that later labels and
        # fallback gaps read. Within one window that anchor is either the
        # pre-window anchor or the blended vector of an earlier fired step
        # of the SAME chain, so every anchor-dependent comparison is
        # enumerable on the host: build, per step, a boolean row over
        # "last fired chain position" with the exact float64 expressions
        # resolve() uses, and let the scan carry the fired position and
        # gather from the rows (no float compare ever runs on device).
        # One launch per cluster, one decision sync per window, every step
        # executed exactly once — no fixpoint iteration, no relaunches.
        chains: dict[int, list] = {}
        for st in steps:
            if st["learn"] or st["kind"] in ("rnn", "fallback"):
                chains.setdefault(st["cid"], []).append(st)
        rnn_any = any(st["kind"] == "rnn" for st in steps)
        launch_cids = [
            c for c in sorted(chains)
            if any(st["learn"] or st["kind"] == "rnn" for st in chains[c])
        ]
        if not launch_cids:  # no device work at all this window
            _, wants = resolve({})
            return _PredictorPlan(wants=wants, new_params={})

        # last-upload vector seen by each step BEFORE it runs (evolves at
        # every step of its cluster, chain member or not — mirrors the
        # ``lastv`` updates in resolve())
        lastv_sim = dict(last_vec)
        lastv_before: dict[int, Any] = {}
        for st in steps:
            lastv_before[st["j"]] = lastv_sim.get(st["cid"])
            lastv_sim[st["cid"]] = blended[st["j"]]

        wants_dev: dict[int, Any] = {}
        finals: dict[int, Any] = {}
        for c in launch_cids:
            sub = chains[c]
            k = pred_of(c)["k"]
            # pow2-padded shapes keep the jit cache O(log window x log K);
            # per-cluster launches keep it independent of cluster count
            Kp = 1 << (k - 1).bit_length()
            Sp = 1 << (len(sub) - 1).bit_length()
            pre = np.zeros((Sp, Kp, 1), np.float32)
            post = np.zeros((Sp, Kp, 1), np.float32)
            lab_t = np.zeros((Sp, Sp + 1), np.int32)
            fb_t = np.zeros((Sp, Sp + 1), bool)
            lgate = np.zeros(Sp, bool)
            dgate = np.zeros(Sp, bool)
            fgate = np.zeros(Sp, bool)
            anchor0 = bcast_np.get(c)
            for p, st in enumerate(sub):
                j = st["j"]
                lv = lastv_before[j]
                # anchor candidates live when step p runs: column 0 = the
                # pre-window anchor, column q+1 = chain step q fired last
                cand = [(0, anchor0)] + [
                    (q + 1, blended[sub[q]["j"]]) for q in range(p)
                    if sub[q]["kind"] in ("rnn", "fallback")
                ]
                if st["learn"]:
                    pre[p, Kp - k:, :] = st["seq_pre"]
                    lgate[p] = True
                    for col, a in cand:
                        if a is None:
                            gb = float(gb_np[j])
                        else:
                            gb = float(np.abs(lv - a).sum(dtype=np.float32))
                        lab_t[p, col] = 1 if st["change"] > gb else 0
                if st["kind"] == "rnn":
                    post[p, Kp - k:, :] = st["seq_post"]
                    dgate[p] = True
                elif st["kind"] == "fallback":
                    fgate[p] = True
                    for col, a in cand:
                        if a is None:
                            ga = float(ga_np[j])
                        else:
                            ga = float(np.abs(blended[j] - a).sum(dtype=np.float32))
                        fb_t[p, col] = ga > 1.0 * st["scale"]
            finals[c], w = K.predictor_chain(
                pred_of(c)["params"], pre, post, lab_t, fb_t,
                lgate, dgate, fgate, Kp - k, 1e-2,
            )
            if any(s["kind"] == "rnn" for s in sub):
                wants_dev[c] = w

        used: dict[int, bool] = {}
        if rnn_any:
            w_host = jax.device_get(wants_dev)  # ONE blocking sync per window
            for c, wc in w_host.items():
                for p, st in enumerate(chains[c]):
                    if st["kind"] == "rnn":
                        used[st["j"]] = bool(wc[p])
        _, wants = resolve(used)
        new_params = {
            c: finals[c] for c in launch_cids
            if any(st["learn"] for st in chains[c])
        }
        return _PredictorPlan(wants=wants, new_params=new_params)

    def _center_norm(self, cluster) -> float:
        """Post-blend center L1 norm for the guard's late check (per-event
        path: one host read per upload — the coalesced path gets the same
        scalar from the fused ``ingest_chain`` stats instead)."""
        if self.clustering.plane is None:
            return float(np.abs(np.asarray(tree_flat_vector(cluster.center))).sum())
        return float(np.abs(np.asarray(cluster.center_vec)).sum())

    def _rollback_center(self, cluster, branch, client_id) -> list[Downlink]:
        """Late detection fired: restore the newest finite last-known-good
        center (snapshot ring, then the broadcast anchor), record the
        recovery on the CI branch, and re-broadcast on demand — the
        paper-native recovery path (a broadcast with staleness accounting,
        not a new protocol). Every member, including the uploader whose
        blend was vetoed, re-syncs to the restored center."""
        cid = cluster.cluster_id
        if not cluster.rollback():
            # every recorded state is itself corrupt — nothing to restore;
            # the ledger still counts the detection
            self.guard.note_rollback()
            self.events.append({"kind": "rollback", "cluster": cid, "restored": False})
            return []
        self.guard.note_rollback()

        def merge_fn(head):
            cluster.version += 1
            return (
                cluster.center if self.clustering.plane is None else cluster.center_vec
            )

        branch.push(client_id, merge_fn, f"center rollback after poisoned blend from {client_id}")
        self.events.append({"kind": "rollback", "cluster": cid, "restored": True})
        return self._broadcast(cluster)

    def _broadcast(self, cluster, exclude: set = frozenset()) -> list[Downlink]:
        cluster.snapshot_broadcast()  # row copy in plane mode
        cluster.last_broadcast_version = cluster.version
        msgs = []
        for member in cluster.members - exclude:
            msgs.append(Downlink(member, cluster.center, cluster.version, cluster.cluster_id, "broadcast"))
            self.client_versions[member] = (cluster.cluster_id, cluster.version)
        self.events.append({"kind": "broadcast", "cluster": cluster.cluster_id, "n": len(msgs)})
        return msgs

    # ---------------------------------------------------------- refinement
    def _feedback_rows(self, pairs: list) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Stack feedback_fn outputs for (client, center) pairs. With a
        batched probe installed (``feedback_batch_fn``, e.g. the client
        fleet engine) the whole pair list is ONE model-evaluation launch;
        otherwise each pair probes via feedback_fn. Either way the chi2 x
        Var statistic downstream is one kernel launch."""
        if self.feedback_batch_fn is not None:
            f_pred, f_true, s_soft = self.feedback_batch_fn(list(pairs))
            return (
                np.asarray(f_pred),
                np.maximum(np.asarray(f_true), 1e-3),
                np.asarray(s_soft),
            )
        rows = [self.feedback_fn(m, center) for m, center in pairs]
        f_pred = np.stack([r[0] for r in rows])
        f_true = np.stack([np.maximum(r[1], 1e-3) for r in rows])
        s_soft = np.stack([r[2] for r in rows])
        return f_pred, f_true, s_soft

    def _collect_feedback(self) -> dict[int, dict[Any, float]]:
        """chi2 x Var(S) feedback for every member of every cluster, in one
        cluster-segmented kernel launch (the seed looped a launch per
        cluster). The same launch accumulates per-cluster sums of g, which
        become the cluster-mean feedback exposed in :meth:`stats`."""
        if self.feedback_fn is None:
            return {}
        cid_order = sorted(self.clustering.clusters)
        entries: list[tuple[int, int, Any, Any]] = []  # (segment, cid, member, center)
        for si, cid in enumerate(cid_order):
            cluster = self.clustering.clusters[cid]
            center = cluster.center  # materialized once per cluster
            for m in sorted(cluster.members):
                entries.append((si, cid, m, center))
        if not entries:
            return {}
        f_pred, f_true, s_soft = self._feedback_rows([(m, c) for _, _, m, c in entries])
        seg_ids = np.asarray([si for si, _, _, _ in entries], np.int32)
        g, seg_sum = K.chi2_feedback_all(
            f_pred, f_true, s_soft, seg_ids, num_segments=len(cid_order),
            **self.clustering._kernel_mesh_kwargs(len(entries)),
        )
        g = np.asarray(g)
        counts = np.bincount(seg_ids, minlength=len(cid_order))
        seg_sum = np.asarray(seg_sum)
        self.last_cluster_feedback_mean = {
            cid: float(seg_sum[si] / counts[si])
            for si, cid in enumerate(cid_order)
            if counts[si] > 0  # empty clusters have no feedback, not g=0
        }
        per_cluster: dict[int, dict[Any, float]] = {}
        for (si, cid, m, _), gi in zip(entries, g.tolist()):
            per_cluster.setdefault(cid, {})[m] = gi
        return per_cluster

    def _reassign_by_feedback(self, feedback: dict[int, dict[Any, float]]) -> int:
        """A poor-fit member may simply belong to another *existing* cluster
        (on-arrival L1 assignment is fast but errorful — Sec. 4.2.2, and an
        upload stays geometrically closest to the center it trained from).
        Probe every flagged member's feedback against every other center in
        a single batched launch and move them to a decisively better fit."""
        clusters = self.clustering.clusters
        if self.feedback_fn is None or len(clusters) < 2:
            return 0
        flagged: list[tuple[Any, int, float]] = []  # (member, home cid, g)
        for cid, fb in feedback.items():
            if cid not in clusters or len(fb) < 2:
                continue
            med = float(np.median(list(fb.values())))
            for m, g in fb.items():
                if g <= 2.0 * (med + 1e-12):
                    continue
                if m in clusters[cid].partial_finetune:
                    continue
                flagged.append((m, cid, g))
        if not flagged:
            return 0
        centers = {cid: clusters[cid].center for cid in clusters}
        others_of = {
            home: [c2 for c2 in sorted(clusters) if c2 != home]
            for home in {home for _, home, _ in flagged}
        }
        pairs = [
            (m, centers[c2]) for m, home, _ in flagged for c2 in others_of[home]
        ]
        f_pred, f_true, s_soft = self._feedback_rows(pairs)
        # probe rows shard over the plane mesh once the flagged-member count
        # crosses mesh_min_rows (the single-device launch stays the default)
        scores = np.asarray(
            K.chi2_feedback(
                f_pred, f_true, s_soft,
                **self.clustering._kernel_mesh_kwargs(len(pairs)),
            )
        ).reshape(len(flagged), len(clusters) - 1)
        moves = 0
        for (m, home, g), row in zip(flagged, scores):
            best_i = int(np.argmin(row))
            if row[best_i] < 0.5 * g:
                best = others_of[home][best_i]
                self.clustering._move(m, best)
                self.client_versions[m] = (best, clusters[best].version)
                moves += 1
        return moves

    def _refine(self) -> list[Downlink]:
        out: list[Downlink] = []
        if not self.enable_clustering:
            return out
        self._refine_round += 1
        if self._refine_round % 5 == 0:  # decay peel counts so later data
            # drift (Fig. 18) can still split a previously-churned client out
            self.clustering.peel_counts = {
                k: v - 1 for k, v in self.clustering.peel_counts.items() if v > 1
            }
        # lift head-only mode imposed before this refinement (Sec. 4.3.3:
        # "only be lifted after the next cluster merging refinement")
        for cluster in self.clustering.clusters.values():
            if cluster.partial_finetune and cluster.pf_round < self._refine_round - 1:
                cluster.partial_finetune.clear()
        feedback = self._collect_feedback()

        # first try moving poor fits to an existing better-fitting cluster
        # (probe their feedback against every center); only the leftovers
        # (fit nowhere) justify spawning a new cluster
        moved = self._reassign_by_feedback(feedback)
        if moved:
            self.events.append({"kind": "reassign", "n": moved})
            feedback = self._collect_feedback()

        # expansion: split poor fits out of each cluster (last uploads are
        # plane rows in plane mode, pytrees otherwise)
        uploads = (
            self.last_uploads if self.clustering.plane is None else self._upload_rows
        )
        for cid, fb in list(feedback.items()):
            if cid not in self.clustering.clusters:
                continue
            new_cid = self.clustering.expand(
                cid, fb, uploads=uploads, refine_round=self._refine_round
            )
            if new_cid is not None:
                parent_pred = self._predictor(cid)
                new_cluster = self.clustering.clusters[new_cid]
                change = max(fb.values()) if fb else 0.0
                self.predictors[new_cid] = predictor_for_expansion(parent_pred, change)
                self.repo.branch(f"cluster/{new_cid}", new_cluster.center)
                self.events.append({"kind": "expand", "from": cid, "to": new_cid})
                for m in new_cluster.members:
                    self.client_versions[m] = (new_cid, new_cluster.version)

        # merging: when cluster count exceeds hm * C, fold the nearest pair
        # when one is genuinely redundant; otherwise dissolve the smallest
        # cluster (refit its members) — blending two *distinct* centers just
        # to honor capacity creates the very staleness blob Sec. 4 avoids
        while self.clustering.should_merge():
            pair = self.clustering.nearest_pair()
            if pair is None:
                if not self._dissolve_smallest():
                    break
                continue
            a, b = pair
            pred_a, pred_b = self._predictor(a), self._predictor(b)  # before deletion
            train_fn = self.local_train_fn or (lambda p: p)
            merged_cid = self.clustering.merge_pair(a, b, train_fn)
            other = b if merged_cid == a else a
            pred = predictor_for_merge(pred_a, pred_b)
            self.predictors[merged_cid] = pred
            self.predictors.pop(other, None)
            self.repo.delete(f"cluster/{other}")
            self.repo.branch(f"cluster/{merged_cid}", self.clustering.clusters[merged_cid].center)
            self.events.append({"kind": "merge", "into": merged_cid, "from": other})
            # merged model is immediately broadcast (Sec. 5.2.2)
            out.extend(self._broadcast(self.clustering.clusters[merged_cid]))
        return out

    def _dissolve_smallest(self) -> bool:
        """Capacity overflow with no redundant pair: retire the smallest
        cluster and refit each member to its best remaining cluster (by
        feedback probe when available, else by L1 of its last upload) —
        every probe for every member batched into a single launch."""
        clustering = self.clustering
        clusters = clustering.clusters
        if len(clusters) < 2:
            return False
        victim = min(clusters, key=lambda c: (clusters[c].size, clusters[c].version))
        rest = [c for c in clusters if c != victim]
        members = sorted(clusters[victim].members, key=str)
        best_of: dict[Any, int] = {m: rest[0] for m in members}
        plane = clustering.plane
        if members and self.feedback_fn is not None:
            centers = {c: clusters[c].center for c in rest}
            f_pred, f_true, s_soft = self._feedback_rows(
                [(m, centers[c]) for m in members for c in rest]
            )
            scores = np.asarray(
                K.chi2_feedback(
                    f_pred, f_true, s_soft,
                    **clustering._kernel_mesh_kwargs(len(f_pred)),
                )
            ).reshape(len(members), len(rest))
            for m, row in zip(members, scores):
                best_of[m] = rest[int(np.argmin(row))]
        elif members and plane is not None:
            have = [m for m in members if m in self._upload_rows]
            if have:
                kw = clustering._kernel_mesh_kwargs(len(have))
                # query rows go shard-local under a mesh (no one-device hop)
                # and uncached (one-shot set); the small center matrix stays
                # replicated
                U = plane.take([self._upload_rows[m] for m in have], on_mesh="shard" if kw else False)
                centers = plane.rows([clusters[c]._row for c in rest], on_mesh=bool(kw))
                D = np.asarray(K.l1_distance_pairwise(U, centers, **kw))
                for m, d in zip(have, D):
                    best_of[m] = rest[int(np.argmin(d))]
        elif members:
            with_uploads = [m for m in members if m in self.last_uploads]
            if with_uploads:
                centers = jnp.stack([tree_flat_vector(clusters[c].center) for c in rest])
                U = jnp.stack([tree_flat_vector(self.last_uploads[m]) for m in with_uploads])
                D = np.asarray(K.l1_distance_pairwise(U, centers))
                for m, d in zip(with_uploads, D):
                    best_of[m] = rest[int(np.argmin(d))]
        for m in members:
            best = best_of[m]
            clustering._move(m, best)
            self.client_versions[m] = (best, clusters[best].version)
        clustering.drop_cluster(victim)
        self.predictors.pop(victim, None)
        self.repo.delete(f"cluster/{victim}")
        self.events.append({"kind": "dissolve", "cluster": victim})
        return True

    # --------------------------------------------------- elastic eviction
    def evict_clients(self, client_ids: list) -> dict:
        """Administratively remove clients that have gone permanently dark
        (device death under fault injection, or a drop-the-straggler
        policy giving up on them). Frees each client's upload row, drops
        its assignment/version bookkeeping, and — when a cluster's
        membership empties — reclaims the cluster itself: center and
        broadcast-anchor rows go back to the plane free-list, the
        predictor and CI branch are deleted. Without this, every
        all-members-dark cluster would leak two plane rows (plus one per
        member upload) for the rest of the run.

        Returns ``{"evicted": [...], "reclaimed": [cluster ids]}``."""
        cl = self.clustering
        evicted: list = []
        reclaimed: list[int] = []
        for client_id in client_ids:
            touched = False
            if self.uplink_codec is not None:
                # dead clients never upload again: their codec anchor (+ EF
                # residual) rows go back to the codec plane's free list
                self.uplink_codec.release_client(client_id)
            row = self._upload_rows.pop(client_id, None)
            if row is not None:
                cl.plane.free(row)
                touched = True
            if self.last_uploads.pop(client_id, None) is not None:
                touched = True
            self.client_versions.pop(client_id, None)
            home = cl.assignment.pop(client_id, None)
            if home is not None and home in cl.clusters:
                touched = True
                cluster = cl.clusters[home]
                cluster.members.discard(client_id)
                cluster.partial_finetune.discard(client_id)
                # reclaiming cluster 0 would break the clustering-off
                # ablation, which hardwires every upload into it
                if not cluster.members and self.enable_clustering:
                    cl.drop_cluster(home)
                    self.predictors.pop(home, None)
                    self.repo.delete(f"cluster/{home}")
                    reclaimed.append(home)
            if touched:
                evicted.append(client_id)
                self.events.append({"kind": "evict", "client": str(client_id)})
        for home in reclaimed:
            self.events.append({"kind": "reclaim", "cluster": home})
        return {"evicted": evicted, "reclaimed": reclaimed}

    # ------------------------------------------------ checkpoint/restart
    def state_dict(self) -> tuple[PyTree, dict]:
        """(array_tree, json_meta) capturing every piece of server state the
        paper's protocol accumulates: cluster centers + broadcast anchors,
        per-cluster RNN predictor weights, Top-K records, membership,
        versions, staleness counters. Restore with :meth:`load_state`."""
        cl = self.clustering
        # per-client last uploads: the dissolve/expand refinement geometry.
        # Without them a restarted server silently refines blind (every
        # member probes as its cluster center) until each client re-uploads.
        if cl.plane is None:
            last_uploads = {str(k): v for k, v in self.last_uploads.items()}
        else:
            last_uploads = {
                str(k): cl.plane.to_pytree(row) for k, row in self._upload_rows.items()
            }
        tree = {
            "centers": {str(cid): c.center for cid, c in cl.clusters.items()},
            "bcast_centers": {
                str(cid): c.last_broadcast_center for cid, c in cl.clusters.items()
            },
            "last_uploads": last_uploads,
            "rnn": {str(cid): p.params for cid, p in self.predictors.items()},
        }
        meta = {
            "clusters": {
                str(cid): {
                    "version": c.version,
                    "members": sorted(map(str, c.members)),
                    "partial_finetune": sorted(map(str, c.partial_finetune)),
                    "pf_round": c.pf_round,
                    "last_broadcast_version": c.last_broadcast_version,
                }
                for cid, c in cl.clusters.items()
            },
            "assignment": {str(k): v for k, v in cl.assignment.items()},
            "next_id": cl._next_id,
            "merges": cl.merges,
            "expansions": cl.expansions,
            "peel_counts": {str(k): v for k, v in cl.peel_counts.items()},
            "predictors": {
                str(cid): {
                    "k": p.k, "records": p.records, "active": p.active,
                    "scale": p.scale, "decisions": p.decisions, "broadcasts": p.broadcasts,
                }
                for cid, p in self.predictors.items()
            },
            "staleness": {
                "count": self.staleness.count,
                "total": self.staleness.total,
                "q_max": self.staleness.q_max,
            },
            "client_versions": {str(k): list(v) for k, v in self.client_versions.items()},
            "uploads": self._uploads,
            "decisions": self._decisions,
            "rnn_broadcasts": self._rnn_broadcasts,
            "refine_round": self._refine_round,
            "upload_clients": sorted(last_uploads),
            # exact-restart extras: the expand cooldown gates refinement
            # decisions, and events/feedback means feed stats() — a mid-run
            # kill+restore must reproduce the uninterrupted ledger exactly
            "last_expand_round": {str(k): v for k, v in cl._last_expand_round.items()},
            "events": list(self.events),
            "cluster_feedback_mean": {
                str(k): v for k, v in self.last_cluster_feedback_mean.items()
            },
        }
        if self.uplink_codec is not None:
            # compressed-uplink codec state (anchors + EF residuals): without
            # it a restarted run re-anchors at zero and the first post-restart
            # upload per client ships a full-model delta through the codec
            tree["uplink"], meta["uplink"] = self.uplink_codec.state_dict()
        return tree, meta

    def state_template(self, meta: dict) -> PyTree:
        """Tree-structure template matching :meth:`state_dict` for ``meta`` —
        lets the checkpointer restore without pickling (centers share the
        init_params structure; predictors share the RNN structure)."""
        from repro.core.broadcast import init_rnn

        rnn_like = self._rnn_init if self._rnn_init is not None else init_rnn(jax.random.PRNGKey(0))
        template = {
            "centers": {cid: self.init_params for cid in meta["clusters"]},
            "bcast_centers": {cid: self.init_params for cid in meta["clusters"]},
            "last_uploads": {c: self.init_params for c in meta.get("upload_clients", [])},
            "rnn": {cid: rnn_like for cid in meta["predictors"]},
        }
        if meta.get("uplink"):
            from repro.fl.uplink import seed_template

            template["uplink"] = seed_template(meta["uplink"], self.init_params)
        return template

    def load_state(self, tree: PyTree, meta: dict, client_id_type=int) -> None:
        """Restore from :meth:`state_dict` output (elastic restart)."""
        cid_of = lambda s: client_id_type(s)
        cl = self.clustering
        if cl.plane is not None:  # return pre-restore upload rows too
            for row in self._upload_rows.values():
                cl.plane.free(row)
        self._upload_rows = {}
        self.last_uploads = {}
        cl.reset()  # frees any live plane rows before adopting the snapshot
        for cid_s, info in meta["clusters"].items():
            cid = int(cid_s)
            c = cl.restore_cluster(cid, tree["centers"][cid_s], tree["bcast_centers"][cid_s])
            c.version = info["version"]
            c.members = {cid_of(m) for m in info["members"]}
            c.partial_finetune = {cid_of(m) for m in info["partial_finetune"]}
            c.pf_round = info["pf_round"]
            c.last_broadcast_version = info["last_broadcast_version"]
            self.repo.branch(f"cluster/{cid}", c.center)
        # restore per-client last uploads (absent in pre-upload_clients
        # checkpoints: refinement then runs without last-upload geometry —
        # no dissolve/expand seeding — until every client re-uploads)
        for k, v in (tree.get("last_uploads") or {}).items():
            if cl.backend == "plane":
                cl._ensure_plane(v)
                self._upload_rows[cid_of(k)] = cl.plane.alloc(v)
            else:
                self.last_uploads[cid_of(k)] = v
        cl.assignment = {cid_of(k): v for k, v in meta["assignment"].items()}
        cl._next_id = meta["next_id"]
        cl.merges = meta["merges"]
        cl.expansions = meta["expansions"]
        cl.peel_counts = {cid_of(k): v for k, v in meta["peel_counts"].items()}
        self.predictors = {}
        for cid_s, info in meta["predictors"].items():
            p = BroadcastPredictor(params=tree["rnn"][cid_s], k=info["k"])
            p.records = list(info["records"])
            p.active = info["active"]
            p.scale = info["scale"]
            p.decisions = info["decisions"]
            p.broadcasts = info["broadcasts"]
            self.predictors[int(cid_s)] = p
        st = meta["staleness"]
        self.staleness.count, self.staleness.total, self.staleness.q_max = (
            st["count"], st["total"], st["q_max"],
        )
        self.client_versions = {cid_of(k): tuple(v) for k, v in meta["client_versions"].items()}
        self._uploads = meta["uploads"]
        self._decisions = meta["decisions"]
        self._rnn_broadcasts = meta["rnn_broadcasts"]
        self._refine_round = meta["refine_round"]
        # exact-restart extras (absent in older checkpoints: cooldowns and
        # stats counters then restart empty, which older callers tolerated)
        cl._last_expand_round = {
            int(k): v for k, v in meta.get("last_expand_round", {}).items()
        }
        self.events = list(meta.get("events", []))
        self.last_cluster_feedback_mean = {
            int(k): v for k, v in meta.get("cluster_feedback_mean", {}).items()
        }
        if meta.get("uplink"):
            if self.uplink_codec is not None:
                self.uplink_codec.load_state(tree["uplink"], meta["uplink"], client_id_type)
                self._pending_uplink_state = None
            else:
                # the codec builds with the next run's fleet; replay then
                self._pending_uplink_state = (tree["uplink"], meta["uplink"], client_id_type)
        else:
            self._pending_uplink_state = None

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        plane = self.clustering.plane
        return {
            "clusters": len(self.clustering.clusters),
            "merges": self.clustering.merges,
            "expansions": self.clustering.expansions,
            "staleness": self.staleness.snapshot(),
            "broadcasts": sum(1 for e in self.events if e["kind"] == "broadcast"),
            "rnn_broadcasts": self._rnn_broadcasts,
            "decisions": self._decisions,
            "backend": self.clustering.backend,
            "plane_rows": 0 if plane is None else plane.num_allocated,
            # snapshot from the last refine, filtered to clusters still alive
            "cluster_feedback_mean": {
                cid: g
                for cid, g in self.last_cluster_feedback_mean.items()
                if cid in self.clustering.clusters
            },
        }
