from repro.core.broadcast import BroadcastPredictor, pretrain_rnn
from repro.core.clustering import Cluster, DynamicClustering
from repro.core.server import Downlink, EchoPFLServer
from repro.core.staleness import StalenessTracker
from repro.core.versioning import Branch, ModelRepo, RWLock

__all__ = [
    "BroadcastPredictor",
    "pretrain_rnn",
    "Cluster",
    "DynamicClustering",
    "Downlink",
    "EchoPFLServer",
    "StalenessTracker",
    "Branch",
    "ModelRepo",
    "RWLock",
]
