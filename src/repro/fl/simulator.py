"""Event-driven federated-learning simulator.

Replays the paper's experimental setup in virtual time: heterogeneous
devices (D1..D5 latency model), asymmetric up/down bandwidth, and a
pluggable coordination strategy (EchoPFL or any baseline). Asynchronous
strategies run on an event heap; synchronous ones run round barriers
(optionally per-cluster barriers, for ClusterFL).

The simulator measures exactly what the paper reports: accuracy-vs-time
curves, per-client accuracy (slowest/fastest device), total/up/down
communication bytes, per-minute communication series (peaks), staleness
statistics, and time-to-target-accuracy.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import os
from typing import Any

import jax
import numpy as np

from repro.core.client import SimClient
from repro.fl.faults import FaultInjector, resolve_faults
from repro.fl.network import NetworkModel

PyTree = Any


def default_client_backend() -> str:
    """``REPRO_CLIENT`` knob: ``fleet`` (batched launches via
    :mod:`repro.fl.fleet` — the default since the CI soak) or ``loop``
    (per-client dispatches, the seed path — kept as the parity leg)."""
    return os.environ.get("REPRO_CLIENT", "fleet").lower()


def default_async_coalesce() -> float:
    """``REPRO_ASYNC_COALESCE`` knob: virtual-time window (seconds) for
    coalescing concurrent async events into batched launches. ``off`` /
    ``0`` / unset keeps the per-event loop (the parity default)."""
    spec = os.environ.get("REPRO_ASYNC_COALESCE", "off").strip().lower()
    if spec in ("", "0", "off", "none", "no"):
        return 0.0
    return float(spec)


@dataclasses.dataclass
class SimReport:
    strategy: str
    curve: list[tuple[float, float]]  # (t, mean acc)
    per_client_acc: dict[int, float]
    per_client_class: dict[int, str]
    final_acc: float
    time_to_target: float | None
    up_bytes: int
    down_bytes: int
    up_events: int
    down_events: int
    peak_down: float
    peak_up: float
    duration: float
    extra: dict
    up_series: dict = dataclasses.field(default_factory=dict)  # minute -> bytes
    down_series: dict = dataclasses.field(default_factory=dict)
    # dense-equivalent uplink bytes: equals up_bytes unless an uplink codec
    # (REPRO_UPLINK) compressed the wire — the ratio is the comm-cost claim
    up_raw_bytes: int = 0
    # retry-attributable uplink bytes: re-sends after losses/timeouts and
    # duplicate retransmissions under fault injection (REPRO_FAULTS)
    up_retry_bytes: int = 0

    def bytes_until(self, t: float) -> tuple[float, float]:
        """(up, down) bytes accumulated in bins up to time t (the paper's
        communication-to-convergence metric)."""
        last = int(t // 60)
        up = sum(v for b, v in self.up_series.items() if b <= last)
        down = sum(v for b, v in self.down_series.items() if b <= last)
        return up, down

    def summary(self) -> dict:
        out = {
            "strategy": self.strategy,
            "final_acc": round(self.final_acc, 4),
            "time_to_target_min": None if self.time_to_target is None else round(self.time_to_target / 60, 2),
            "duration_min": round(self.duration / 60, 2),
            "up_MB": round(self.up_bytes / 1e6, 2),
            "down_MB": round(self.down_bytes / 1e6, 2),
            "total_MB": round((self.up_bytes + self.down_bytes) / 1e6, 2),
            "peak_down_MB_per_min": round(self.peak_down / 1e6, 2),
            "peak_up_MB_per_min": round(self.peak_up / 1e6, 2),
        }
        if self.up_raw_bytes and self.up_raw_bytes != self.up_bytes:
            out["up_raw_MB"] = round(self.up_raw_bytes / 1e6, 2)
            out["uplink_ratio"] = round(self.up_bytes / self.up_raw_bytes, 4)
        if self.up_retry_bytes:
            out["up_retry_MB"] = round(self.up_retry_bytes / 1e6, 2)
        return out


_MODEL_BYTES_CACHE: dict = {}


def model_bytes(params: PyTree) -> int:
    """Wire size of one model payload: sum of per-leaf nbytes. Leaf dtype is
    honored — a compressed/quantized payload (int8, fp16) is not 4 bytes per
    element; non-array leaves (python scalars) count as 4-byte words.

    Memoized by (treedef, leaf shapes/dtypes): both simulator loops bill
    every uplink/downlink event through this function with the same handful
    of model structures, so repeat events pay one tree walk and a hash
    lookup instead of the per-leaf arithmetic. Deliberately NOT keyed by
    object identity — that would pin payload pytrees (and their device
    buffers) in a module-global for the process lifetime."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = (
        treedef,
        tuple((getattr(x, "shape", None), getattr(x, "dtype", None)) for x in leaves),
    )
    total = _MODEL_BYTES_CACHE.get(key)
    if total is None:
        total = 0
        for x in leaves:
            dtype = getattr(x, "dtype", None)
            itemsize = dtype.itemsize if dtype is not None else 4
            total += int(np.prod(getattr(x, "shape", ()))) * itemsize
        if len(_MODEL_BYTES_CACHE) > 64:
            _MODEL_BYTES_CACHE.clear()
        _MODEL_BYTES_CACHE[key] = total
    return total


class Simulator:
    def __init__(
        self,
        clients: list[SimClient],
        strategy,
        *,
        network: NetworkModel | None = None,
        eval_interval: float = 60.0,
        target_acc: float = 0.85,
        seed: int = 0,
        churn: dict[Any, list[tuple[float, float]]] | None = None,
        client_backend: str | None = None,
        coalesce_window: float | None = None,
        uplink: Any | None = None,
        faults: Any | None = None,
        guard: Any | None = None,
    ):
        from repro.fl.guard import IngestGuard, resolve_guard
        from repro.fl.uplink import resolve_uplink

        self.clients = {c.client_id: c for c in clients}
        self.strategy = strategy
        self.net = network or NetworkModel()
        # uplink compression (REPRO_UPLINK): config resolves now, the codec
        # itself builds lazily with the fleet (it needs the model template)
        self.uplink = resolve_uplink(uplink)
        self._codec = None
        self.eval_interval = eval_interval
        self.target_acc = target_acc
        self.rng = np.random.default_rng(seed)
        self.curve: list[tuple[float, float]] = []
        self._counter = itertools.count()
        self.client_backend = (client_backend or default_client_backend()).lower()
        if self.client_backend not in ("loop", "fleet"):
            raise ValueError(
                f"REPRO_CLIENT backend must be loop|fleet, got {self.client_backend}"
            )
        self.coalesce_window = (
            float(coalesce_window) if coalesce_window is not None else default_async_coalesce()
        )
        self.coalesced_groups: dict[str, list[int]] = {}  # kind -> group sizes (bench introspection)
        self._fleet = None  # built lazily from the first initial model
        # elastic membership: {client: [(t_offline, t_back), ...]} — a device
        # that would start local training inside an offline window instead
        # resumes when it returns (dropout/rejoin; the async protocol absorbs
        # both, which is what the fault-tolerance tests assert)
        self.churn = churn or {}
        self.churn_delays = 0
        # fault injection (REPRO_FAULTS / the faults= argument): None when
        # disabled — every fault branch below is then dead, keeping clean
        # trajectories bitwise-identical to the pre-fault code
        plan = resolve_faults(faults)
        self._faults = FaultInjector(plan) if plan is not None else None
        # ingest guard (REPRO_GUARD / the guard= argument): None when off —
        # every guard hook below is then dead, keeping guard-off trajectories
        # bitwise-identical to the pre-guard code
        gcfg = resolve_guard(guard)
        self._guard = IngestGuard(gcfg) if gcfg is not None else None
        self._dead: set = set()  # permanently-dark clients (death / drop policy)
        self._useq: dict[Any, int] = {}  # per-client upload send sequence
        self._ingest_high: dict[Any, int] = {}  # highest useq ingested (dup fence)
        self._dl_seq: dict[Any, int] = {}  # per-recipient downlink send sequence
        self._dl_high: dict[Any, int] = {}  # highest fseq installed (reorder fence)
        self._template = None  # model template, kept for post-restart rewiring

    def _next_online(self, cid, t: float) -> float:
        """Single churn consultation point for a local-round start: static
        churn windows first, then injected crashes (the crash loses the
        round's work and the device resumes through this same path —
        ``inf`` marks a permanent death)."""
        for t_off, t_on in self.churn.get(cid, ()):
            if t_off <= t < t_on:
                self.churn_delays += 1
                return t_on
        if self._faults is not None:
            down = self._faults.crash(cid)
            if down is not None:
                if down == math.inf:
                    return math.inf
                self.churn_delays += 1
                return t + down
        return t

    # -------------------------------------------------------- fleet engine
    def _ensure_fleet(self, template: PyTree) -> None:
        """Build the batched client engine (REPRO_CLIENT=fleet) once the
        model structure is known, and hand the strategy its batched
        feedback probe if it accepts one. A hook installed by a *previous*
        simulator's fleet (strategy objects can be reused across runs) is
        always replaced — or cleared on the loop backend — so probes never
        route through a dead fleet's clients/data."""
        strat = self.strategy
        if self.uplink.mode != "none":
            if self._codec is None:
                from repro.fl.uplink import UplinkCodec

                # both backends compress: the codec is its own batched launch,
                # so even the per-client loop ships compressed (B = 1) uploads
                self._codec = UplinkCodec(template, list(self.clients), self.uplink)
            attach = getattr(strat, "attach_uplink_codec", None)
            if attach is not None and getattr(strat, "uplink_codec", None) is not self._codec:
                # the strategy adopts the codec so anchors/residuals ride its
                # checkpoints (a pre-attach load_state restores here too —
                # including the fresh strategy a mid-run kill+restore builds)
                attach(self._codec)
        if self._guard is not None:
            attach_g = getattr(strat, "attach_guard", None)
            if attach_g is not None and getattr(strat, "guard", None) is not self._guard:
                # the strategy adopts the guard: post-blend center checks
                # ride the fused ingest stats and every cluster grows a
                # last-known-good snapshot ring for rollback
                attach_g(self._guard)
        current = getattr(strat, "feedback_batch_fn", "missing")
        fleet_hook = current is not None and current != "missing" and getattr(
            current, "_fleet_hook", False
        )
        if self.client_backend != "fleet":
            if fleet_hook:
                strat.feedback_batch_fn = None
            return
        if self._fleet is None:
            from repro.fl.fleet import ClientFleet

            self._fleet = ClientFleet(list(self.clients.values()), template)
        if current == "missing":
            return
        # (re)install OUR fleet's hook — on every run start, not just fleet
        # construction, since another simulator sharing this strategy may
        # have rebound or cleared it in between. A caller-supplied batch fn
        # (no _fleet_hook tag) is always left alone.
        if current is None or (fleet_hook and getattr(current, "_fleet", None) is not self._fleet):
            fleet = self._fleet

            def hook(pairs):
                return fleet.feedback_many(pairs)

            hook._fleet_hook = True
            hook._fleet = fleet
            strat.feedback_batch_fn = hook

    def _set_model(self, c: SimClient, params: PyTree) -> None:
        """Install a downlinked model on a client (mirrored into the fleet's
        model row so the batched paths see it, and into the client's uplink
        anchor — a downlink is a value both sides agree on for free)."""
        c.model = params
        if self._codec is not None:
            self._codec.install(c.client_id, params)
        if self._fleet is not None:
            self._fleet.set_model(c.client_id, params)

    # ------------------------------------------------------ uplink encoding
    def _encode_upload(self, cid, new_params: PyTree) -> tuple[PyTree, int, int | None]:
        """Route ONE trained model through the uplink codec: returns the
        payload the strategy ingests, the billed wire bytes, and the dense
        size for ratio tracking. The client keeps its own uncompressed
        model; the server sees (and the predictor's change statistics see)
        the reconstruction — what actually crossed the compressed wire.
        With no codec this is the identity: dense params, dense bytes."""
        raw = model_bytes(new_params)
        if self._codec is None:
            return new_params, raw, None
        rec, nbytes = self._codec.encode(cid, new_params)
        return rec, nbytes, raw

    # -------------------------------------------------------- fault layer
    def _upload_with_faults(self, cid, nbytes: int, raw: int | None, t: float) -> tuple[float, bool]:
        """Bill one (possibly retried) upload: every failed attempt sends
        its full payload over the thin link (flagged retry-attributable
        past the first send) and waits a capped exponential backoff before
        re-sending. Returns ``(delay to arrival, delivered)``; the extra
        delay flows into version-based staleness accounting for free —
        the server simply sees an older base_version. ``delivered=False``
        only under the drop policy (the straggler baseline gives up)."""
        inj = self._faults
        fails, delivered = inj.upload_plan(cid)
        delay = 0.0
        for i in range(fails):
            delay += self.net.upload(nbytes, t + delay, raw_nbytes=raw, retry=i > 0)
            delay += inj.backoff(i)
        if not delivered:
            return delay, False
        dur = self.net.upload(nbytes, t + delay, raw_nbytes=raw, retry=fails > 0)
        if fails:
            inj.ledger["retry_delay_s"] += delay
        return delay + dur, True

    def _send_upload(self, push, t: float, cid, up_params, nbytes, raw, base_version) -> None:
        """Schedule one trained upload's arrival (+ fault retries, drops,
        duplicate deliveries). Payload carries the per-client send sequence
        number; the ingest side fences on it to absorb duplicates."""
        if self._faults is None:
            dur = self.net.upload(nbytes, t, raw_nbytes=raw)
            push(t + dur, "upload_done", (cid, up_params, base_version, 0))
            return
        delay, delivered = self._upload_with_faults(cid, nbytes, raw, t)
        if not delivered:  # drop policy hit the retry cap: straggler leaves
            self._retire_client(cid, "dropped")
            return
        pz = self._faults.poison(cid)
        if pz is not None:
            # value-level fault: the bytes crossed the wire fine, the
            # *values* arrive corrupt (bitflip / broken quantizer /
            # adversarial client). Both the original delivery and any
            # duplicate carry the same corrupted payload.
            from repro.fl.faults import apply_poison

            up_params = apply_poison(up_params, pz[0], pz[1], self._faults.cfg)
        useq = self._useq[cid] = self._useq.get(cid, 0) + 1
        push(t + delay, "upload_done", (cid, up_params, base_version, useq))
        dup = self._faults.duplicate(cid)
        if dup is not None:  # retransmission: real bytes cross the link again
            self.net.upload(nbytes, t, raw_nbytes=raw, retry=True)
            push(t + delay + dup, "upload_done", (cid, up_params, base_version, useq))

    def _push_downlink(self, push, t_send: float, dl, dur: float) -> None:
        """Schedule one downlink delivery. Under fault injection the send
        gets a per-recipient sequence number (the install path fences on
        it) and possibly an injected reorder delay."""
        if self._faults is None:
            push(t_send + dur, "downlink", dl)
            return
        dl._fseq = self._dl_seq[dl.client_id] = self._dl_seq.get(dl.client_id, -1) + 1
        push(t_send + dur + self._faults.reorder(dl.client_id), "downlink", dl)

    def _guard_check(self, cid, params) -> str:
        """Score ONE delivered upload against the ingest guard, BEFORE the
        strategy sees it. The cluster key is the client's current home (-1
        pre-assignment); the L1 distance stat is measured against that
        cluster's center — the discriminator that catches sign-flip poison,
        whose L2 norm is unchanged by construction. Rejected uploads never
        reach ``handle_upload``: aggregation, feedback and predictor
        learning are all skipped for free (bytes were billed at send
        time — the wire doesn't know the values are garbage)."""
        guard = self._guard
        cl = getattr(self.strategy, "clustering", None)
        home = cl.assignment.get(cid) if cl is not None else None
        if home is not None and home in cl.clusters:
            key, center = home, cl.clusters[home].center
        else:
            key, center = -1, None
        finite, l2, dist = guard.upload_stats(params, center)
        return guard.check_upload(cid, key, finite, l2, dist)

    def _retire_client(self, cid, kind: str) -> None:
        """Remove a permanently-dark client from the protocol: the server
        evicts it (freeing plane rows, reclaiming all-dark clusters) and
        the simulator stops scheduling it. Its accuracy freezes at the
        last installed model."""
        if cid in self._dead:
            return
        self._dead.add(cid)
        # the guard can retire clients without a fault injector in play
        led = self._faults.ledger if self._faults is not None else None
        if led is not None and kind == "dropped":
            led["dropped_clients"] += 1
        evict = getattr(self.strategy, "evict_clients", None)
        if evict is not None:
            res = evict([cid])
            if led is not None:
                led["evicted_clients"] += len(res["evicted"])
                led["reclaimed_clusters"] += len(res["reclaimed"])

    def _server_kill_restore(self) -> None:
        """Kill the live strategy mid-run and restore a fresh instance from
        a checkpoint written through the crash-safe checkpointer. The old
        object is discarded, so everything the continuation needs must come
        back through ``state_dict``/``load_state`` — the acceptance bar is
        that the run then finishes with the uninterrupted run's exact
        upload/byte/staleness ledger."""
        from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore_pytree

        inj = self._faults
        plan = inj.plan.restart
        tree, meta = self.strategy.state_dict()
        ck = Checkpointer(plan.directory, keep=2)
        try:
            ck.save(inj.ledger["server_restarts"], tree, extra=meta)
        finally:
            ck.close()
        fresh = plan.strategy_factory()
        step = latest_step(plan.directory)
        path = os.path.join(plan.directory, f"step_{step:010d}")
        raw_meta = restore_pytree(path, like=None)[1]
        tree_r, meta_r = restore_pytree(path, like=fresh.state_template(raw_meta))
        fresh.load_state(tree_r, meta_r, client_id_type=plan.client_id_type)
        self.strategy = fresh
        if self._template is not None:
            # rebind the fleet's feedback hook and replay the codec state
            # into the restored strategy, exactly as a run start would
            self._ensure_fleet(self._template)
        inj.mark_restarted()

    # ----------------------------------------------------------- evaluation
    def _evaluate(self, t: float) -> float:
        accs = {}
        # a permanently-dark client was evicted server-side (model_for would
        # hand back init_params): it scores with its last installed model —
        # frozen, which is exactly the degradation the fault bench measures
        dead = self._dead
        if self._fleet is not None:
            # one masked launch for the whole fleet instead of N dispatches
            params = [
                self.clients[cid].model if cid in dead else self.strategy.model_for(cid)
                for cid in self._fleet.ids
            ]
            fleet_accs = self._fleet.evaluate_fleet(params)
            accs = {cid: float(a) for cid, a in zip(self._fleet.ids, fleet_accs)}
        else:
            for cid, c in self.clients.items():
                params = c.model if cid in dead else self.strategy.model_for(cid)
                accs[cid] = c.evaluate(params if params is not None else c.model)
        mean = float(np.mean(list(accs.values())))
        self.curve.append((t, mean))
        self._last_accs = accs
        return mean

    def _report(self, t_end: float, extra: dict) -> SimReport:
        if self._codec is not None:
            extra["uplink"] = {
                "mode": self._codec.mode,
                "payload_bytes": self._codec.nbytes,
                "launches": self._codec.launches,
            }
        self._evaluate(t_end)
        target_t = None
        for t, acc in self.curve:
            if acc >= self.target_acc:
                target_t = t
                break
        return SimReport(
            strategy=self.strategy.name,
            curve=self.curve,
            per_client_acc=self._last_accs,
            per_client_class={cid: c.device_class for cid, c in self.clients.items()},
            final_acc=self.curve[-1][1],
            time_to_target=target_t,
            up_bytes=self.net.up_bytes,
            down_bytes=self.net.down_bytes,
            up_events=self.net.up_events,
            down_events=self.net.down_events,
            peak_down=self.net.peak("down"),
            peak_up=self.net.peak("up"),
            duration=t_end,
            extra=extra,
            up_series=self.net.series("up"),
            down_series=self.net.series("down"),
            up_raw_bytes=self.net.up_raw_bytes,
            up_retry_bytes=self.net.up_retry_bytes,
        )

    # ------------------------------------------------------------ async run
    def _init_async_events(self, push) -> None:
        """Initial broadcast of the seed model + first tick — shared by the
        per-event and coalesced loops so their event streams start
        identically (the degenerate-window bitwise parity depends on it)."""
        strat = self.strategy
        init = strat.initial_models(sorted(self.clients))
        nbytes = model_bytes(next(iter(init.values())))
        self._template = next(iter(init.values()))
        self._ensure_fleet(self._template)
        if self._codec is not None:
            # both sides saw this broadcast: it is the delta anchor
            self._codec.seed(init)
        for cid, params in init.items():
            dl = self.net.download(nbytes, 0.0)
            c = self.clients[cid]
            self._set_model(c, params)
            c.base_version = 0
            push(dl + c.compute_time(), "upload_start", cid)
        if getattr(strat, "tick_interval", None):
            push(strat.tick_interval, "tick", None)

    def run_async(self, *, max_time: float = 3600.0, max_uploads: int | None = None) -> SimReport:
        """Event loop for asynchronous strategies (EchoPFL, FedAsyn, FedSEA).

        With a coalescing window (``REPRO_ASYNC_COALESCE`` / the
        ``coalesce_window`` constructor argument), all events inside one
        virtual-time window are popped together and processed as
        kind-batched launches (:meth:`_run_async_coalesced`); the default
        (window 0) is this per-event loop, byte-for-byte the parity
        baseline."""
        if self.coalesce_window > 0:
            return self._run_async_coalesced(
                self.coalesce_window, max_time=max_time, max_uploads=max_uploads
            )
        strat = self.strategy
        events: list = []  # (time, seq, kind, payload)

        def push(t, kind, payload):
            heapq.heappush(events, (t, next(self._counter), kind, payload))

        self._init_async_events(push)
        next_eval = self.eval_interval
        uploads = 0
        t = 0.0
        while events:
            if self._faults is not None and self._faults.restart_due(uploads):
                self._server_kill_restore()
                strat = self.strategy
            t, _, kind, payload = heapq.heappop(events)
            if t > max_time:
                t = max_time
                break
            while t >= next_eval:
                self._evaluate(next_eval)
                next_eval += self.eval_interval

            if kind == "upload_start":  # local training finished; uplink begins
                cid = payload
                t_on = self._next_online(cid, t)
                if t_on == math.inf:  # crash was fatal: device never returns
                    self._retire_client(cid, "death")
                    continue
                if t_on > t:  # device offline: resume when it rejoins
                    push(t_on + self.clients[cid].compute_time(), "upload_start", cid)
                    continue
                c = self.clients[cid]
                if self._fleet is not None:
                    # row-sliced fleet path: trains from (and writes back)
                    # this client's model row; c.model mirrors the result
                    new_params, _ = self._fleet.train_client(cid)
                else:
                    new_params, _ = c.local_train()
                c.model = new_params
                up_params, nbytes, raw = self._encode_upload(cid, new_params)
                self._send_upload(push, t, cid, up_params, nbytes, raw, c.base_version)
            elif kind == "upload_done":
                cid, params, base_version, useq = payload
                if self._faults is not None:
                    # version-fenced idempotent ingest: a duplicate delivery
                    # (or anything older than what already landed) is absorbed
                    if useq <= self._ingest_high.get(cid, -1):
                        self._faults.ledger["dups_absorbed"] += 1
                        continue
                    self._ingest_high[cid] = useq
                if self._guard is not None and self._guard_check(cid, params) != "accept":
                    # quarantined at ingest: the strategy never sees the
                    # payload; the client (unless escalated to eviction)
                    # keeps training from its own current model
                    if self._guard.should_evict(cid):
                        self._retire_client(cid, "guard")
                    else:
                        push(t + self.clients[cid].compute_time(), "upload_start", cid)
                    continue
                uploads += 1
                c = self.clients[cid]
                downlinks = strat.handle_upload(cid, params, base_version, c.data.n, t)
                # sync-point strategies may buffer; flush anything returned
                for dl in downlinks:
                    dur = self.net.download(model_bytes(dl.params), t)
                    self._push_downlink(push, t, dl, dur)
                # client starts next local round immediately from current base
                push(t + self.clients[cid].compute_time(), "upload_start", cid)
                if max_uploads and uploads >= max_uploads:
                    break
            elif kind == "downlink":
                dl = payload
                if self._faults is not None:
                    # reorder fence: a delivery overtaken by a newer send to
                    # the same client must not roll its model back
                    if dl._fseq < self._dl_high.get(dl.client_id, -1):
                        self._faults.ledger["stale_downlinks_absorbed"] += 1
                        continue
                    self._dl_high[dl.client_id] = dl._fseq
                c = self.clients[dl.client_id]
                self._set_model(c, dl.params)
                c.base_version = dl.version
                c.cluster_id = dl.cluster_id
                if hasattr(strat, "clustering") and dl.cluster_id in strat.clustering.clusters:
                    c.partial_finetune = (
                        dl.client_id in strat.clustering.clusters[dl.cluster_id].partial_finetune
                    )
            elif kind == "tick":  # strategy-driven periodic hook (FedSEA sync points)
                for dl in strat.on_tick(t):
                    dur = self.net.download(model_bytes(dl.params), t)
                    self._push_downlink(push, t, dl, dur)
                if strat.tick_interval:
                    push(t + strat.tick_interval, "tick", None)

        extra = strat.stats() if hasattr(strat, "stats") else {}
        extra["uploads"] = uploads
        if self.churn:
            extra["churn_delays"] = self.churn_delays
        if self._faults is not None:
            extra["faults"] = self._faults.ledger_snapshot()
        if self._guard is not None:
            extra["guard"] = self._guard.ledger_snapshot()
        return self._report(t, extra)

    # ------------------------------------------------- coalesced async run
    def _run_async_coalesced(
        self, window: float, *, max_time: float, max_uploads: int | None
    ) -> SimReport:
        """Event-coalesced async loop: the paper's "aggregate as updates
        arrive" server, without paying one Python/jit dispatch cycle per
        arrival. All events whose virtual times fall in one ``window`` are
        popped together and bucketed by kind, and each bucket is ONE
        batched operation: N ``downlink`` events one staged model write, N
        ``upload_start`` events one row-sliced fleet training launch, N
        ``upload_done`` events one :meth:`EchoPFLServer.handle_uploads`
        ingest (phase order downlink -> train -> ingest, the causal order
        of one server tick). Every event keeps its own timestamp for
        billing and follow-up scheduling, events inside a bucket process in
        event order, and a window never crosses an eval tick, a strategy
        tick, the horizon, or the upload cap.

        Semantics: a window is one superstep of concurrently-arriving
        events — messages *generated* inside it (an ingest's downlinks, a
        training's arrival) deliver when their own timestamps pop, i.e. at
        the next window. The per-event loop is the ``window -> 0`` limit:
        with one event per window the phases are trivially the per-event
        order and the trajectories are bitwise-identical (the parity suite
        asserts exactly this, on both kernel backends); at real windows the
        virtual-time trajectory and per-upload billing are unchanged while
        model values stay allclose — concurrent devices simply no longer
        see downlinks that landed mid-window retroactively rebasing the
        training round they had already finished. Compute times draw from
        the shared device RNG at collection time, in global event order,
        so the draw stream matches the per-event loop's except where churn
        interleaves a resume with an arrival that was *generated* in the
        same window (delivered next superstep): only then can virtual
        times shift."""
        strat = self.strategy
        events: list = []  # (time, seq, kind, payload)

        def push(t, kind, payload):
            heapq.heappush(events, (t, next(self._counter), kind, payload))

        self._init_async_events(push)
        self.coalesced_groups = {}  # fresh introspection per run

        def stash(tn, kn, pn):
            """Draw from the shared device RNG at COLLECTION time, in global
            event order: churn resumes (upload_start) and next-round
            schedules (upload_done) both call ``compute_time`` on the one
            generator every client's ``round_time_fn`` closes over, and the
            phase processing below reorders events by kind — drawing there
            would permute the stream relative to the per-event loop. The
            pre-drawn values ride the bucket entries."""
            if kn == "upload_start":
                t_on = self._next_online(pn, tn)
                if t_on == math.inf:  # fatal crash: no resume, no RNG draw
                    return math.inf
                if t_on > tn:  # device offline: resume when it rejoins
                    return t_on + self.clients[pn].compute_time()
                return None
            if kn == "upload_done":
                if self._faults is not None:
                    # duplicate fence at collection time: the per-event loop
                    # fences at pop time, which is this same global order —
                    # and an absorbed duplicate must not draw compute time
                    if pn[3] <= self._ingest_high.get(pn[0], -1):
                        self._faults.ledger["dups_absorbed"] += 1
                        return "dup"
                    self._ingest_high[pn[0]] = pn[3]
                if self._guard is not None:
                    # guard verdicts, like the dup fence, land at collection
                    # time in global event order — the per-event loop decides
                    # at pop time, which is this same order. An evicted
                    # client never resumes, so (like a fatal crash) it must
                    # not draw a compute time; a rejected-but-alive client
                    # draws exactly one, for its rescheduled next round.
                    if self._guard_check(pn[0], pn[1]) != "accept":
                        if self._guard.should_evict(pn[0]):
                            self._retire_client(pn[0], "guard")
                            return "evicted"
                        return ("rejected", self.clients[pn[0]].compute_time())
                return self.clients[pn[0]].compute_time()
            return None

        next_eval = self.eval_interval
        uploads = 0
        t = 0.0
        while events:
            if self._faults is not None and self._faults.restart_due(uploads):
                self._server_kill_restore()
                strat = self.strategy
            t0, _, kind, payload = heapq.heappop(events)
            if t0 > max_time:
                t = max_time
                break
            t = t0
            while t >= next_eval:
                self._evaluate(next_eval)
                next_eval += self.eval_interval

            if kind == "tick":  # strategy-driven periodic hook (FedSEA sync points)
                for dl in strat.on_tick(t):
                    dur = self.net.download(model_bytes(dl.params), t)
                    self._push_downlink(push, t, dl, dur)
                if strat.tick_interval:
                    push(t + strat.tick_interval, "tick", None)
                continue

            # collect the window and bucket by kind (time order within each)
            buckets: dict[str, list] = {"downlink": [], "upload_start": [], "upload_done": []}
            s0 = stash(t0, kind, payload)
            buckets[kind].append((t0, payload, s0))
            limit = t0 + window
            cap = max_uploads - uploads if max_uploads else None
            # the cap counts ACCEPTED ingests only: dup-fenced, guard-rejected
            # and guard-evicted arrivals never reach the server (a pre-drawn
            # float compute time marks an arrival that will actually ingest)
            ud_seen = 1 if kind == "upload_done" and isinstance(s0, float) else 0
            while events and (cap is None or ud_seen < cap):
                tn, _, kn, pn = events[0]
                if kn == "tick" or tn >= limit or tn >= next_eval or tn > max_time:
                    break
                heapq.heappop(events)
                sn = stash(tn, kn, pn)
                buckets[kn].append((tn, pn, sn))
                t = tn
                ud_seen += kn == "upload_done" and isinstance(sn, float)
            for kn, group in buckets.items():
                if group:
                    self.coalesced_groups.setdefault(kn, []).append(len(group))

            if buckets["downlink"]:
                self._coalesced_downlinks(buckets["downlink"])
            if buckets["upload_start"]:
                self._coalesced_upload_starts(buckets["upload_start"], push)
            if buckets["upload_done"]:
                uploads += self._coalesced_upload_dones(buckets["upload_done"], push)
                if max_uploads and uploads >= max_uploads:
                    break

        extra = strat.stats() if hasattr(strat, "stats") else {}
        extra["uploads"] = uploads
        extra["coalesce_window"] = window
        if self.churn:
            extra["churn_delays"] = self.churn_delays
        if self._faults is not None:
            extra["faults"] = self._faults.ledger_snapshot()
        if self._guard is not None:
            extra["guard"] = self._guard.ledger_snapshot()
        return self._report(t, extra)

    def _coalesced_upload_starts(self, group, push) -> None:
        """One fused training launch for a window of concurrently finishing
        local rounds (churn settled — and its RNG drawn — at collection
        time); billing and scheduling run per event in order, so heap
        tie-breaking sequence numbers match the per-event loop push for
        push."""
        ready = [cid for _, cid, resume in group if resume is None]
        trained: dict[Any, Any] = {}
        encoded: dict[Any, Any] = {}
        if self._fleet is not None and len(ready) > 1:
            if self._codec is not None:
                # the window's whole cohort compresses as ONE codec launch,
                # fed the training launch's device matrix directly (no
                # per-client re-flatten round trip)
                outs, _, vecs = self._fleet.train_rows(ready, with_vecs=True)
                recs, _ = self._codec.encode_rows(ready, vecs)
                encoded = dict(zip(ready, recs))
            else:
                outs, _ = self._fleet.train_rows(ready)
            trained = dict(zip(ready, outs))
        for ti, cid, resume in group:
            if resume == math.inf:  # fatal crash: the device never returns
                self._retire_client(cid, "death")
                continue
            if resume is not None:  # device was offline: resumes when back
                push(resume, "upload_start", cid)
                continue
            c = self.clients[cid]
            if cid in trained:
                new_params = trained[cid]
            elif self._fleet is not None:
                new_params, _ = self._fleet.train_client(cid)
            else:
                new_params, _ = c.local_train()
            c.model = new_params
            if cid in encoded:
                up_params, nbytes, raw = encoded[cid], self._codec.nbytes, model_bytes(new_params)
            else:
                up_params, nbytes, raw = self._encode_upload(cid, new_params)
            self._send_upload(push, ti, cid, up_params, nbytes, raw, c.base_version)

    def _coalesced_upload_dones(self, group, push) -> int:
        """One batched server ingest for a window of arrivals; downlinks
        and the next local rounds are billed/scheduled per event, in order."""
        strat = self.strategy
        # duplicate, guard-rejected and guard-evicted deliveries were fenced
        # out at collection time: they never reach the server and never
        # ingest. A rejected-but-alive client still gets its next round
        # scheduled (its compute time rode the bucket entry as a tuple);
        # dups and evictions schedule nothing and drew nothing.
        live = [e for e in group if isinstance(e[2], float)]
        batch = [
            (cid, params, bv, self.clients[cid].data.n, ti)
            for ti, (cid, params, bv, _useq), _ in live
        ]
        if batch:
            if len(batch) > 1 and hasattr(strat, "handle_uploads"):
                downlinks_per = strat.handle_uploads(batch)
            else:
                downlinks_per = [strat.handle_upload(*b) for b in batch]
        else:
            downlinks_per = []
        dls_iter = iter(downlinks_per)
        for ti, (cid, _params, _bv, _useq), sn in group:
            if sn == "dup" or sn == "evicted":
                continue
            if isinstance(sn, tuple):  # guard-rejected: reschedule only
                push(ti + sn[1], "upload_start", cid)
                continue
            next_compute = sn
            dls = next(dls_iter)
            if self._faults is not None:
                # fault mode bills and ships each downlink individually so
                # sequence numbers and injected reorder delays land exactly
                # as the per-event loop's (byte totals and event counts are
                # identical to the bulk billing either way)
                for dl in dls:
                    dur = self.net.download(model_bytes(dl.params), ti)
                    self._push_downlink(push, ti, dl, dur)
                push(ti + next_compute, "upload_start", cid)
                continue
            # every downlink of one ingest carries a whole model (unicast
            # and echo broadcast alike), so the fan-out shares one wire
            # size and one transfer duration: bill it in one call and ship
            # it as ONE batch event instead of len(fan-out) heap entries —
            # the per-downlink Python (push/pop/billing) is what dominates
            # the echo at fleet scale
            run: list = []
            run_obj, run_nb = None, 0
            for dl in dls:
                if run and dl.params is not run_obj:  # a broadcast fans one object
                    nb = model_bytes(dl.params)
                    if nb != run_nb:
                        dur = self.net.download_bulk(run_nb, len(run), ti)
                        push(ti + dur, "downlink", run)
                        run = []
                    run_obj, run_nb = dl.params, nb
                elif not run:
                    run_obj, run_nb = dl.params, model_bytes(dl.params)
                run.append(dl)
            if run:
                dur = self.net.download_bulk(run_nb, len(run), ti)
                push(ti + dur, "downlink", run)
            # next local round: duration pre-drawn at collection time
            push(ti + next_compute, "upload_start", cid)
        return len(batch)

    def _coalesced_downlinks(self, group) -> None:
        """Apply a window of downlinks (payloads may be single
        :class:`Downlink` objects or whole fan-out batches): the fleet's
        model rows take one staged batch write, client protocol state
        updates per downlink in delivery order."""
        strat = self.strategy
        flat: list = []
        for _ti, payload, _ in group:
            flat.extend(payload) if isinstance(payload, list) else flat.append(payload)
        if self._faults is not None:
            # reorder fence in delivery order, BEFORE the staged batch write:
            # a stale delivery must not reach the model rows at all
            keep: list = []
            for dl in flat:
                if dl._fseq < self._dl_high.get(dl.client_id, -1):
                    self._faults.ledger["stale_downlinks_absorbed"] += 1
                    continue
                self._dl_high[dl.client_id] = dl._fseq
                keep.append(dl)
            flat = keep
            if not flat:
                return
        batched_rows = self._fleet is not None and len(flat) > 1
        if batched_rows:
            self._fleet.set_models(
                [dl.client_id for dl in flat], [dl.params for dl in flat]
            )
        has_clustering = hasattr(strat, "clustering")
        for dl in flat:
            c = self.clients[dl.client_id]
            if batched_rows:
                c.model = dl.params  # row already staged by set_models
                if self._codec is not None:  # anchors refresh per delivery
                    self._codec.install(dl.client_id, dl.params)
            else:
                self._set_model(c, dl.params)
            c.base_version = dl.version
            c.cluster_id = dl.cluster_id
            if has_clustering and dl.cluster_id in strat.clustering.clusters:
                c.partial_finetune = (
                    dl.client_id in strat.clustering.clusters[dl.cluster_id].partial_finetune
                )

    # ------------------------------------------------------------- sync run
    def run_sync(self, *, rounds: int = 50, max_time: float | None = None) -> SimReport:
        """Round-barrier loop for synchronous strategies (FedAvg, Oort,
        ClusterFL with per-cluster barriers, Standalone)."""
        strat = self.strategy
        init = strat.initial_models(sorted(self.clients))
        nbytes = model_bytes(next(iter(init.values())))
        self._template = next(iter(init.values()))
        self._ensure_fleet(self._template)
        t = 0.0
        if self._codec is not None:
            self._codec.seed(init)
        for cid, params in init.items():
            self._set_model(self.clients[cid], params)
        t += nbytes / self.net.downstream_bps
        self.net.download(nbytes * len(init), 0.0)

        next_eval = self.eval_interval
        groups_time = {g: t for g in strat.groups(sorted(self.clients))}
        rounds_done = 0  # rounds=0 must return a zero-round report, not crash
        for rnd in range(rounds):
            # each group (one global group, or one per cluster) runs its own barrier
            for group_id, members in strat.groups(sorted(self.clients)).items():
                t0 = groups_time.get(group_id, t)
                selected = strat.select(group_id, members, rnd)
                if not selected:
                    continue
                finish_times = {}
                uploads = {}
                encoded: dict[Any, Any] = {}
                if self._fleet is not None:
                    # the whole cohort's local training is ONE fused launch;
                    # per-client timing/accounting below stays loop-ordered
                    # so the RNG draws and byte counts match the loop path
                    if self._codec is not None:
                        trained, _, vecs = self._fleet.train_cohort(
                            selected, [strat.model_for(cid) for cid in selected],
                            with_vecs=True,
                        )
                        recs, _ = self._codec.encode_rows(selected, vecs)
                        encoded = dict(zip(selected, recs))
                    else:
                        trained, _ = self._fleet.train_cohort(
                            selected, [strat.model_for(cid) for cid in selected]
                        )
                    trained = dict(zip(selected, trained))
                for cid in selected:
                    c = self.clients[cid]
                    if self._fleet is not None:
                        params = trained[cid]
                    else:
                        params, _ = c.local_train(strat.model_for(cid))
                    dur = c.compute_time()
                    if cid in encoded:
                        up_params, nbytes_up, raw = (
                            encoded[cid], self._codec.nbytes, model_bytes(params),
                        )
                    else:
                        up_params, nbytes_up, raw = self._encode_upload(cid, params)
                    up_dur = self.net.upload(nbytes_up, t0 + dur, raw_nbytes=raw)
                    finish_times[cid] = t0 + dur + up_dur
                    uploads[cid] = up_params
                barrier = max(finish_times.values())
                downlinks = strat.finish_round(group_id, uploads, barrier)
                dl_time = 0.0
                for dl in downlinks:
                    dl_time = max(dl_time, self.net.download(model_bytes(dl.params), barrier))
                    c = self.clients[dl.client_id]
                    self._set_model(c, dl.params)
                    c.base_version = dl.version
                groups_time[group_id] = barrier + dl_time
            t = max(groups_time.values())
            rounds_done = rnd + 1
            while t >= next_eval:
                self._evaluate(next_eval)
                next_eval += self.eval_interval
            if max_time and t > max_time:
                break
        extra = strat.stats() if hasattr(strat, "stats") else {}
        extra["rounds"] = rounds_done
        return self._report(t, extra)

    def run(self, **kw) -> SimReport:
        if getattr(self.strategy, "is_synchronous", False):
            return self.run_sync(**{k: v for k, v in kw.items() if k in ("rounds", "max_time")})
        return self.run_async(**{k: v for k, v in kw.items() if k in ("max_time", "max_uploads")})
