"""Poison-resilient ingest: the update guard and quarantine ledger.

PR 9 made the transport layer hostile-but-survivable; the value path was
still fully trusting — nothing checked an incoming delta before
``assign_and_lerp`` blended it into a shared cluster center, so one NaN,
Inf, or magnitude-blown upload (bitflips, broken quantization,
adversarial clients — Papaya's production failure modes in PAPERS.md)
corrupted the center, and EchoPFL's own on-demand broadcast then
amplified the blast radius to every cluster member, the predictor's
change/gap statistics, and the chi2 feedback loop.

:class:`IngestGuard` closes the value path. Per delivered upload it
scores three host-side statistics and accepts or rejects *before* the
strategy sees the payload:

* **finite mask** — any NaN/Inf coordinate is an unconditional reject;
* **L2 norm** of the uploaded vector — catches magnitude blowups
  (``REPRO_FAULT_POISON_SCALE``) against a robust per-cluster bound;
* **L1 distance to the client's current cluster center** — catches
  direction attacks (``REPRO_FAULT_POISON_SIGN``: a sign-flipped model
  has the *same* norm but lands far from every center). Checked (and
  recorded) only when the client's cluster home is unchanged since its
  last accepted upload: right after a reassignment or merge a client is
  legitimately far from a center whose history it never fed, so the
  distance gate waits one settled round instead of false-positive
  striking honest movers.

Thresholds are robust running statistics per cluster: the median and
MAD (median absolute deviation) over the last ``window`` *accepted*
values, with the bound ``med + k * max(1.4826 * mad, rel_floor * med)``.
Rejected values never enter the history, so a poisoning client cannot
drag the threshold toward its own uploads. A ``grace`` cold-start
window accepts unconditionally-finite uploads until each cluster has
enough history for the median to mean anything (non-finite uploads are
rejected even during grace — NaN needs no statistics).

Escalation: every rejection is a strike. At ``quarantine_strikes`` the
client enters persistent quarantine (uploads keep billing bytes — the
transport already spent them — but are auto-rejected and ledgered); at
``evict_strikes`` the simulator retires the client entirely through the
same eviction path device death uses, reclaiming its plane rows.

Late detection — center rollback
--------------------------------
A poison can slip a finite, modest-norm corruption past the per-upload
gate (or the guard can be attached with poison already blended in). As
a second line the server checks the *post-blend center norm*, computed
inside the existing fused ``ingest_chain`` launch (``with_stats`` adds
one scalar per step to the already-synced stats vector — no extra
launches or host syncs), against the same MAD discipline via
:meth:`IngestGuard.center_ok`. A failed check rolls the cluster center
back to the last-known-good snapshot ring entry
(:meth:`~repro.core.clustering.Cluster.rollback`) and re-broadcasts on
demand — recovery is just another EchoPFL broadcast with staleness
accounting, not a new protocol.

Determinism contract
--------------------
``REPRO_GUARD=off`` (the default) constructs nothing: the simulator
holds ``guard=None``, every hook is behind an ``is None`` check, and
trajectories are bitwise-identical to the pre-guard code. ``on`` over a
clean run is all-accept by construction (stats ride existing launches
and syncs; thresholds live on host and are generous multiples of the
robust spread), so clean guard-on trajectories are *also*
bitwise-identical — the guard only ever changes a run that a poison
would otherwise have corrupted. tests/test_guard.py pins both.

Knobs: ``REPRO_GUARD`` (``off``/``on``); thresholds are code defaults
on :class:`GuardConfig` (constructor-overridable, not env-mapped — the
env switch is the contract surface, the statistics are implementation).
"""
from __future__ import annotations

import dataclasses
import math
import os
from collections import deque
from typing import Any

import numpy as np

__all__ = [
    "GuardConfig",
    "IngestGuard",
    "guard_enabled",
    "resolve_guard",
]


def guard_enabled() -> bool:
    """``REPRO_GUARD`` ambient switch (``1``/``on`` enables)."""
    return os.environ.get("REPRO_GUARD", "").strip().lower() in ("1", "on", "true", "yes")


@dataclasses.dataclass
class GuardConfig:
    """Robust-threshold + escalation parameters (see module docstring)."""

    grace: int = 8  # accepted finite uploads per cluster before bounds engage
    window: int = 64  # history length per cluster for median/MAD
    k: float = 12.0  # bound = med + k * max(1.4826*mad, rel_floor*med)
    rel_floor: float = 1.0  # spread floor relative to the median
    quarantine_strikes: int = 3
    evict_strikes: int = 6
    snapshot_ring: int = 2  # last-known-good center snapshots per cluster

    def __post_init__(self):
        for name in ("grace", "window", "quarantine_strikes", "evict_strikes",
                     "snapshot_ring"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"{name} must be >= 0, got {v!r}")
        if self.evict_strikes < self.quarantine_strikes:
            raise ValueError(
                "evict_strikes must be >= quarantine_strikes, got "
                f"{self.evict_strikes} < {self.quarantine_strikes}")
        for name in ("k", "rel_floor"):
            if getattr(self, name) <= 0.0:
                raise ValueError(f"{name} must be > 0, got {getattr(self, name)!r}")


def resolve_guard(spec: Any = None) -> GuardConfig | None:
    """Normalize the simulator's ``guard=`` argument.

    ``None`` consults ``REPRO_GUARD`` (ambient default); ``"off"``
    forces the guard away regardless of the environment; ``"on"`` or a
    :class:`GuardConfig` enables it. Returns ``None`` when disabled —
    the simulator then constructs nothing and every guard hook is inert."""
    if spec is None:
        return GuardConfig() if guard_enabled() else None
    if isinstance(spec, str):
        low = spec.strip().lower()
        if low in ("", "0", "off", "none", "no"):
            return None
        if low in ("1", "on", "true", "yes"):
            return GuardConfig()
        raise ValueError(f"guard spec must be on|off or a GuardConfig; got {spec!r}")
    if isinstance(spec, GuardConfig):
        return spec
    raise ValueError(f"guard spec must be on|off or a GuardConfig; got {spec!r}")


def _leaves(tree: Any) -> list[np.ndarray]:
    """Host-numpy leaves of a pytree without importing jax here: the
    payloads the guard sees are already host numpy views on the
    coalesced path; the per-event path pays one ``np.asarray`` sync."""
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _robust_bound(hist: deque, k: float, rel_floor: float) -> float:
    vals = np.asarray(hist, dtype=np.float64)
    med = float(np.median(vals))
    mad = float(np.median(np.abs(vals - med)))
    spread = max(1.4826 * mad, rel_floor * abs(med), 1e-12)
    return med + k * spread


class IngestGuard:
    """Per-upload accept/reject + strike escalation + rollback bookkeeping.

    One guard lives per :class:`~repro.fl.simulator.Simulator` run; the
    simulator consults it at the single upload funnel both async loops
    share, and the server consults :meth:`center_ok` after each blend.
    All state is host-side Python/numpy — nothing here touches a device."""

    def __init__(self, cfg: GuardConfig | None = None):
        self.cfg = cfg or GuardConfig()
        self._norm_hist: dict[Any, deque] = {}
        self._dist_hist: dict[Any, deque] = {}
        self._center_hist: dict[Any, deque] = {}
        self._last_home: dict[Any, Any] = {}  # cid -> cluster at last accept
        self._strikes: dict[Any, int] = {}
        self.quarantined: set = set()
        self.evicted: set = set()
        self.ledger: dict[str, Any] = {
            "accepted": 0,
            "rejected_nonfinite": 0,
            "rejected_norm": 0,
            "rejected_dist": 0,
            "rejected_quarantined": 0,
            "rollbacks": 0,
            "quarantined_clients": 0,
            "evicted_clients": 0,
        }

    # ------------------------------------------------------------- stats
    def upload_stats(self, update: Any, center: Any | None) -> tuple[bool, float, float]:
        """``(finite, l2_norm, l1_dist_to_center)`` of an upload, in host
        numpy (float64 accumulation so the stats themselves can't
        overflow on a poisoned payload). ``center=None`` (no cluster
        yet) reports ``dist = 0`` — the norm and finite gates still apply."""
        sq = 0.0
        dist = 0.0
        finite = True
        c_leaves = _leaves(center) if center is not None else None
        for i, u in enumerate(_leaves(update)):
            u64 = u.astype(np.float64, copy=False)
            if finite and not bool(np.all(np.isfinite(u64))):
                finite = False
            sq += float(np.sum(u64 * u64))
            if c_leaves is not None:
                dist += float(np.sum(np.abs(u64 - c_leaves[i].astype(np.float64, copy=False))))
        l2 = math.sqrt(sq) if math.isfinite(sq) else float("inf")
        if not finite:
            l2 = float("inf")
            dist = float("inf")
        return finite, l2, dist

    # ---------------------------------------------------------- decision
    def check_upload(self, cid: Any, cluster_key: Any, finite: bool,
                     l2: float, dist: float) -> str:
        """Gate one delivered upload. Returns ``accept`` or a reject
        reason (``nonfinite``/``norm``/``dist``/``quarantined``).
        Accepted stats enter the per-cluster history; every reject is a
        strike that escalates to quarantine then (via
        :meth:`should_evict`) eviction."""
        if cid in self.quarantined:
            self.ledger["rejected_quarantined"] += 1
            self._strike(cid)
            return "quarantined"
        if not finite:
            return self._reject(cid, "nonfinite")
        nh = self._norm_hist.setdefault(cluster_key, deque(maxlen=self.cfg.window))
        dh = self._dist_hist.setdefault(cluster_key, deque(maxlen=self.cfg.window))
        if nh and len(nh) >= self.cfg.grace and l2 > _robust_bound(nh, self.cfg.k, self.cfg.rel_floor):
            return self._reject(cid, "norm")
        # the distance statistic only means something for a *settled*
        # member: right after a reassignment or merge the client is
        # legitimately far from a center whose history it never fed, so
        # the check (and the history append) waits one accepted round
        stable = self._last_home.get(cid) == cluster_key
        if (stable and dh and len(dh) >= self.cfg.grace
                and dist > _robust_bound(dh, self.cfg.k, self.cfg.rel_floor)):
            return self._reject(cid, "dist")
        nh.append(l2)
        if stable:
            dh.append(dist)
        self._last_home[cid] = cluster_key
        self.ledger["accepted"] += 1
        return "accept"

    def _reject(self, cid: Any, reason: str) -> str:
        self.ledger[f"rejected_{reason}"] += 1
        self._strike(cid)
        return reason

    def _strike(self, cid: Any) -> None:
        n = self._strikes.get(cid, 0) + 1
        self._strikes[cid] = n
        if n >= self.cfg.quarantine_strikes and cid not in self.quarantined:
            self.quarantined.add(cid)
            self.ledger["quarantined_clients"] += 1

    def should_evict(self, cid: Any) -> bool:
        """True exactly once, when the strike count crosses the eviction
        bar — the simulator then retires the client through the same
        path permanent device death uses."""
        if cid in self.evicted:
            return False
        if self._strikes.get(cid, 0) >= self.cfg.evict_strikes:
            self.evicted.add(cid)
            self.ledger["evicted_clients"] += 1
            return True
        return False

    # ----------------------------------------------------- late detection
    def center_ok(self, cluster_key: Any, cnorm: float) -> bool:
        """Post-blend check on a cluster center's L1 norm (computed
        inside the fused ingest launch and synced with the stats the
        server already pulls). NaN/Inf or a MAD-bound blowout vetoes the
        blend — the caller rolls the center back. Healthy norms enter
        the per-cluster history."""
        v = float(cnorm)
        if not math.isfinite(v):
            return False
        hist = self._center_hist.setdefault(cluster_key, deque(maxlen=self.cfg.window))
        if hist and len(hist) >= self.cfg.grace and v > _robust_bound(hist, self.cfg.k, self.cfg.rel_floor):
            return False
        hist.append(v)
        return True

    def note_rollback(self) -> None:
        self.ledger["rollbacks"] += 1

    # ------------------------------------------------------------ ledger
    def ledger_snapshot(self) -> dict:
        out = dict(self.ledger)
        out["quarantined"] = sorted(map(repr, self.quarantined))
        out["evicted"] = sorted(map(repr, self.evicted))
        out["strikes"] = sum(self._strikes.values())
        return out
