"""Experiment wiring: task + device fleet + strategy -> Simulator.

This is the single entry point the benchmarks, examples and tests use, so
every paper table compares strategies under identical data partitions,
device mixes and network conditions.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.baselines import ClusterFL, FedAsyn, FedAvg, FedSEA, Oort, Standalone
from repro.configs.paper_tasks import PAPER_TASKS
from repro.core.client import SimClient
from repro.core.server import EchoPFLServer
from repro.data.synthetic import make_task
from repro.fl.devices import PAPER_SIM_MIX, make_device_fleet
from repro.fl.network import NetworkModel
from repro.fl.simulator import Simulator
from repro.fl.tasks import MLP_TASK, default_task

PyTree = Any


def build_clients(
    task_name: str,
    num_clients: int,
    seed: int = 0,
    latent_clusters: int = 4,
    device_mix: dict | None = None,
    base_round_time: float = 30.0,
    samples_per_client: int = 96,
    local_epochs: int = 5,
):
    rng = np.random.default_rng(seed)
    task = make_task(
        task_name, num_clients, rng,
        latent_clusters=latent_clusters, samples_per_client=samples_per_client,
    )
    fleet = make_device_fleet(num_clients, rng, device_mix or PAPER_SIM_MIX, base_round_time)
    cfg = PAPER_TASKS[task_name]
    init_params = MLP_TASK.init_params(jax.random.PRNGKey(seed), cfg)
    clients = [
        SimClient(
            client_id=i,
            data=task.clients[i],
            num_classes=cfg.num_classes,
            device_class=fleet[i]["class"],
            round_time_fn=fleet[i]["round_time"],
            local_epochs=local_epochs,
        )
        for i in range(num_clients)
    ]
    return task, clients, init_params


def build_strategy(
    name: str,
    init_params: PyTree,
    clients: list[SimClient],
    *,
    seed: int = 0,
    num_clusters: int = 2,
    hm: float = 2.0,
    mix_rate: float = 0.25,
    enable_clustering: bool = True,
    enable_broadcast: bool = True,
    sync_interval: float = 120.0,
    plane_backend: str | None = None,
):
    sizes = {c.client_id: c.data.n for c in clients}
    by_id = {c.client_id: c for c in clients}
    if name == "echopfl":
        def feedback_fn(client_id, center):
            return by_id[client_id].feedback_inputs(center)

        def local_train_fn(center):
            # Algorithm 1 posterior pass: one epoch on a random member's data
            member = by_id[int(np.random.default_rng(seed).choice(sorted(by_id)))]
            trained, _ = member.local_train(center)
            return trained

        return EchoPFLServer(
            init_params,
            num_initial_clusters=num_clusters,
            hm=hm,
            mix_rate=mix_rate,
            feedback_fn=feedback_fn,
            local_train_fn=local_train_fn,
            enable_clustering=enable_clustering,
            enable_broadcast=enable_broadcast,
            plane_backend=plane_backend,
            seed=seed,
        )
    if name == "fedavg":
        return FedAvg(init_params, sizes)
    if name == "fedasyn":
        return FedAsyn(init_params)
    if name == "fedsea":
        return FedSEA(init_params, sync_interval=sync_interval)
    if name == "clusterfl":
        return ClusterFL(init_params, sizes, num_clusters=max(num_clusters, 4), seed=seed)
    if name == "oort":
        hints = {c.client_id: np.mean([c.round_time_fn() for _ in range(3)]) for c in clients}
        return Oort(init_params, sizes, hints, seed=seed)
    if name == "standalone":
        return Standalone(init_params)
    raise KeyError(name)


def run_experiment(
    task_name: str,
    strategy_name: str,
    *,
    num_clients: int = 20,
    seed: int = 0,
    max_time: float = 3600.0,
    rounds: int = 40,
    target_acc: float = 0.85,
    eval_interval: float = 60.0,
    network: NetworkModel | None = None,
    latent_clusters: int = 4,
    device_mix: dict | None = None,
    samples_per_client: int = 96,
    local_epochs: int = 5,
    base_round_time: float = 30.0,
    client_backend: str | None = None,
    uplink: Any | None = None,
    **strategy_kw,
):
    if default_task().name == "lm":
        # REPRO_TASK=lm swaps the whole workload: token streams + LoRA/head
        # deltas over a frozen transformer base instead of the synthetic
        # MLP task. ``task_name`` (a PAPER_TASKS data recipe) does not
        # apply there; the LM driver owns its data pipeline.
        from repro.fl.lm_task import run_lm_experiment

        return run_lm_experiment(
            strategy_name, num_clients=num_clients, seed=seed,
            max_time=max_time, rounds=rounds, eval_interval=eval_interval,
            network=network, local_epochs=local_epochs,
            base_round_time=base_round_time, client_backend=client_backend,
            uplink=uplink, **strategy_kw,
        )
    task, clients, init_params = build_clients(
        task_name, num_clients, seed=seed, latent_clusters=latent_clusters,
        device_mix=device_mix, samples_per_client=samples_per_client,
        local_epochs=local_epochs, base_round_time=base_round_time,
    )
    strategy = build_strategy(strategy_name, init_params, clients, seed=seed, **strategy_kw)
    sim = Simulator(
        clients, strategy,
        network=network or NetworkModel(),
        eval_interval=eval_interval, target_acc=target_acc, seed=seed,
        client_backend=client_backend, uplink=uplink,
    )
    report = sim.run(max_time=max_time, rounds=rounds)
    report.extra["task"] = task_name
    report.extra["latent_clusters"] = {c.client_id: c.data.latent_cluster for c in clients}
    return task, clients, strategy, report
