"""Asymmetric-bandwidth wireless network model.

The paper's systems observation: downstream can be ~10x upstream in 5G
[Chen & Zhao 2014]. Broadcast rides the fat downstream link, uploads cross
the thin upstream link. This model tracks per-direction byte totals and a
time series (for the communication-peak experiment, Fig. 10).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class NetworkModel:
    upstream_bps: float = 10e6 * 8 / 8  # 10 MB/s
    downstream_bps: float = 100e6 * 8 / 8  # 100 MB/s (10x asymmetry)
    bin_seconds: float = 60.0

    def __post_init__(self):
        self.up_bytes = 0
        self.up_raw_bytes = 0  # dense-equivalent uplink bytes (compression ratio)
        self.up_retry_bytes = 0  # retry-attributable uplink bytes (fault layer)
        self.down_bytes = 0
        self.up_events = 0
        self.down_events = 0
        self._up_series: dict[int, float] = defaultdict(float)
        self._down_series: dict[int, float] = defaultdict(float)

    @staticmethod
    def _check_bytes(nbytes: int) -> None:
        if nbytes < 0:
            raise ValueError(f"byte count must be >= 0, got {nbytes}")

    def upload(self, nbytes: int, t: float, raw_nbytes: int | None = None, retry: bool = False) -> float:
        """Register an upload starting at t; returns transfer duration.

        ``nbytes`` is what actually crosses the thin link (the compressed
        payload when an uplink codec is active) and drives ALL billing —
        totals, the per-bin series, the transfer duration. ``raw_nbytes``
        is the dense size of the same model payload, tracked separately so
        reports can state the achieved compression ratio; it defaults to
        ``nbytes`` (uncompressed uploads). ``retry`` marks the transfer as
        retry-attributable (a re-send after a loss/timeout, or a duplicate
        retransmission): it bills identically but is also accumulated in
        ``up_retry_bytes`` so reports can state the fault overhead."""
        self._check_bytes(nbytes)
        if raw_nbytes is not None:
            self._check_bytes(raw_nbytes)
        self.up_bytes += nbytes
        self.up_raw_bytes += nbytes if raw_nbytes is None else raw_nbytes
        if retry:
            self.up_retry_bytes += nbytes
        self.up_events += 1
        self._up_series[int(t // self.bin_seconds)] += nbytes
        return nbytes / self.upstream_bps

    def download(self, nbytes: int, t: float) -> float:
        self._check_bytes(nbytes)
        self.down_bytes += nbytes
        self.down_events += 1
        self._down_series[int(t // self.bin_seconds)] += nbytes
        return nbytes / self.downstream_bps

    def download_bulk(self, nbytes: int, count: int, t: float) -> float:
        """Bill ``count`` equal-size downloads starting at ``t`` in one call
        (a broadcast's whole fan-out): byte totals, event counts, and the
        per-bin series land exactly as ``count`` ``download`` calls would
        (the per-bin sum adds integer byte counts, exact in float64), and
        the shared transfer duration is returned once."""
        self._check_bytes(nbytes)
        if count <= 0:
            raise ValueError(f"download_bulk count must be >= 1, got {count}")
        self.down_bytes += nbytes * count
        self.down_events += count
        self._down_series[int(t // self.bin_seconds)] += nbytes * count
        return nbytes / self.downstream_bps

    def _series_for(self, direction: str) -> dict[int, float]:
        if direction == "down":
            return self._down_series
        if direction == "up":
            return self._up_series
        raise ValueError(
            f"unknown direction {direction!r}: expected 'up' or 'down'"
        )

    def peak(self, direction: str = "down") -> float:
        return max(self._series_for(direction).values(), default=0.0)

    def series(self, direction: str = "down") -> dict[int, float]:
        return dict(self._series_for(direction))

    @property
    def total_bytes(self) -> int:
        return self.up_bytes + self.down_bytes
