"""Device-resident client fleet engine: the *client* side of the simulator
as batched matrix compute.

PRs 1-2 made the server hot path device-resident (the parameter plane);
this module does the same for the simulated devices. The seed simulator
dispatched one ``_sgd_epoch`` jit call per client per epoch, one
``evaluate`` launch per client per eval tick, and one
``predict_distributions`` probe per (member, center) feedback pair —
O(clients) Python-loop dispatches for work that is embarrassingly
batchable. The fleet engine replaces those loops with three fused
launches:

* :meth:`ClientFleet.train_cohort` / :meth:`ClientFleet.train_client` —
  ``jax.vmap`` over clients of a ``lax.scan`` over epochs (the task's
  ``fleet_local_train``). Per-client ``lr`` / ``epochs`` / ``head_only``
  are vmapped operands, so heterogeneous epoch budgets and partial
  fine-tuning stay per-row.
* :meth:`ClientFleet.evaluate_fleet` — one masked-accuracy launch for the
  whole fleet per eval tick.
* :meth:`ClientFleet.feedback_many` — batched ``predict_distributions``
  emitting ``(pairs, num_classes)`` F/S stacks that feed the server's
  ``chi2_feedback_all`` kernel directly.

State layout mirrors the server plane: every client's current model is a
row of a second :class:`~repro.core.plane.ParameterPlane` (a non-cluster
row namespace), and each client additionally owns an *evaluation-view* row
holding the last parameters it was evaluated with — refreshed only when
the strategy hands a different object, so the per-tick eval gather is the
plane's incrementally-patched cached view (O(changed rows), not O(fleet)).
Per-client train/test data pads into ``(clients, n, dim)`` device tensors
with validity masks; ragged datasets are handled by masking, which keeps
padded rows out of losses, accuracies, and histograms. A replaced
``SimClient.data`` (distribution drift) is detected by identity check at
every launch and triggers a tensor rebuild, matching the loop backend's
live-read semantics.

Cohort launches pad to the next power of two (extra rows get a zero epoch
budget), so the jit cache holds O(log clients) entries instead of one per
cohort size, and the dispatch count stays flat as the fleet grows.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import FlattenSpec, flatten_spec
from repro.core.plane import ParameterPlane
from repro.fl.tasks import MLP_TASK

PyTree = Any


def _pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("spec", "max_epochs", "task"))
def _train_launch(mat, train, gather, lr, epochs, head, *,
                  spec: FlattenSpec, max_epochs: int, task):
    # the cohort's data-row gather happens inside the launch, fused with the
    # training compute — no materialized (P, n, ...) copies per round. The
    # whole train dict is gathered; tensors the task never reads (e.g. the
    # MLP feedback path ignoring labels) are pruned by XLA DCE.
    d = {k: v[gather] for k, v in train.items()}
    params_b = jax.vmap(spec._unflatten)(mat)
    new_b, losses = task.fleet_local_train(
        params_b, d, lr, epochs, head, max_epochs=max_epochs
    )
    return jax.vmap(spec._flatten)(new_b), losses


@functools.partial(jax.jit, static_argnames=("spec", "max_epochs", "task"))
def _train_launch_bank(bank, sel, train, gather, lr, epochs, head, *,
                       spec: FlattenSpec, max_epochs: int, task):
    # row-sliced variant: the model matrix is gathered from the fleet's
    # model-row bank INSIDE the launch. An eager per-call gather of dozens
    # of scattered plane rows is the slow path on CPU (that is why the
    # plane caches views); in-jit it compiles once and fuses with training.
    return _train_launch.__wrapped__(
        bank[sel], train, gather, lr, epochs, head,
        spec=spec, max_epochs=max_epochs, task=task,
    )


@functools.partial(jax.jit, static_argnames=("spec", "task"))
def _eval_launch(mat, test, *, spec: FlattenSpec, task):
    return task.fleet_evaluate(jax.vmap(spec._unflatten)(mat), test)


@functools.partial(jax.jit, static_argnames=("spec", "num_classes", "task"))
def _feedback_launch(bank, sel, train, gather, *, spec: FlattenSpec,
                     num_classes: int, task):
    # a probe sweep pairs hundreds of members against a handful of DISTINCT
    # centers: the (pairs, dim) probe matrix is expanded from the small
    # center bank inside the launch, never materialized eagerly
    mat = bank[sel]
    d = {k: v[gather] for k, v in train.items()}
    return task.fleet_feedback(jax.vmap(spec._unflatten)(mat), d, num_classes)


class ClientFleet:
    """Batched state + fused launches for a list of :class:`SimClient`s.

    With ``mesh`` (or the ``REPRO_FLEET_MESH`` env knob), the fleet's
    client-model plane AND its ``(clients, n, dim)`` data tensors place
    over the mesh's ``plane`` (row) axis — batched training/eval launches
    then shard over simulated devices the same way the server plane's
    kernels already do, instead of pinning the whole fleet's models and
    datasets to one accelerator. Per-client arithmetic is unchanged (the
    launches are client-wise vmaps), so trajectories do not depend on the
    mesh."""

    def __init__(self, clients: Sequence[Any], template: PyTree, *,
                 mesh: Any | None = None, task: Any | None = None):
        self.clients = list(clients)
        self.ids = [c.client_id for c in self.clients]
        self.index = {cid: i for i, cid in enumerate(self.ids)}
        K = len(self.clients)
        self.num_classes = self.clients[0].num_classes
        # the fleet's task: explicit arg, else the clients' own, else MLP.
        # All clients must share one task (one fused launch per fleet).
        self.task = task or getattr(self.clients[0], "task", None) or MLP_TASK
        self.spec = flatten_spec(template)
        if mesh is None:
            from repro.launch.mesh import fleet_mesh_from_env

            mesh = fleet_mesh_from_env()
        elif mesh is False:
            mesh = None
        if mesh is not None and K % mesh.shape["plane"] != 0:
            # the (clients, n, dim) tensors place with an eager device_put,
            # which (unlike jit outputs) cannot pad a non-divisible leading
            # dim — a fleet that does not divide the row shards runs
            # single-device, like the un-meshed default
            mesh = None
        self.mesh = mesh
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            # (clients, ...) tensors of any rank shard over the row axis
            self._dim_shardings: dict[int, Any] = {}
            self._sharding_of = lambda ndim: self._dim_shardings.setdefault(
                ndim, NamedSharding(mesh, PartitionSpec("plane", *(None,) * (ndim - 1)))
            )
            self._replicated = NamedSharding(mesh, PartitionSpec())
        self.plane = ParameterPlane(template, capacity=2 * K, mesh=mesh)
        self._model_row = [self.plane.alloc() for _ in range(K)]
        self._eval_row = [self.plane.alloc() for _ in range(K)]
        self._has_model = [False] * K
        # monotonic per-client model-row version (bumped on every write), so
        # the eval rows can tell whether a mirrored model row went stale
        self._model_ver = [0] * K
        # what each eval row currently holds: the exact params object last
        # written (identity-compared), or a ("model", version) tag when it
        # mirrors the client's own model row
        self._eval_src: list[Any] = [object()] * K

        self._build_data()
        # pytree -> flat-vector memo, keyed by object identity (the held
        # reference keeps the id stable). Strategies hand the *same* center
        # object to every member, so a broadcast costs one flatten total.
        self._flat_cache: dict[int, tuple[Any, jax.Array]] = {}
        self.launches = 0  # fused launches issued (bench introspection)

    # ----------------------------------------------------------- data plane
    def _shard_clients(self, x: jax.Array) -> jax.Array:
        """Place a (clients, ...) tensor sharded over the fleet mesh's row
        axis (no-op without a mesh)."""
        if self.mesh is None:
            return x
        return jax.device_put(x, self._sharding_of(x.ndim))

    def _rep(self, x) -> jax.Array:
        """Replicate a small launch operand (a stacked model matrix, gather
        indices, per-row hyperparams) over the fleet mesh so it can share a
        jit with the client-sharded data tensors (no-op without a mesh)."""
        if self.mesh is None:
            return jnp.asarray(x)
        return jax.device_put(jnp.asarray(x), self._replicated)

    def _build_data(self) -> None:
        """(Re)pad every client's train/test split into the task's batched
        device tensors, and cache the true label histograms."""
        self._data_ref = [c.data for c in self.clients]
        fd = self.task.build_fleet_data(
            self._data_ref, self._shard_clients, self.num_classes
        )
        self._train_data = fd.train
        self._test_data = fd.test
        self.f_true = fd.f_true

    def _sync_data(self) -> None:
        """Match the loop backend's live-read semantics: a replaced
        ``SimClient.data`` (distribution drift, Fig. 18 style) triggers a
        rebuild of the batched tensors. The steady-state cost is K identity
        checks per launch; the rebuild itself only runs on an actual swap."""
        for c, ref in zip(self.clients, self._data_ref):
            if c.data is not ref:
                self._build_data()
                return

    # ------------------------------------------------------------ adapters
    def _vec_of(self, params: PyTree) -> jax.Array:
        if isinstance(params, jax.Array) and params.ndim == 1:
            return params
        key = id(params)
        hit = self._flat_cache.pop(key, None)  # pop + reinsert: LRU on hit
        if hit is not None and hit[0] is params:
            self._flat_cache[key] = hit
            return hit[1]
        vec = self.spec.flatten(params)
        if len(self._flat_cache) >= 512:  # evict the LRU entry only — the
            # hot working set (live centers, the global model) stays cached
            self._flat_cache.pop(next(iter(self._flat_cache)))
        self._flat_cache[key] = (params, vec)
        return vec

    def to_pytree_np(self, vec: np.ndarray) -> PyTree:
        """Host-side unflatten (numpy views, zero device dispatches) for
        fanning a batched training result back out into per-client pytrees."""
        return self.spec.unflatten_np(vec)

    # ------------------------------------------------------------- models
    def set_model(self, cid, params: PyTree) -> None:
        i = self.index[cid]
        self.plane.write(self._model_row[i], self._vec_of(params))
        self._has_model[i] = True
        self._model_ver[i] += 1

    def set_models(self, cids: Sequence[Any], params_list: Sequence[PyTree]) -> None:
        """Install a batch of downlinked models in one staged write: a
        broadcast's fan-out (N downlinks of the SAME center object landing
        at the same virtual time) costs one cached flatten and one
        ``write_rows`` staging entry instead of N row stagings. Duplicate
        clients keep the LAST entry, matching sequential ``set_model``
        overwrite order."""
        latest: dict[int, PyTree] = {}
        for cid, p in zip(cids, params_list):
            latest[self.index[cid]] = p
        rows, vecs = [], []
        for i, p in latest.items():
            rows.append(self._model_row[i])
            vecs.append(self._vec_of(p))
            self._has_model[i] = True
            self._model_ver[i] += 1
        self.plane.write_rows(rows, jnp.stack(vecs))

    def model_vec(self, cid) -> jax.Array:
        i = self.index[cid]
        if not self._has_model[i]:
            # the loop path (SimClient.local_train with model=None) fails
            # loudly too — never train from the zero-seeded row silently
            raise ValueError(f"client {cid} has no model set")
        return self.plane.row(self._model_row[i])

    # ------------------------------------------------------------ training
    def _train_specs(self, cids: Sequence[Any]):
        cs = [self.clients[self.index[c]] for c in cids]
        lr = np.asarray([c.lr for c in cs], np.float32)
        epochs = np.asarray([c.local_epochs for c in cs], np.int32)
        head = np.asarray([1.0 if c.partial_finetune else 0.0 for c in cs], np.float32)
        return lr, epochs, head

    def _train(self, idx: np.ndarray, mat: jax.Array | None, lr, epochs, head, *,
               bank: jax.Array | None = None):
        """Shared padded launch: returns device (S, dim) vecs + (S,) losses.
        ``mat`` is an explicit (S, dim) model matrix; alternatively pass
        ``bank`` (the full model-row view) and the rows ``idx`` select are
        gathered inside the launch."""
        self._sync_data()
        S = len(idx)
        P = _pow2(S)
        if P != S:
            idx = np.concatenate([idx, np.full(P - S, idx[0])])
            if mat is not None:
                mat = jnp.concatenate([mat, jnp.broadcast_to(mat[:1], (P - S, mat.shape[1]))])
            lr = np.concatenate([lr, np.zeros(P - S, np.float32)])
            epochs = np.concatenate([epochs, np.zeros(P - S, np.int32)])  # padded rows train 0 epochs
            head = np.concatenate([head, np.zeros(P - S, np.float32)])
        max_epochs = int(epochs.max()) if len(epochs) else 0
        self.launches += 1
        args = (
            self._train_data,
            self._rep(idx),
            self._rep(lr),
            self._rep(epochs),
            self._rep(head),
        )
        if bank is not None:
            vecs, losses = _train_launch_bank(
                self._rep(bank), self._rep(idx), *args,
                spec=self.spec, max_epochs=max_epochs, task=self.task,
            )
        else:
            vecs, losses = _train_launch(
                self._rep(mat), *args,
                spec=self.spec, max_epochs=max_epochs, task=self.task,
            )
        return vecs[:S], losses[:S]

    def train_cohort(
        self, cids: Sequence[Any], params_list: Sequence[PyTree], *,
        with_vecs: bool = False,
    ):
        """One fused launch of local training for a selected cohort (the
        sync-round path). ``params_list[i]`` is what client ``cids[i]``
        trains from; ``None`` falls back to the client's own model row
        (the same contract as ``SimClient.local_train(None)``). Returns
        (per-client trained pytrees, losses) — plus the device ``(S, dim)``
        trained matrix when ``with_vecs`` is set, so a downstream batched
        consumer (the uplink codec) can launch on it directly instead of
        re-flattening S pytrees."""
        idx = np.asarray([self.index[c] for c in cids])
        mat = jnp.stack([
            self.model_vec(c) if p is None else self._vec_of(p)
            for c, p in zip(cids, params_list)
        ])
        vecs, losses = self._train(idx, mat, *self._train_specs(cids))
        vecs_np, losses_np = jax.device_get((vecs, losses))
        # the per-client leaves are views over this one base matrix: freeze
        # it so an (unsupported) in-place mutation raises, exactly like the
        # immutable jax-array leaves the loop path hands out
        vecs_np = np.asarray(vecs_np)
        vecs_np.flags.writeable = False
        out = [self.to_pytree_np(v) for v in vecs_np], losses_np
        return (*out, vecs) if with_vecs else out

    def train_client(self, cid) -> tuple[PyTree, jax.Array]:
        """Row-sliced single-client path (the async event loop): trains from
        this client's model row, writes the new row back, and returns the
        updated params as a pytree plus the device-scalar loss."""
        i = self.index[cid]
        mat = self.model_vec(cid)[None, :]
        vecs, losses = self._train(np.asarray([i]), mat, *self._train_specs([cid]))
        vec = vecs[0]
        self.plane.write(self._model_row[i], vec)
        self._has_model[i] = True
        self._model_ver[i] += 1
        return self.spec.unflatten(vec), losses[0]

    def train_rows(self, cids: Sequence[Any], *, with_vecs: bool = False):
        """Row-sliced BATCH of the async path: N concurrent ``upload_start``
        events become one fused launch. Every client trains from (and
        writes back) its own model row — exactly N :meth:`train_client`
        calls' arithmetic, since the rows are mutually independent — and
        the trained models come back as host-side numpy-view pytrees plus
        the (N,) losses (and, with ``with_vecs``, the device ``(N, dim)``
        trained matrix for batched downstream consumers like the uplink
        codec). ``cids`` must be distinct (one in-flight local round per
        client, which the event loop guarantees)."""
        idx = np.asarray([self.index[c] for c in cids])
        for c in cids:
            if not self._has_model[self.index[c]]:
                raise ValueError(f"client {c} has no model set")
        # the model-row bank is a hot cached view (downlink writes patch it
        # incrementally); the batch's rows are gathered from it inside the
        # launch — an eager scattered-row gather per window is the slow
        # path on CPU
        bank = self.plane.rows(tuple(self._model_row))
        vecs, losses = self._train(idx, None, *self._train_specs(cids), bank=bank)
        self.plane.write_rows([self._model_row[i] for i in idx], vecs)
        for i in idx:
            self._has_model[i] = True
            self._model_ver[i] += 1
        vecs_np, losses_np = jax.device_get((vecs, losses))
        vecs_np = np.asarray(vecs_np)
        vecs_np.flags.writeable = False  # leaves are views: freeze like train_cohort
        out = [self.to_pytree_np(v) for v in vecs_np], losses_np
        return (*out, vecs) if with_vecs else out

    # ---------------------------------------------------------- evaluation
    def evaluate_fleet(self, params_list: Sequence[PyTree | None]) -> np.ndarray:
        """(K,) accuracies in fleet order, one launch. ``params_list[i]`` is
        the pytree client ``i`` evaluates (identity-cached into its eval
        row); ``None`` falls back to the client's own model row — or 0.0
        when no model was ever set, matching the per-client loop path."""
        self._sync_data()
        plane = self.plane
        zero = np.zeros(len(self.ids), bool)
        refresh_rows: list[int] = []
        refresh_vecs: list[jax.Array] = []
        for i, obj in enumerate(params_list):
            if obj is None:
                if not self._has_model[i]:
                    zero[i] = True
                    continue
                tag = ("model", self._model_ver[i])
                src = self._eval_src[i]
                if not (isinstance(src, tuple) and src == tag):  # mirror stale
                    plane.copy_row(self._model_row[i], self._eval_row[i])
                    self._eval_src[i] = tag
            elif self._eval_src[i] is not obj:
                refresh_rows.append(self._eval_row[i])
                refresh_vecs.append(self._vec_of(obj))
                self._eval_src[i] = obj
        if refresh_rows:
            # one bulk staging entry for the whole refresh (a broadcast can
            # change most of the fleet's eval params in one tick)
            plane.write_rows(refresh_rows, jnp.stack(refresh_vecs))
        # cached view, patched in place (mesh-replicated under a fleet mesh
        # so it can share the launch with the client-sharded data tensors)
        mat = plane.rows(tuple(self._eval_row), on_mesh=self.mesh is not None)
        self.launches += 1
        accs = np.asarray(
            _eval_launch(mat, self._test_data, spec=self.spec, task=self.task)
        )
        if zero.any():
            accs = np.where(zero, 0.0, accs)
        return accs

    # ------------------------------------------------------------ feedback
    def feedback_many(
        self, pairs: Sequence[tuple[Any, PyTree]]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched (member, center) feedback probes: one launch emitting the
        stacked (F_pred, F_true, S_soft) rows the server's segmented chi2
        kernel consumes — a drop-in for ``EchoPFLServer.feedback_batch_fn``."""
        self._sync_data()
        idx = np.asarray([self.index[m] for m, _ in pairs])
        # distinct centers only (a sweep probes every member against the
        # same few cluster centers): stack the small bank, expand in-launch
        bank_ids: dict[int, int] = {}
        bank_vecs: list[jax.Array] = []
        sel = np.empty(len(pairs), np.int32)
        for k, (_, center) in enumerate(pairs):
            key = id(center)
            slot = bank_ids.get(key)
            if slot is None:
                slot = bank_ids[key] = len(bank_vecs)
                bank_vecs.append(self._vec_of(center))
            sel[k] = slot
        B = _pow2(len(bank_vecs))  # pow2-padded bank: O(log centers) jit cache
        bank_vecs += [bank_vecs[0]] * (B - len(bank_vecs))
        bank = jnp.stack(bank_vecs)
        M = len(pairs)
        P = _pow2(M)
        gather = idx
        if P != M:
            gather = np.concatenate([idx, np.full(P - M, idx[0])])
            sel = np.concatenate([sel, np.full(P - M, sel[0], np.int32)])
        self.launches += 1
        f_pred, s_soft = _feedback_launch(
            self._rep(bank), self._rep(sel), self._train_data, self._rep(gather),
            spec=self.spec, num_classes=self.num_classes, task=self.task,
        )
        f_pred, s_soft = jax.device_get((f_pred[:M], s_soft[:M]))
        return np.asarray(f_pred), self.f_true[idx], np.asarray(s_soft)
