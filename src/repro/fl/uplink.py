"""Fleet-batched compressed uplinks: the ``REPRO_UPLINK`` hot path.

EchoPFL's bandwidth asymmetry (thin ~10 MB/s uplink vs fat ~100 MB/s
downlink) makes the *uplink* the communication bottleneck, and the paper's
comm-cost claim (~37% total-bytes reduction) rests on compressing it. The
codecs in :mod:`repro.optim.compression` supply the arithmetic; this module
wires them into the simulator's upload path as batched launches:

* Every client owns an **anchor row** in a dedicated
  :class:`~repro.core.plane.ParameterPlane`: the last model value both
  sides agree on. It is seeded with the initial broadcast, advanced to the
  *reconstruction* of every upload (the server applies exactly the
  decompressed delta, so both ends advance in lockstep), and refreshed to
  every downlinked model the client installs (:meth:`UplinkCodec.install`
  — the server knows what it sent, so this costs zero wire bytes and keeps
  the delta measured against the client's actual training base).
* An upload compresses ``delta = trained - anchor``. Under ``topk`` the
  delta passes through error-feedback top-k, whose residual lives in a
  second per-client plane row (restored by ``load_state`` alongside the
  anchor); under ``int8`` it quantizes with per-chunk scales. Either way
  the reconstruction ``anchor + decompress(payload)`` is handed onward, so
  the server's ingest (``ingest_chain`` / ``handle_uploads``) and the
  broadcast predictor's want-sync statistics see exactly what crossed the
  compressed wire — no ingest-side changes, no second decompression pass.
* A cohort of B concurrent uploads (a coalesced window, a sync round) is
  ONE fused launch: gather the anchor/residual banks in-jit, compress all
  rows, write the updated state back through the plane's staged (donated)
  scatter. B = 1 runs the same launch, so the per-event loop and a
  degenerate coalescing window stay bitwise-identical.
* The payload's exact wire size — int32 indices + f32 values, or int8
  codes + f32 per-chunk scales — depends only on static config, so
  :meth:`UplinkCodec.nbytes` bills every compressed uplink without a
  device sync (``compression.wire_bytes`` == ``payload_bytes`` of the
  emitted payload; the regression tests pin the equality).

Knobs (read at simulator construction; constructor args win):

* ``REPRO_UPLINK`` — ``none`` (default; the uncompressed path, bitwise the
  pre-codec trajectories) | ``topk`` | ``int8``.
* ``REPRO_UPLINK_K`` — top-k budget: a fraction of the flat dim in (0, 1)
  (default ``0.1``) or an absolute count ``>= 1``.
* ``REPRO_UPLINK_CHUNK`` — int8 scale-chunk length (default ``512``),
  clamped to the flat dim.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import flatten_spec
from repro.core.plane import ParameterPlane
from repro.optim.compression import (
    Int8Payload,
    TopKPayload,
    ef_topk_batch,
    int8_compress_batch,
    int8_decompress_batch,
    payload_bytes,
    wire_bytes,
)

PyTree = Any

UPLINK_MODES = ("none", "topk", "int8")


@dataclasses.dataclass(frozen=True)
class UplinkConfig:
    """Static uplink-compression config (mode + codec geometry)."""

    mode: str = "none"
    k: float = 0.1  # topk budget: fraction of dim in (0, 1) or absolute count >= 1
    chunk: int = 512  # int8 per-chunk scale granularity

    def __post_init__(self):
        if self.mode not in UPLINK_MODES:
            raise ValueError(
                f"REPRO_UPLINK mode must be one of {UPLINK_MODES}, got {self.mode!r}"
            )
        if self.k <= 0:
            raise ValueError(f"REPRO_UPLINK_K must be positive, got {self.k}")
        if self.chunk < 1:
            raise ValueError(f"REPRO_UPLINK_CHUNK must be >= 1, got {self.chunk}")

    def resolve_k(self, dim: int) -> int:
        """Concrete per-row keep count for a flat dim: fractions round, both
        forms clamp into [1, dim]."""
        k = self.k * dim if self.k < 1 else self.k
        return max(1, min(dim, int(round(k))))

    def resolve_chunk(self, dim: int) -> int:
        return max(1, min(dim, int(self.chunk)))


def default_uplink() -> str:
    """``REPRO_UPLINK`` knob: ``none`` (uncompressed, the parity default) |
    ``topk`` (EF-top-k deltas) | ``int8`` (per-chunk quantized deltas)."""
    return os.environ.get("REPRO_UPLINK", "none").strip().lower() or "none"


def uplink_config_from_env() -> UplinkConfig:
    return UplinkConfig(
        mode=default_uplink(),
        k=float(os.environ.get("REPRO_UPLINK_K", "0.1")),
        chunk=int(os.environ.get("REPRO_UPLINK_CHUNK", "512")),
    )


def resolve_uplink(spec: Any) -> UplinkConfig:
    """Coerce a constructor argument (None -> env, a mode string, or a full
    :class:`UplinkConfig`) into a validated config."""
    if spec is None:
        return uplink_config_from_env()
    if isinstance(spec, UplinkConfig):
        return spec
    env = uplink_config_from_env()
    return UplinkConfig(mode=str(spec).strip().lower() or "none", k=env.k, chunk=env.chunk)


def _pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("k",))
def _encode_topk(bank_a, bank_r, sel, mat, *, k: int):
    # anchor/residual rows gather from the plane banks INSIDE the launch
    # (cached incrementally-patched views — same economics as the fleet's
    # model-row bank), fused with the EF-top-k compress + reconstruct
    A = bank_a[sel]
    _idx, _vals, sent, new_r = ef_topk_batch(mat - A, bank_r[sel], k)
    return A + sent, new_r


@functools.partial(jax.jit, static_argnames=("chunk",))
def _encode_int8(bank_a, sel, mat, *, chunk: int):
    A = bank_a[sel]
    q, scales = int8_compress_batch(mat - A, chunk)
    return A + int8_decompress_batch(q, scales, chunk)


class UplinkCodec:
    """Per-client uplink compression state + one-launch cohort encoding.

    Owns a dedicated :class:`ParameterPlane` whose rows are each client's
    anchor (and, under ``topk``, EF residual). :meth:`encode_vecs` is the
    single entry point: compress a ``(B, dim)`` cohort of trained models
    against their anchors, advance the state rows, and hand back the
    reconstructed uploads the server ingests — one fused launch regardless
    of B. The strategy adopting the codec (``attach_uplink_codec``) carries
    its rows through ``state_dict``/``load_state`` checkpoints."""

    def __init__(self, template: PyTree, client_ids: Sequence[Any], config: UplinkConfig):
        if config.mode == "none":
            raise ValueError("UplinkCodec requires mode topk|int8 (none means no codec)")
        self.config = config
        self.mode = config.mode
        self.spec = flatten_spec(template)
        self.dim = self.spec.dim
        self.k = config.resolve_k(self.dim)
        self.chunk = config.resolve_chunk(self.dim)
        self.ids = list(client_ids)
        self.index = {cid: i for i, cid in enumerate(self.ids)}
        K = len(self.ids)
        self.plane = ParameterPlane(template, capacity=(2 * K if self.mode == "topk" else K))
        self._anchor_row = self.plane.alloc_many(K)
        self._resid_row = self.plane.alloc_many(K) if self.mode == "topk" else None
        self._seeded = [False] * K
        self._released = [False] * K  # evicted clients: rows returned to the plane
        self._install_memo: tuple[Any, Any] = (None, None)  # (params obj, flat vec)
        self._zero_vec = jnp.zeros((self.dim,), self.plane.dtype)
        self.launches = 0  # fused encode launches issued (bench introspection)
        # exact wire size of ONE compressed upload — static config only, and
        # pinned equal to payload_bytes() of the emitted payload shape
        self.nbytes = wire_bytes(self.mode, self.dim, k=self.k, chunk=self.chunk)
        assert self.nbytes == payload_bytes(self.payload_template())

    def payload_template(self):
        """A zero payload with the exact shapes/dtypes every upload ships —
        the byte-accounting tests feed this to ``payload_bytes``."""
        if self.mode == "topk":
            return TopKPayload(
                indices=np.zeros(self.k, np.int32),
                values=np.zeros(self.k, np.float32),
                length=self.dim,
            )
        n_chunks = -(-self.dim // self.chunk)
        return Int8Payload(
            q=np.zeros(self.dim, np.int8),
            scales=np.zeros(n_chunks, np.float32),
            chunk=self.chunk,
        )

    # -------------------------------------------------------------- seeding
    def seed(self, models: dict[Any, PyTree]) -> None:
        """Install initial anchors from a broadcast both sides saw (the run
        start's ``initial_models``). Clients whose anchors already exist —
        restored from a checkpoint, or seeded by an earlier run — are left
        untouched, so a restart never clobbers live codec state."""
        by_obj: dict[int, jax.Array] = {}  # a broadcast fans one object: flatten once
        rows, vecs = [], []
        for cid, params in models.items():
            i = self.index.get(cid)
            if i is None or self._seeded[i] or self._released[i]:
                continue
            key = id(params)
            vec = by_obj.get(key)
            if vec is None:
                vec = by_obj[key] = self.spec.flatten(params)
            rows.append(self._anchor_row[i])
            vecs.append(vec)
            self._seeded[i] = True
        if rows:
            self.plane.write_rows(rows, jnp.stack(vecs))

    def install(self, cid, params: PyTree) -> None:
        """Advance a client's anchor to a just-downlinked model — a value
        both sides agree on (the server sent it, the client installed it),
        at zero wire cost. Without this the anchor would trail the last
        upload's reconstruction while the client trains from fresher
        downlinks, and the growing ``trained - anchor`` delta would swamp a
        top-k budget (EF residual blow-up on unicast-heavy strategies).
        The EF residual is DROPPED with the old anchor: it carried delta
        mass measured against a base the downlink just superseded, and in
        model-delta space (clients re-train toward the same displacement
        every round) re-adding it double-counts — the corrected vector
        grows linearly and the reconstruction overshoots until divergence.
        Error feedback therefore spans exactly the uploads *between* two
        downlinks. A broadcast fans ONE object at many clients, so
        consecutive installs of the same pytree share a single flatten."""
        i = self.index.get(cid)
        if i is None or self._released[i]:
            return
        obj, vec = self._install_memo
        if obj is not params:
            vec = self.spec.flatten(params)
            self._install_memo = (params, vec)
        self.plane.write(self._anchor_row[i], vec)
        if self._resid_row is not None:
            self.plane.write(self._resid_row[i], self._zero_vec)
        self._seeded[i] = True

    def release_client(self, cid) -> None:
        """Free a dead/evicted client's codec rows (anchor + EF residual)
        back to the plane. ``evict_clients`` calls this alongside the
        server-side reclamation — without it every death leaked
        ``1 + (mode == topk)`` rows of codec state for the rest of the
        run. Idempotent; released clients drop out of seeding, installs,
        checkpoints, and the encode bank gather."""
        i = self.index.get(cid)
        if i is None or self._released[i]:
            return
        self.plane.free(self._anchor_row[i])
        if self._resid_row is not None:
            self.plane.free(self._resid_row[i])
        self._released[i] = True
        self._seeded[i] = False

    # ------------------------------------------------------------- encoding
    def _bank_rows(self, rows: Sequence[int]) -> tuple[int, ...]:
        """Bank-gather row tuple with released clients' entries redirected
        to a live stand-in row: a released client never uploads again, so
        its entry is never selected — the stand-in only keeps the gather
        off freed (re-allocatable) plane rows while the bank keeps its
        stable shape and cache key."""
        if not any(self._released):
            return tuple(rows)
        stand_in = next(
            (r for r, dead in zip(rows, self._released) if not dead), rows[0]
        )
        return tuple(
            stand_in if dead else r for r, dead in zip(rows, self._released)
        )
    def encode_vecs(self, cids: Sequence[Any], mat) -> np.ndarray:
        """ONE fused launch: compress ``mat[i]`` (client ``cids[i]``'s
        trained flat model) against its anchor, advance anchor/residual
        rows, and return the ``(B, dim)`` reconstructed uploads as a frozen
        host matrix. ``cids`` must be distinct (one in-flight round per
        client — the event loop's invariant). Cohorts pad to the next power
        of two (padding rows recompute row 0 and are dropped), so the jit
        cache stays O(log fleet)."""
        idx = [self.index[c] for c in cids]
        for c, i in zip(cids, idx):
            if self._released[i]:
                raise ValueError(f"client {c}'s uplink codec rows were released")
            if not self._seeded[i]:
                raise ValueError(f"client {c} has no uplink anchor seeded")
        B = len(idx)
        P = _pow2(B)
        sel = np.asarray(idx + [idx[0]] * (P - B), np.int32)
        mat = jnp.asarray(mat, self.plane.dtype)
        if P != B:
            mat = jnp.concatenate([mat, jnp.broadcast_to(mat[:1], (P - B, mat.shape[1]))])
        bank_a = self.plane.rows(self._bank_rows(self._anchor_row))
        self.launches += 1
        if self.mode == "topk":
            bank_r = self.plane.rows(self._bank_rows(self._resid_row))
            rec, new_r = _encode_topk(bank_a, bank_r, sel, mat, k=self.k)
            rec = rec[:B]
            rows = [self._resid_row[i] for i in idx] + [self._anchor_row[i] for i in idx]
            self.plane.write_rows(rows, jnp.concatenate([new_r[:B], rec], axis=0))
        else:
            rec = _encode_int8(bank_a, sel, mat, chunk=self.chunk)[:B]
            self.plane.write_rows([self._anchor_row[i] for i in idx], rec)
        rec_np = np.asarray(jax.device_get(rec))
        # the reconstructed pytrees hand out views over this matrix: freeze
        # it so an (unsupported) in-place mutation raises, like fleet outputs
        rec_np.flags.writeable = False
        return rec_np

    def encode_rows(self, cids: Sequence[Any], mat) -> tuple[list[PyTree], int]:
        """Cohort form: reconstructed per-client pytrees (numpy views over
        one matrix) + the per-upload wire bytes."""
        rec = self.encode_vecs(cids, mat)
        return [self.spec.unflatten_np(v) for v in rec], self.nbytes

    def encode(self, cid, params: PyTree) -> tuple[PyTree, int]:
        """Single-upload form (the per-event loop): same launch at B = 1."""
        vec = params if isinstance(params, jax.Array) and params.ndim == 1 else self.spec.flatten(params)
        rec = self.encode_vecs([cid], vec[None, :])
        return self.spec.unflatten_np(rec[0]), self.nbytes

    # ------------------------------------------------ checkpoint/restart
    def state_dict(self) -> tuple[PyTree, dict]:
        """(array_tree, json_meta) of the codec's live rows: per-client
        anchors (+ EF residuals under ``topk``). Without them a restarted
        compressed run would re-anchor at zero and the first post-restart
        upload per client would ship a full-model-sized delta through the
        codec — wrong bytes AND wrong arithmetic."""
        seeded = [cid for cid in self.ids if self._seeded[self.index[cid]]]
        tree: dict[str, Any] = {
            "anchors": {
                str(cid): self.plane.to_pytree(self._anchor_row[self.index[cid]])
                for cid in seeded
            }
        }
        if self.mode == "topk":
            tree["residuals"] = {
                str(cid): self.plane.to_pytree(self._resid_row[self.index[cid]])
                for cid in seeded
            }
        meta = {
            "mode": self.mode,
            "k": self.k,
            "chunk": self.chunk,
            "clients": sorted(str(cid) for cid in seeded),
        }
        return tree, meta

    def load_state(self, tree: PyTree, meta: dict, client_id_type=int) -> None:
        """Restore from :meth:`state_dict` output. Pre-restore rows are
        dropped (re-zeroed) first, exactly like the server's upload rows;
        codec geometry (``k``/``chunk``) follows the CURRENT config — only
        the mode must match, since residuals/anchors are mode-specific."""
        if meta["mode"] != self.mode:
            raise ValueError(
                f"uplink codec mode mismatch: checkpoint is {meta['mode']!r}, "
                f"this run is {self.mode!r}"
            )
        K = len(self.ids)
        live = [i for i in range(K) if not self._released[i]]
        zeros = jnp.zeros((len(live), self.dim), self.plane.dtype)
        self.plane.write_rows([self._anchor_row[i] for i in live], zeros)
        if self._resid_row is not None:
            self.plane.write_rows([self._resid_row[i] for i in live], zeros)
        self._seeded = [False] * K

        def restore(section: dict, row_of: list[int]) -> None:
            rows, vecs = [], []
            for s, p in section.items():
                i = self.index.get(client_id_type(s))
                if i is None or self._released[i]:  # not simulated / evicted
                    continue
                rows.append(row_of[i])
                vecs.append(self.spec.flatten(p))
            if rows:
                self.plane.write_rows(rows, jnp.stack(vecs))

        restore(tree.get("anchors") or {}, self._anchor_row)
        for s in (tree.get("anchors") or {}):
            i = self.index.get(client_id_type(s))
            if i is not None and not self._released[i]:
                self._seeded[i] = True
        if self.mode == "topk":
            restore(tree.get("residuals") or {}, self._resid_row)


def seed_template(meta: dict, params_template: PyTree) -> PyTree:
    """Tree-structure template matching :meth:`UplinkCodec.state_dict` for
    ``meta`` — lets a checkpointer restore the codec section without
    pickling (every row shares the model parameter structure)."""
    tree: dict[str, Any] = {"anchors": {c: params_template for c in meta["clients"]}}
    if meta["mode"] == "topk":
        tree["residuals"] = {c: params_template for c in meta["clients"]}
    return tree
