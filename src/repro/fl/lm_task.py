"""REPRO_TASK=lm: personalized LM fine-tuning as plane rows.

Each simulated device personalizes a FROZEN transformer base (the
``tiny_lm`` config by default) by training a small delta pytree:

* ``head_a``/``head_b`` — a LoRA factorization of the output head. With
  tied embeddings the update merges into the embedding matrix, so it
  personalizes both the input lookup and the logits (the tied-weight
  analogue of a per-client classifier head).
* ``wq`` — per-slot LoRA on the attention query projections of the
  scanned blocks, so local training runs the flash-attention kernels
  forward AND backward, not just a linear probe over frozen features.

Only the delta rides the wire and becomes a plane row: the base lives in
a :class:`FrozenBase` (a ``register_static`` pytree wrapper with zero
array leaves), so ``simulator.model_bytes`` bills uploads/downlinks at
delta size automatically and the server's clustering plane stores
``dim = size(delta)`` rows, not ``size(base)``.

Per-client data is a token stream (:mod:`repro.data.lm`): clients in the
same latent cluster share one Zipf+Markov distribution (same support
permutation and successor table) but draw disjoint sequences. Feedback
distributions (Eq. 2/3) histogram token ids into ``buckets`` classes
(``token_id % J``) — the LM analogue of the MLP label histogram, sized so
the server's chi2 kernels stay (J,)-cheap regardless of vocab.

LoRA b-factors init to zero, so every client's initial delta row sits at
the plane origin and row distance directly measures personalization
divergence — the EchoPFL Eq. 1 metric, unpolluted by base weights.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data.lm import TokenStream, TokenStreamConfig
from repro.fl.tasks import FleetData, pad_rows
from repro.models.model import forward as model_forward
from repro.models.model import init_params as model_init_params

PyTree = Any


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True, eq=False)
class FrozenBase:
    """Static pytree wrapper for the frozen base parameters.

    ``register_static`` makes it flatten to ZERO leaves (the whole object
    is treedef metadata), which is what keeps the base out of every
    leaf-walking code path at once: ``model_bytes`` bills payloads that
    carry it at 0 bytes, ``flatten_spec`` rows exclude it, and jit treats
    it as a compile-time constant. ``eq=False`` gives identity hash/eq —
    comparing multi-MB pytrees per jit-cache lookup would be absurd."""

    params: PyTree


@dataclasses.dataclass
class LMClientData:
    """One client's token sequences, pre-split. Mirrors the surface the
    coordination layers read from ``ClientDataset``: ``n`` (upload
    weighting) and ``label_histogram`` (feedback f_true)."""

    tokens_train: np.ndarray  # (n_train, S) int32
    labels_train: np.ndarray  # (n_train, S) int32 next-token targets
    tokens_test: np.ndarray  # (n_test, S) int32
    labels_test: np.ndarray
    latent_cluster: int = 0

    @property
    def n(self) -> int:
        return len(self.tokens_train)

    def label_histogram(self, num_classes: int) -> np.ndarray:
        """Counts of target tokens per ``token_id % J`` bucket (the LM
        analogue of the MLP class histogram — counts, not frequencies,
        matching ``ClientDataset.label_histogram``)."""
        return np.bincount(
            self.labels_train.reshape(-1) % num_classes, minlength=num_classes
        ).astype(np.float64)


@dataclasses.dataclass(frozen=True)
class LMTask:
    """PersonalizationTask over LoRA/head deltas on a frozen base.

    Frozen + hashable: ``base`` hashes by identity (FrozenBase), ``cfg``
    by value, so the fleet's static-task jit cache keys correctly."""

    base: FrozenBase
    cfg: ModelConfig
    lora_rank: int = 4
    buckets: int = 16
    name: str = "lm"

    # ---- delta pytree ---------------------------------------------------
    def init_params(self, key: jax.Array) -> PyTree:
        cfg, r = self.cfg, self.lora_rank
        d, V, P = cfg.d_model, cfg.padded_vocab, cfg.num_periods
        k_head, k_wq = jax.random.split(key)
        delta: dict[str, Any] = {
            # standard LoRA init: a random, b zero — the initial delta is an
            # exact zero update, so initial rows sit at the plane origin
            "head_a": jax.random.normal(k_head, (d, r), jnp.float32) / np.sqrt(d),
            "head_b": jnp.zeros((r, V), jnp.float32),
            "wq": {},
        }
        for i, spec in enumerate(cfg.pattern):
            if spec.mixer in ("attn", "attn_local"):
                k_wq, k = jax.random.split(k_wq)
                hk = cfg.num_heads * cfg.resolved_head_dim
                delta["wq"][f"slot{i}"] = {
                    "a": jax.random.normal(k, (P, d, r), jnp.float32) / np.sqrt(d),
                    "b": jnp.zeros((P, r, hk), jnp.float32),
                }
        return delta

    def merged(self, delta: PyTree) -> PyTree:
        """Base + delta as effective forward params (pure, jit-traceable;
        the base leaves fold in as constants)."""
        base = self.base.params
        cfg = self.cfg
        scale = 1.0 / self.lora_rank
        params = dict(base)
        head_upd = (delta["head_a"] @ delta["head_b"]) * scale  # (d, V)
        if cfg.tie_embeddings:
            params["embed"] = base["embed"] + head_upd.T.astype(base["embed"].dtype)
        else:
            params["lm_head"] = base["lm_head"] + head_upd.astype(base["lm_head"].dtype)
        if delta["wq"]:
            blocks = dict(base["blocks"])
            for slot, ab in delta["wq"].items():
                sp = dict(blocks[slot])
                mx = dict(sp["mixer"])
                upd = jnp.einsum("pdr,prx->pdx", ab["a"], ab["b"]) * scale
                mx["wq"] = mx["wq"] + upd.reshape(mx["wq"].shape).astype(mx["wq"].dtype)
                sp["mixer"] = mx
                blocks[slot] = sp
            params["blocks"] = blocks
        return params

    # ---- per-client arithmetic (the vmap operands) ----------------------
    def _nll(self, delta, tokens, labels, seq_mask):
        """Mean next-token NLL over valid sequences (padded rows masked)."""
        logits, _, _ = model_forward(self.cfg, self.merged(delta), {"tokens": tokens})
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        per = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]  # (n, S)
        per = per * seq_mask[:, None]
        denom = jnp.maximum(jnp.sum(seq_mask) * tokens.shape[1], 1.0)
        return -(jnp.sum(per) / denom)

    def _scan_train(self, delta, tokens, labels, seq_mask, lr, epochs, head_frac,
                    max_epochs: int):
        """Multi-epoch full-batch SGD on the delta, mirroring
        ``mlp._scan_train``: steps past this client's ``epochs`` budget are
        carried through untouched, and head-only fine-tuning selects the
        block-LoRA gradients to exact zeros (the head LoRA is the LM
        analogue of the MLP's last layer)."""

        def step(carry, e):
            p, last_loss = carry
            loss, grads = jax.value_and_grad(
                lambda q: self._nll(q, tokens, labels, seq_mask)
            )(p)
            freeze_body = head_frac > 0
            gw = jax.tree_util.tree_map(
                lambda g: jnp.where(freeze_body, jnp.zeros_like(g), g), grads["wq"]
            )
            grads = {**grads, "wq": gw}
            new = jax.tree_util.tree_map(lambda a, g: a - lr * g, p, grads)
            active = e < epochs
            p2 = jax.tree_util.tree_map(
                lambda old, nw: jnp.where(active, nw, old), p, new
            )
            return (p2, jnp.where(active, loss, last_loss)), None

        (delta, loss), _ = jax.lax.scan(
            step, (delta, jnp.zeros(())), jnp.arange(max_epochs)
        )
        return delta, loss

    def _accuracy(self, delta, tokens, labels, seq_mask):
        logits, _, _ = model_forward(self.cfg, self.merged(delta), {"tokens": tokens})
        pred = jnp.argmax(logits, axis=-1)
        correct = (pred == labels).astype(jnp.float32) * seq_mask[:, None]
        denom = jnp.maximum(jnp.sum(seq_mask) * tokens.shape[1], 1.0)
        return jnp.sum(correct) / denom

    def _distributions(self, delta, tokens, seq_mask, num_classes: int):
        """(F_pred, S_soft) over ``token_id % J`` buckets: predicted-token
        bucket counts and the mean bucket-aggregated softmax."""
        J = num_classes
        logits, _, _ = model_forward(self.cfg, self.merged(delta), {"tokens": tokens})
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (n, S, V)
        pred = jnp.argmax(logits, axis=-1)  # (n, S)
        bucket = jax.nn.one_hot(jnp.arange(logits.shape[-1]) % J, J)  # (V, J)
        valid = seq_mask[:, None]  # (n, 1)
        onehot = jax.nn.one_hot(pred % J, J) * valid[..., None]
        hist = jnp.sum(onehot, axis=(0, 1))  # (J,) bucket counts
        sprob = jnp.einsum("nsv,vj->nsj", probs, bucket) * valid[..., None]
        denom = jnp.maximum(jnp.sum(seq_mask) * tokens.shape[1], 1.0)
        return hist, jnp.sum(sprob, axis=(0, 1)) / denom

    # ---- fleet engine (batched; called inside the fleet's jits) ---------
    def build_fleet_data(self, datasets, shard, num_classes):
        n_tr = max(d.n for d in datasets)
        n_te = max(len(d.tokens_test) for d in datasets)

        def stack(attr, n):
            return shard(jnp.asarray(np.stack(
                [pad_rows(np.asarray(getattr(d, attr), np.int32), n) for d in datasets]
            )))

        def masks(n, lens):
            return shard(jnp.asarray(np.stack(
                [pad_rows(np.ones(k, np.float32), n) for k in lens]
            )))

        train = {
            "tokens": stack("tokens_train", n_tr),
            "labels": stack("labels_train", n_tr),
            "mask": masks(n_tr, [d.n for d in datasets]),
        }
        test = {
            "tokens": stack("tokens_test", n_te),
            "labels": stack("labels_test", n_te),
            "mask": masks(n_te, [len(d.tokens_test) for d in datasets]),
        }
        f_true = np.stack([
            d.label_histogram(num_classes).astype(np.float32) for d in datasets
        ])
        return FleetData(train=train, test=test, f_true=f_true)

    def fleet_local_train(self, params_b, train, lr, epochs, head, *, max_epochs):
        return jax.vmap(
            functools.partial(self._scan_train, max_epochs=max_epochs)
        )(params_b, train["tokens"], train["labels"], train["mask"], lr, epochs, head)

    def fleet_evaluate(self, params_b, test):
        return jax.vmap(self._accuracy)(
            params_b, test["tokens"], test["labels"], test["mask"]
        )

    def fleet_feedback(self, params_b, train, num_classes):
        return jax.vmap(
            functools.partial(self._distributions, num_classes=num_classes)
        )(params_b, train["tokens"], train["mask"])

    # ---- per-client entry points (loop backend / SimClient) -------------
    def local_train(self, params, data, *, epochs, lr, head_only):
        mask = jnp.ones((data.n,), jnp.float32)
        delta, loss = _client_train(
            self, params, jnp.asarray(data.tokens_train), jnp.asarray(data.labels_train),
            mask, jnp.asarray(lr, jnp.float32), jnp.asarray(epochs, jnp.int32),
            jnp.asarray(1.0 if head_only else 0.0, jnp.float32), max_epochs=epochs,
        )
        return delta, loss

    def evaluate(self, params, data):
        return float(_client_eval(
            self, params, jnp.asarray(data.tokens_test), jnp.asarray(data.labels_test),
            jnp.ones((len(data.tokens_test),), jnp.float32),
        ))

    def feedback_inputs(self, params, data, num_classes):
        f_pred, s_soft = _client_feedback(
            self, params, jnp.asarray(data.tokens_train),
            jnp.ones((data.n,), jnp.float32), num_classes=num_classes,
        )
        f_true = data.label_histogram(num_classes)
        return np.asarray(f_pred), f_true.astype(np.float32), np.asarray(s_soft)


@functools.partial(jax.jit, static_argnames=("task", "max_epochs"))
def _client_train(task, delta, tokens, labels, mask, lr, epochs, head_frac, *,
                  max_epochs: int):
    return task._scan_train(delta, tokens, labels, mask, lr, epochs, head_frac,
                            max_epochs=max_epochs)


@functools.partial(jax.jit, static_argnames=("task",))
def _client_eval(task, delta, tokens, labels, mask):
    return task._accuracy(delta, tokens, labels, mask)


@functools.partial(jax.jit, static_argnames=("task", "num_classes"))
def _client_feedback(task, delta, tokens, mask, *, num_classes: int):
    return task._distributions(delta, tokens, mask, num_classes)


# ---------------------------------------------------------------------------
# data + experiment drivers
# ---------------------------------------------------------------------------


_DEFAULT_LM_TASK: LMTask | None = None


def default_lm_task() -> LMTask:
    """The singleton ``REPRO_TASK=lm`` task (tiny_lm base, PRNGKey(0)).

    A singleton on purpose: the task is a static jit-cache key, so every
    resolver call must hand back the SAME object or each lookup would
    recompile the fleet launches."""
    global _DEFAULT_LM_TASK
    if _DEFAULT_LM_TASK is None:
        cfg = get_config("tiny_lm")
        base = model_init_params(cfg, jax.random.PRNGKey(0))
        _DEFAULT_LM_TASK = LMTask(base=FrozenBase(base), cfg=cfg)
    return _DEFAULT_LM_TASK


def make_lm_data(
    num_clients: int,
    *,
    vocab_size: int,
    latent_clusters: int = 4,
    n_train: int = 8,
    n_test: int = 4,
    seq_len: int = 32,
    seed: int = 0,
) -> list[LMClientData]:
    """Per-client token datasets with cluster-structured heterogeneity.

    All clients of a latent cluster share one stream DISTRIBUTION (support
    permutation + Markov successor table come from the cluster seed); each
    client then draws its own sequences from a reseeded sampler — same
    personalization geometry as the synthetic MLP tasks."""
    out = []
    for i in range(num_clients):
        cl = i % latent_clusters
        stream = TokenStream(TokenStreamConfig(
            vocab_size=vocab_size, seq_len=seq_len, batch_size=1,
            seed=7000 + 17 * cl + seed,
        ))
        # distribution tables are built; re-seed only the sampling rng
        stream.rng = np.random.default_rng(100_003 * (seed + 1) + i)
        seqs = np.stack([stream._sample_seq(seq_len + 1) for _ in range(n_train + n_test)])
        tok, lab = seqs[:, :-1].astype(np.int32), seqs[:, 1:].astype(np.int32)
        out.append(LMClientData(
            tokens_train=tok[:n_train], labels_train=lab[:n_train],
            tokens_test=tok[n_train:], labels_test=lab[n_train:],
            latent_cluster=cl,
        ))
    return out


def build_lm_clients(
    num_clients: int,
    *,
    seed: int = 0,
    latent_clusters: int = 4,
    device_mix: dict | None = None,
    base_round_time: float = 30.0,
    local_epochs: int = 2,
    lr: float = 0.5,
    n_train: int = 8,
    n_test: int = 4,
    seq_len: int = 32,
    task: LMTask | None = None,
):
    """(clients, task, init_delta) for the LM workload — the LM analogue of
    ``experiment.build_clients``."""
    from repro.core.client import SimClient
    from repro.fl.devices import PAPER_SIM_MIX, make_device_fleet

    task = task or default_lm_task()
    rng = np.random.default_rng(seed)
    datasets = make_lm_data(
        num_clients, vocab_size=task.cfg.vocab_size, latent_clusters=latent_clusters,
        n_train=n_train, n_test=n_test, seq_len=seq_len, seed=seed,
    )
    fleet = make_device_fleet(num_clients, rng, device_mix or PAPER_SIM_MIX, base_round_time)
    clients = [
        SimClient(
            client_id=i,
            data=datasets[i],
            num_classes=task.buckets,
            device_class=fleet[i]["class"],
            round_time_fn=fleet[i]["round_time"],
            local_epochs=local_epochs,
            lr=lr,
            task=task,
        )
        for i in range(num_clients)
    ]
    init_delta = task.init_params(jax.random.PRNGKey(seed))
    return clients, task, init_delta


def run_lm_experiment(
    strategy_name: str,
    *,
    num_clients: int = 8,
    seed: int = 0,
    max_time: float = 1800.0,
    rounds: int = 5,
    eval_interval: float = 120.0,
    network=None,
    local_epochs: int = 2,
    base_round_time: float = 30.0,
    client_backend: str | None = None,
    uplink=None,
    latent_clusters: int = 4,
    n_train: int = 8,
    n_test: int = 4,
    seq_len: int = 32,
    **strategy_kw,
):
    """End-to-end LM personalization run: returns (task, clients, strategy,
    report) like ``experiment.run_experiment``. Sync strategies go through
    ``run_sync`` round barriers; async ones through the (coalesced) event
    loop — both on delta payloads."""
    from repro.fl.experiment import build_strategy
    from repro.fl.network import NetworkModel
    from repro.fl.simulator import Simulator

    clients, task, init_delta = build_lm_clients(
        num_clients, seed=seed, latent_clusters=latent_clusters,
        base_round_time=base_round_time, local_epochs=local_epochs,
        n_train=n_train, n_test=n_test, seq_len=seq_len,
    )
    strategy = build_strategy(strategy_name, init_delta, clients, seed=seed, **strategy_kw)
    sim = Simulator(
        clients, strategy,
        network=network or NetworkModel(),
        eval_interval=eval_interval, seed=seed, client_backend=client_backend,
        uplink=uplink,
    )
    report = sim.run(max_time=max_time, rounds=rounds)
    report.extra["task"] = "lm"
    report.extra["latent_clusters"] = {c.client_id: c.data.latent_cluster for c in clients}
    return task, clients, strategy, report
