"""The :class:`PersonalizationTask` protocol: what a workload must provide
for the fleet engine, the simulator, and the EchoPFL server to personalize
it as plane rows.

EchoPFL's coordination layer never looks inside a model: the server blends
flat rows (Eq. 1 distances + mixed-rate lerp), the fleet engine trains
batched flat rows, and feedback is a pair of class distributions (Eq. 2/3).
Everything task-specific — what a model pytree IS, how a client's dataset
becomes batched device tensors, what one local epoch does, what the
feedback histograms count — lives behind this protocol. The seed repo
hard-coded the toy MLP in ``fl/fleet.py`` / ``core/client.py`` /
``fl/experiment.py``; those layers now only call task methods.

Implementations must be hashable value objects (frozen dataclasses): the
fleet's fused launches pass the task as a static jit argument, so a task's
identity keys the compile cache the same way the flatten spec does.

Two tasks ship:

* :class:`MLPTask` (``REPRO_TASK=mlp``, the default) — the paper's toy-MLP
  workload, delegating 1:1 to :mod:`repro.models.mlp`. The delegation is
  call-for-call identical to the seed wiring, so default trajectories are
  bitwise-unchanged.
* ``LMTask`` (``REPRO_TASK=lm``, :mod:`repro.fl.lm_task`) — per-client
  LoRA/head deltas over a frozen transformer base; the deltas are the
  plane rows.

The per-client methods (``local_train`` / ``evaluate`` /
``feedback_inputs``) serve the loop backend and :class:`SimClient`; the
``fleet_*`` methods are jit-pure batched counterparts the fleet engine
vmaps — both views of the same arithmetic.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass
class FleetData:
    """Batched device tensors for one fleet: ``train``/``test`` are dicts of
    ``(clients, ...)`` arrays whose layout only the owning task interprets
    (the fleet gathers rows by client index inside its launches and passes
    the dict through); ``f_true`` is the (clients, J) matrix of true label
    histograms feeding the chi2 kernels."""

    train: dict[str, jax.Array]
    test: dict[str, jax.Array]
    f_true: np.ndarray


def pad_rows(arr: np.ndarray, n: int) -> np.ndarray:
    """Zero-pad a per-client array's leading dim to ``n`` rows."""
    if len(arr) == n:
        return arr
    return np.concatenate([arr, np.zeros((n - len(arr),) + arr.shape[1:], arr.dtype)])


@runtime_checkable
class PersonalizationTask(Protocol):
    """What the coordination layers require of a workload.

    ``name`` tags the task; ``init_params(key)`` builds the model pytree a
    client uploads (for delta-style tasks: the DELTA pytree — the frozen
    base never rides the wire and never becomes plane rows; flattening this
    pytree with ``repro.common.pytrees.flatten_spec`` defines the row).
    """

    name: str

    # ---- model surface -------------------------------------------------
    def init_params(self, key: jax.Array) -> PyTree: ...

    # ---- fleet engine (batched, jit-pure, task static) -----------------
    def build_fleet_data(
        self, datasets: list[Any], shard: Callable[[jax.Array], jax.Array],
        num_classes: int,
    ) -> FleetData: ...

    def fleet_local_train(
        self, params_b: PyTree, train: dict[str, jax.Array], lr: jax.Array,
        epochs: jax.Array, head: jax.Array, *, max_epochs: int,
    ) -> tuple[PyTree, jax.Array]: ...

    def fleet_evaluate(
        self, params_b: PyTree, test: dict[str, jax.Array]
    ) -> jax.Array: ...

    def fleet_feedback(
        self, params_b: PyTree, train: dict[str, jax.Array], num_classes: int
    ) -> tuple[jax.Array, jax.Array]: ...

    # ---- per-client (loop backend / SimClient) -------------------------
    def local_train(
        self, params: PyTree, data: Any, *, epochs: int, lr: float, head_only: bool
    ) -> tuple[PyTree, Any]: ...

    def evaluate(self, params: PyTree, data: Any) -> float: ...

    def feedback_inputs(
        self, params: PyTree, data: Any, num_classes: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]: ...


@dataclasses.dataclass(frozen=True)
class MLPTask:
    """The paper's toy-MLP workload (the seed behavior, bit-for-bit): every
    method delegates to :mod:`repro.models.mlp` with exactly the operands
    the pre-protocol code passed."""

    name: str = "mlp"

    # ---- model surface -------------------------------------------------
    def init_params(self, key, cfg=None):
        from repro.configs.paper_tasks import PAPER_TASKS
        from repro.models.mlp import init_mlp

        return init_mlp(cfg or PAPER_TASKS["image_recognition"], key)

    # ---- fleet engine --------------------------------------------------
    def build_fleet_data(self, datasets, shard, num_classes):
        n_tr = max(len(d.y_train) for d in datasets)
        n_te = max(len(d.y_test) for d in datasets)
        train = {
            "x": shard(jnp.asarray(np.stack(
                [pad_rows(np.asarray(d.x_train, np.float32), n_tr) for d in datasets]))),
            "y": shard(jnp.asarray(np.stack(
                [pad_rows(np.asarray(d.y_train, np.int32), n_tr) for d in datasets]))),
            "mask": shard(jnp.asarray(np.stack(
                [pad_rows(np.ones(len(d.y_train), np.float32), n_tr) for d in datasets]))),
        }
        test = {
            "x": shard(jnp.asarray(np.stack(
                [pad_rows(np.asarray(d.x_test, np.float32), n_te) for d in datasets]))),
            "y": shard(jnp.asarray(np.stack(
                [pad_rows(np.asarray(d.y_test, np.int32), n_te) for d in datasets]))),
            "mask": shard(jnp.asarray(np.stack(
                [pad_rows(np.ones(len(d.y_test), np.float32), n_te) for d in datasets]))),
        }
        f_true = np.stack([
            d.label_histogram(num_classes).astype(np.float32) for d in datasets
        ])
        return FleetData(train=train, test=test, f_true=f_true)

    def fleet_local_train(self, params_b, train, lr, epochs, head, *, max_epochs):
        from repro.models import mlp

        return mlp.fleet_local_train(
            params_b, train["x"], train["y"], train["mask"], lr, epochs, head,
            max_epochs=max_epochs,
        )

    def fleet_evaluate(self, params_b, test):
        from repro.models import mlp

        return mlp.fleet_evaluate(params_b, test["x"], test["y"], test["mask"])

    def fleet_feedback(self, params_b, train, num_classes):
        from repro.models import mlp

        return mlp.fleet_predict_distributions(
            params_b, train["x"], train["mask"], num_classes
        )

    # ---- per-client ----------------------------------------------------
    def local_train(self, params, data, *, epochs, lr, head_only):
        from repro.models import mlp

        return mlp.local_train(
            params, jnp.asarray(data.x_train), jnp.asarray(data.y_train),
            epochs=epochs, lr=lr, head_only=head_only,
        )

    def evaluate(self, params, data):
        from repro.models import mlp

        return float(mlp.evaluate(
            params, jnp.asarray(data.x_test), jnp.asarray(data.y_test)
        ))

    def feedback_inputs(self, params, data, num_classes):
        from repro.models import mlp

        f_pred, s_soft = mlp.predict_distributions(
            params, jnp.asarray(data.x_train), num_classes
        )
        f_true = data.label_histogram(num_classes)
        return np.asarray(f_pred), f_true.astype(np.float32), np.asarray(s_soft)


MLP_TASK = MLPTask()


def get_task(name: str) -> PersonalizationTask:
    """Resolve a task implementation by name (``mlp`` | ``lm``)."""
    if name == "mlp":
        return MLP_TASK
    if name == "lm":
        from repro.fl.lm_task import default_lm_task

        return default_lm_task()
    raise ValueError(f"unknown REPRO_TASK {name!r}: expected 'mlp' or 'lm'")


def default_task() -> PersonalizationTask:
    """The REPRO_TASK env knob (default ``mlp``). Builders consult this;
    :class:`SimClient` itself defaults to the MLP task only when its
    ``task`` field is unset, so constructed fleets never change task
    mid-flight because the environment did."""
    return get_task(os.environ.get("REPRO_TASK", "mlp"))
