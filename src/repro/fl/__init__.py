from repro.fl.devices import DEVICE_CLASSES, DeviceClass, make_device_fleet
from repro.fl.fleet import ClientFleet
from repro.fl.network import NetworkModel
from repro.fl.simulator import SimReport, Simulator

__all__ = [
    "DEVICE_CLASSES",
    "DeviceClass",
    "make_device_fleet",
    "ClientFleet",
    "NetworkModel",
    "Simulator",
    "SimReport",
]
