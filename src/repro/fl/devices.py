"""Mobile device heterogeneity model (paper Sec. 7.1).

Five device classes with relative local-training speed factors calibrated
to the boards the paper uses. The base unit is seconds per local training
round of the T1 CNN; other tasks scale it. Factors are from the boards'
relative FP32 throughput (Jetson AGX ~ 11x RPi4 on small CNNs).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    name: str
    speed_factor: float  # multiplier on base local-round time
    jitter: float  # lognormal sigma for per-round variation


DEVICE_CLASSES: dict[str, DeviceClass] = {
    "D1": DeviceClass("jetson_nano", 4.0, 0.15),
    "D2": DeviceClass("jetson_nx_xavier", 2.0, 0.10),
    "D3": DeviceClass("jetson_nano_orin", 1.5, 0.10),
    "D4": DeviceClass("jetson_agx_xavier", 1.0, 0.10),
    "D5": DeviceClass("raspberry_pi_4", 8.0, 0.25),
}

# Paper simulation mix (Sec. 7.2.1): 20% D1, 20% D2, 20% D3, 40% D5.
PAPER_SIM_MIX = {"D1": 0.2, "D2": 0.2, "D3": 0.2, "D5": 0.4}
# Paper real-world mix (Sec. 7.5): 3 D1, 5 D2, 4 D3, 2 D4, 6 D5.
PAPER_CASE_STUDY_MIX = {"D1": 3, "D2": 5, "D3": 4, "D4": 2, "D5": 6}


def make_device_fleet(
    num_clients: int,
    rng: np.random.Generator,
    mix: dict[str, float] | None = None,
    base_round_time: float = 30.0,
) -> list[dict]:
    """Returns per-client dicts: {class, round_time_fn}."""
    mix = mix or PAPER_SIM_MIX
    names = list(mix)
    weights = np.asarray([mix[n] for n in names], np.float64)
    if weights.sum() > 1.5:  # absolute counts
        assign = sum(([n] * int(mix[n]) for n in names), [])
        assert len(assign) == num_clients, f"mix counts {len(assign)} != {num_clients}"
    else:
        weights = weights / weights.sum()
        counts = np.floor(weights * num_clients).astype(int)
        while counts.sum() < num_clients:
            counts[rng.integers(0, len(names))] += 1
        assign = sum(([n] * int(c) for n, c in zip(names, counts)), [])
    rng.shuffle(assign)

    fleet = []
    for cls_key in assign:
        cls = DEVICE_CLASSES[cls_key]
        mean_t = base_round_time * cls.speed_factor

        def round_time(rng_=rng, mean=mean_t, sigma=cls.jitter):
            return float(mean * rng_.lognormal(0.0, sigma))

        fleet.append({"class": cls_key, "round_time": round_time})
    return fleet
