"""Deterministic fault injection for the async protocol.

The simulator's virtual world has, until now, been a friendly one: every
device that starts a local round finishes it, every upload crosses the
thin link on the first try, every downlink arrives exactly once and in
order, and the server never dies mid-run. Production federated systems
(Papaya is the reference point in PAPERS.md) live in the opposite
regime — device churn and transport failures dominate — so this module
injects exactly those faults, *deterministically*, so chaos runs are as
reproducible and parity-testable as clean ones:

- **client crash mid-local-round**: the round's work is lost and the
  device goes dark for a drawn downtime, rejoining through the same
  ``_next_online`` path static churn uses; a configurable fraction of
  crashes are permanent (device death), after which the server reclaims
  the client's protocol state (see ``EchoPFLServer.evict_clients``).
- **upload loss/timeout with capped exponential-backoff retries**: each
  failed attempt bills its full payload bytes and transfer duration plus
  a backoff through :class:`~repro.fl.network.NetworkModel` (flagged so
  retry-attributable bytes are reported separately), and the added delay
  flows into version-based staleness accounting for free. Under the
  ``drop`` policy the sender gives up after ``max_retries`` failures
  instead — the drop-the-straggler baseline the bench compares against.
- **duplicate delivery**: the upload arrives twice (the retransmission
  bills real bytes); the ingest path absorbs the second copy through a
  per-client monotonic sequence fence.
- **downlink reorder**: a broadcast leg is delayed past a later send;
  the client install path fences on a per-recipient send sequence so a
  stale model never overwrites a newer one.
- **server kill + restore mid-``run_async``**: the live strategy is
  checkpointed through :mod:`repro.checkpoint`, discarded, and a fresh
  instance restored from disk — continuing the run must reproduce the
  uninterrupted ledger exactly.

Determinism contract
--------------------
Every decision is drawn from a :class:`numpy.random.SeedSequence` keyed
by ``(seed, fault kind, client id hash, per-(kind, client) counter)`` —
*never* from a shared stream. The two async paths (per-event and
coalesced) and the two client backends (loop and fleet) consult the
injector at different wall points and in different batch shapes; keying
each draw by its own counter makes the schedule a pure function of "the
n-th time this client hit this fault point", which is identical across
all four combinations. A fixed ``REPRO_FAULT_SEED`` therefore yields the
identical fault schedule everywhere, and the chaos parity tests extend
the existing bitwise suites. With faults disabled the simulator never
constructs an injector, so clean trajectories stay bitwise-identical to
the pre-fault code.

Knobs (all read by :func:`default_fault_config`):

``REPRO_FAULTS``              master switch (``1``/``on`` enables)
``REPRO_FAULT_SEED``          schedule seed (default 0)
``REPRO_FAULT_CRASH``         P(crash) per local round (default 0.05)
``REPRO_FAULT_CRASH_DOWNTIME``mean crash downtime seconds (default 120)
``REPRO_FAULT_DEATH``         P(crash is permanent) (default 0.0)
``REPRO_FAULT_LOSS``          P(loss/timeout) per upload attempt (0.1)
``REPRO_FAULT_MAX_RETRIES``   retry cap per upload (default 4)
``REPRO_FAULT_BACKOFF``       base backoff seconds, doubled per retry (5)
``REPRO_FAULT_BACKOFF_CAP``   backoff ceiling seconds (default 60)
``REPRO_FAULT_DUP``           P(duplicate delivery) per upload (0.05)
``REPRO_FAULT_REORDER``       P(extra delay) per downlink (0.05)
``REPRO_FAULT_POLICY``        ``retry`` (default) or ``drop``
``REPRO_FAULT_POISON_NAN``    P(delivered upload turns partly NaN) (0.0)
``REPRO_FAULT_POISON_SCALE``  P(delivered upload magnitude-blown) (0.0)
``REPRO_FAULT_POISON_SIGN``   P(delivered upload sign-flipped) (0.0)
``REPRO_FAULT_POISON_FACTOR`` scale blowup factor (default 1e3)

Value-level poison (the ``POISON`` knobs) corrupts the *post-codec*
upload delta after transport succeeds — the model for bitflips, broken
quantizers, and adversarial clients rather than lost packets. One draw
per delivered upload partitions a single uniform across the three
corruption kinds, so the schedule stays a pure per-``(kind, cid,
counter)`` function and the per-event/coalesced loops and loop/fleet
backends poison the identical uploads. The defense layer that catches
these lives in :mod:`repro.fl.guard` (``REPRO_GUARD=on``).
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Any, Callable

import numpy as np

# fault-kind codes for the draw key: stable small ints, never reordered
_K_CRASH = 1
_K_UPLOAD = 2
_K_DUP = 3
_K_REORDER = 4
_K_POISON = 5


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(name, "").strip()
    return float(raw) if raw else default


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name, "").strip()
    return int(raw) if raw else default


def faults_enabled() -> bool:
    """``REPRO_FAULTS`` master switch."""
    return os.environ.get("REPRO_FAULTS", "").strip().lower() in ("1", "on", "true", "yes")


@dataclasses.dataclass
class FaultConfig:
    """Per-kind fault rates + retry discipline (see module docstring)."""

    seed: int = 0
    crash_rate: float = 0.05
    crash_downtime: float = 120.0  # mean; draw is uniform in [0.5, 1.5) x mean
    death_rate: float = 0.0  # fraction of crashes that are permanent
    loss_rate: float = 0.1  # per upload attempt
    max_retries: int = 4
    backoff_base: float = 5.0
    backoff_cap: float = 60.0
    dup_rate: float = 0.05
    reorder_rate: float = 0.05
    reorder_max_delay: float = 60.0
    dup_max_delay: float = 30.0
    policy: str = "retry"  # retry | drop (drop-the-straggler baseline)
    poison_nan_rate: float = 0.0  # per delivered upload
    poison_scale_rate: float = 0.0
    poison_sign_rate: float = 0.0
    poison_scale_factor: float = 1e3
    poison_nan_frac: float = 0.01  # fraction of coordinates NaN'd

    def __post_init__(self):
        if self.policy not in ("retry", "drop"):
            raise ValueError(f"REPRO_FAULT_POLICY must be retry|drop, got {self.policy!r}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        for name in ("crash_rate", "death_rate", "loss_rate", "dup_rate",
                     "reorder_rate", "poison_nan_rate", "poison_scale_rate",
                     "poison_sign_rate", "poison_nan_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be a probability in [0, 1], got {v!r}")
        total = self.poison_nan_rate + self.poison_scale_rate + self.poison_sign_rate
        if total > 1.0:
            raise ValueError(
                f"poison rates must sum to <= 1 (one corruption per upload), got {total!r}")
        for name in ("crash_downtime", "backoff_base", "backoff_cap",
                     "reorder_max_delay", "dup_max_delay"):
            v = getattr(self, name)
            if v < 0.0:
                raise ValueError(f"{name} must be >= 0 seconds, got {v!r}")
        if self.poison_scale_factor <= 0.0:
            raise ValueError(
                f"poison_scale_factor must be > 0, got {self.poison_scale_factor!r}")


def default_fault_config() -> FaultConfig:
    """Build a :class:`FaultConfig` from the ``REPRO_FAULT*`` environment."""
    return FaultConfig(
        seed=_env_int("REPRO_FAULT_SEED", 0),
        crash_rate=_env_float("REPRO_FAULT_CRASH", 0.05),
        crash_downtime=_env_float("REPRO_FAULT_CRASH_DOWNTIME", 120.0),
        death_rate=_env_float("REPRO_FAULT_DEATH", 0.0),
        loss_rate=_env_float("REPRO_FAULT_LOSS", 0.1),
        max_retries=_env_int("REPRO_FAULT_MAX_RETRIES", 4),
        backoff_base=_env_float("REPRO_FAULT_BACKOFF", 5.0),
        backoff_cap=_env_float("REPRO_FAULT_BACKOFF_CAP", 60.0),
        dup_rate=_env_float("REPRO_FAULT_DUP", 0.05),
        reorder_rate=_env_float("REPRO_FAULT_REORDER", 0.05),
        policy=os.environ.get("REPRO_FAULT_POLICY", "retry").strip().lower() or "retry",
        poison_nan_rate=_env_float("REPRO_FAULT_POISON_NAN", 0.0),
        poison_scale_rate=_env_float("REPRO_FAULT_POISON_SCALE", 0.0),
        poison_sign_rate=_env_float("REPRO_FAULT_POISON_SIGN", 0.0),
        poison_scale_factor=_env_float("REPRO_FAULT_POISON_FACTOR", 1e3),
    )


def apply_poison(params: Any, kind: str, u: float, cfg: FaultConfig) -> Any:
    """Corrupt one delivered upload per the drawn poison ``(kind, u)``.

    Always builds fresh host arrays — payload leaves may be frozen views
    shared with the client's own model or a codec bank, and the fault
    must corrupt only what crossed the wire. ``nan`` overwrites a
    deterministic ``poison_nan_frac`` slice of each leaf starting at an
    offset derived from ``u`` (the draw's second uniform), so the exact
    corrupted coordinates are part of the seeded schedule; ``scale``
    multiplies by ``poison_scale_factor``; ``sign`` negates."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for x in leaves:
        a = np.array(x)
        if kind == "sign":
            a = -a
        elif kind == "scale":
            a = a * a.dtype.type(cfg.poison_scale_factor)
        else:  # nan
            flat = a.reshape(-1)
            n = flat.size
            if n:
                cnt = max(1, int(round(cfg.poison_nan_frac * n)))
                idx = (int(u * n) + np.arange(cnt)) % n
                flat[idx] = np.nan
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class ServerRestartPlan:
    """Kill + restore the server mid-``run_async``: once ``at_uploads``
    uploads have been ingested, the live strategy's :meth:`state_dict` is
    written through the checkpointer, the object discarded, and
    ``strategy_factory()``'s fresh instance restored from disk. The run
    then continues on the restored server — the acceptance bar is that
    the final report matches an uninterrupted run's ledger exactly."""

    at_uploads: int
    directory: str
    strategy_factory: Callable[[], Any]
    client_id_type: type = int


@dataclasses.dataclass
class FaultPlan:
    """Everything the simulator needs to run a chaos leg: the seeded
    per-kind rates plus an optional mid-run server restart."""

    config: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    restart: ServerRestartPlan | None = None


def resolve_faults(spec: Any = None) -> FaultPlan | None:
    """Normalize the simulator's ``faults=`` argument.

    ``None`` consults ``REPRO_FAULTS`` (the ambient default); ``"off"``
    forces clean runs regardless of the environment; a
    :class:`FaultConfig` / :class:`FaultPlan` is adopted as-is. Returns
    ``None`` when faults are fully disabled — the simulator then never
    touches any fault path, keeping clean trajectories bitwise-identical."""
    if spec is None:
        return FaultPlan(config=default_fault_config()) if faults_enabled() else None
    if isinstance(spec, str):
        low = spec.strip().lower()
        if low in ("", "0", "off", "none", "no"):
            return None
        if low in ("1", "on", "true", "yes"):
            return FaultPlan(config=default_fault_config())
        raise ValueError(f"faults spec must be on|off, a FaultConfig or a FaultPlan; got {spec!r}")
    if isinstance(spec, FaultConfig):
        return FaultPlan(config=spec)
    if isinstance(spec, FaultPlan):
        return spec
    raise ValueError(f"faults spec must be on|off, a FaultConfig or a FaultPlan; got {spec!r}")


class FaultInjector:
    """Order-independent seeded fault schedule + the run's fault ledger.

    One injector lives per :class:`~repro.fl.simulator.Simulator` run.
    Each query advances a per-``(kind, client)`` counter and derives its
    uniforms from ``SeedSequence((seed, kind, crc32(client), counter))``,
    so the schedule depends only on how many times each fault point was
    hit per client — not on the global interleaving, which differs
    between the per-event and coalesced loops."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.cfg = plan.config
        self._counters: dict[tuple[int, int], int] = {}
        self._restart_done = False
        self.ledger: dict[str, Any] = {
            "crashes": 0,
            "deaths": 0,
            "crash_downtime_s": 0.0,
            "upload_failures": 0,
            "retried_uploads": 0,
            "retry_delay_s": 0.0,
            "dropped_uploads": 0,
            "dropped_clients": 0,
            "dups_injected": 0,
            "dups_absorbed": 0,
            "reorders_injected": 0,
            "stale_downlinks_absorbed": 0,
            "server_restarts": 0,
            "evicted_clients": 0,
            "reclaimed_clusters": 0,
            "poison_nan": 0,
            "poison_scale": 0,
            "poison_sign": 0,
        }

    # ------------------------------------------------------------- draws
    def _draw(self, kind: int, cid: Any, n: int) -> np.ndarray:
        key = (kind, zlib.crc32(repr(cid).encode()))
        count = self._counters.get(key, 0)
        self._counters[key] = count + 1
        ss = np.random.SeedSequence(entropy=(self.cfg.seed, kind, key[1], count))
        return np.random.default_rng(ss).random(n)

    def crash(self, cid: Any) -> float | None:
        """Consulted once per local-round start. ``None``: no crash.
        ``inf``: permanent death. Otherwise the downtime in seconds."""
        cfg = self.cfg
        if cfg.crash_rate <= 0.0:
            return None
        u = self._draw(_K_CRASH, cid, 3)
        if u[0] >= cfg.crash_rate:
            return None
        self.ledger["crashes"] += 1
        if cfg.death_rate > 0.0 and u[1] < cfg.death_rate:
            self.ledger["deaths"] += 1
            return float("inf")
        downtime = float(cfg.crash_downtime * (0.5 + u[2]))
        self.ledger["crash_downtime_s"] += downtime
        return downtime

    def upload_plan(self, cid: Any) -> tuple[int, bool]:
        """One decision per upload: ``(failed_attempts, delivered)``.

        Geometric in the per-attempt loss rate, capped at
        ``max_retries`` failures. Under the ``retry`` policy the attempt
        after the last failure always delivers (the capped-backoff
        sender keeps the device in the protocol); under ``drop``,
        hitting the cap abandons the upload — and the client."""
        cfg = self.cfg
        if cfg.loss_rate <= 0.0:
            return 0, True
        u = self._draw(_K_UPLOAD, cid, max(cfg.max_retries, 1))
        fails = 0
        while fails < cfg.max_retries and u[fails] < cfg.loss_rate:
            fails += 1
        self.ledger["upload_failures"] += fails
        if fails:
            self.ledger["retried_uploads"] += 1
        if cfg.policy == "drop" and fails >= cfg.max_retries:
            self.ledger["dropped_uploads"] += 1
            return fails, False
        return fails, True

    def backoff(self, attempt: int) -> float:
        """Backoff after the ``attempt``-th failure (0-indexed),
        exponential with a ceiling."""
        return min(self.cfg.backoff_base * (2.0**attempt), self.cfg.backoff_cap)

    def duplicate(self, cid: Any) -> float | None:
        """Consulted once per delivered upload: ``None`` or the extra
        delay after the original arrival at which the duplicate lands."""
        cfg = self.cfg
        if cfg.dup_rate <= 0.0:
            return None
        u = self._draw(_K_DUP, cid, 2)
        if u[0] >= cfg.dup_rate:
            return None
        self.ledger["dups_injected"] += 1
        return float(1.0 + u[1] * (cfg.dup_max_delay - 1.0))

    def reorder(self, cid: Any) -> float:
        """Consulted once per downlink send to ``cid``: extra delivery
        delay (0.0 = in order)."""
        cfg = self.cfg
        if cfg.reorder_rate <= 0.0:
            return 0.0
        u = self._draw(_K_REORDER, cid, 2)
        if u[0] >= cfg.reorder_rate:
            return 0.0
        self.ledger["reorders_injected"] += 1
        return float(1.0 + u[1] * (cfg.reorder_max_delay - 1.0))

    def poison(self, cid: Any) -> tuple[str, float] | None:
        """Consulted once per *delivered* upload (after transport wins,
        before ingest). ``None``: the delta is clean. Otherwise
        ``(kind, u)`` with ``kind`` in ``nan|scale|sign`` and ``u`` a
        second uniform the corruptor may use (NaN coordinate offset).
        One uniform is partitioned across the three rates so at most one
        corruption applies per upload and adding a kind never perturbs
        another kind's schedule."""
        cfg = self.cfg
        total = cfg.poison_nan_rate + cfg.poison_scale_rate + cfg.poison_sign_rate
        if total <= 0.0:
            return None
        u = self._draw(_K_POISON, cid, 2)
        if u[0] < cfg.poison_nan_rate:
            kind = "nan"
        elif u[0] < cfg.poison_nan_rate + cfg.poison_scale_rate:
            kind = "scale"
        elif u[0] < total:
            kind = "sign"
        else:
            return None
        self.ledger[f"poison_{kind}"] += 1
        return kind, float(u[1])

    # ----------------------------------------------------------- restart
    def restart_due(self, uploads: int) -> bool:
        plan = self.plan.restart
        return plan is not None and not self._restart_done and uploads >= plan.at_uploads

    def mark_restarted(self) -> None:
        self._restart_done = True
        self.ledger["server_restarts"] += 1

    # ------------------------------------------------------------ ledger
    def ledger_snapshot(self) -> dict:
        out = dict(self.ledger)
        out["policy"] = self.cfg.policy
        out["seed"] = self.cfg.seed
        return out
