"""Minimal functional optimizer library (no optax dependency).

Interface mirrors the (init, update) pair convention:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All transforms are jit-compatible pytree programs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


class SGDState(NamedTuple):
    step: jax.Array


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        del params
        return SGDState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        lr_t = sched(state.step)
        updates = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
        return updates, SGDState(step=state.step + 1)

    return Optimizer(init, update)


class MomentumState(NamedTuple):
    step: jax.Array
    velocity: PyTree


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return MomentumState(
            step=jnp.zeros((), jnp.int32),
            velocity=jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update(grads, state, params=None):
        del params
        lr_t = sched(state.step)
        vel = jax.tree_util.tree_map(lambda v, g: beta * v + g, state.velocity, grads)
        if nesterov:
            updates = jax.tree_util.tree_map(lambda v, g: -lr_t * (beta * v + g), vel, grads)
        else:
            updates = jax.tree_util.tree_map(lambda v: -lr_t * v, vel)
        return updates, MomentumState(step=state.step + 1, velocity=vel)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """Adam; with weight_decay > 0 this is AdamW (decoupled decay)."""
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = sched(state.step)
        grads32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads32)
        nu = jax.tree_util.tree_map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads32)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(m, n, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if params is None and weight_decay:
            raise ValueError("adamw requires params for decoupled weight decay")
        if params is None:
            params = jax.tree_util.tree_map(lambda m: jnp.zeros_like(m), mu)
        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    return adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)
