"""Upstream update compression for the thin uplink.

EchoPFL's systems insight is bandwidth *asymmetry*: downstream (server ->
clients, broadcast) is ~10x fatter than upstream (client -> server). We
therefore compress only the *uplink* parameter deltas. Two codecs:

- top-k sparsification with error feedback (EF-SGD style): keeps the k
  largest-magnitude entries of the flattened delta, accumulating the residual
  locally so nothing is permanently lost,
- int8 linear quantization with per-chunk scales.

Both operate on flat vectors so they compose with the pytree flatten helpers
and are architecture-agnostic — exactly like the coordination protocol itself.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TopKPayload(NamedTuple):
    indices: jax.Array  # (k,) int32
    values: jax.Array  # (k,) float32
    length: int  # original vector length (static)


def topk_compress(vec: jax.Array, k: int) -> TopKPayload:
    k = min(k, vec.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(vec), k)
    return TopKPayload(indices=idx.astype(jnp.int32), values=vec[idx], length=vec.shape[0])


def topk_decompress(payload: TopKPayload) -> jax.Array:
    out = jnp.zeros((payload.length,), payload.values.dtype)
    return out.at[payload.indices].set(payload.values)


class ErrorFeedbackState(NamedTuple):
    residual: jax.Array


def ef_topk_step(vec: jax.Array, state: ErrorFeedbackState, k: int) -> tuple[TopKPayload, ErrorFeedbackState]:
    """Error-feedback top-k: compress (vec + residual), carry what was dropped."""
    corrected = vec + state.residual
    payload = topk_compress(corrected, k)
    sent = topk_decompress(payload)
    return payload, ErrorFeedbackState(residual=corrected - sent)


class Int8Payload(NamedTuple):
    q: jax.Array  # (n,) int8
    scales: jax.Array  # (n_chunks,) float32
    chunk: int  # static chunk size


def int8_compress(vec: jax.Array, chunk: int = 4096) -> Int8Payload:
    n = vec.shape[0]
    pad = (-n) % chunk
    v = jnp.pad(vec, (0, pad)).reshape(-1, chunk)
    scales = jnp.max(jnp.abs(v), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(v / scales[:, None]), -127, 127).astype(jnp.int8)
    return Int8Payload(q=q.reshape(-1)[:n], scales=scales, chunk=chunk)


def int8_decompress(payload: Int8Payload) -> jax.Array:
    n = payload.q.shape[0]
    pad = (-n) % payload.chunk
    q = jnp.pad(payload.q, (0, pad)).reshape(-1, payload.chunk).astype(jnp.float32)
    return (q * payload.scales[:, None]).reshape(-1)[:n]


def payload_bytes(payload) -> int:
    """Wire size of a compressed payload — used by the comm-cost accounting."""
    if isinstance(payload, TopKPayload):
        return payload.indices.size * 4 + payload.values.size * 4
    if isinstance(payload, Int8Payload):
        return payload.q.size * 1 + payload.scales.size * 4
    raise TypeError(type(payload))
