"""Upstream update compression for the thin uplink.

EchoPFL's systems insight is bandwidth *asymmetry*: downstream (server ->
clients, broadcast) is ~10x fatter than upstream (client -> server). We
therefore compress only the *uplink* parameter deltas. Two codecs:

- top-k sparsification with error feedback (EF-SGD style): keeps the k
  largest-magnitude entries of the flattened delta, accumulating the residual
  locally so nothing is permanently lost,
- int8 linear quantization with per-chunk scales.

Both operate on flat vectors so they compose with the pytree flatten helpers
and are architecture-agnostic — exactly like the coordination protocol itself.

Two API tiers:

* single-vector codecs (``topk_compress``/``ef_topk_step``/``int8_compress``)
  — the reference semantics, payload-object based, used by the unit tests
  and the analytical comm-cost sweeps;
* batched row-wise codecs (``ef_topk_batch``/``int8_compress_batch`` and
  friends) — plain traceable functions over ``(B, n)`` matrices, composed
  into ONE fused launch per upload cohort by
  :class:`repro.fl.uplink.UplinkCodec`. Per-row arithmetic is independent
  (row-wise ``top_k``/elementwise ops), so a batch of B rows computes
  exactly B single-row codecs. ``ef_topk_update`` is the jitted standalone
  form with the residual buffer donated — an EF state that lives as its own
  device matrix is updated in place instead of copied every step.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class TopKPayload(NamedTuple):
    indices: jax.Array  # (k,) int32
    values: jax.Array  # (k,) float32
    length: int  # original vector length (static)


def topk_compress(vec: jax.Array, k: int) -> TopKPayload:
    k = min(k, vec.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(vec), k)
    return TopKPayload(indices=idx.astype(jnp.int32), values=vec[idx], length=vec.shape[0])


def topk_decompress(payload: TopKPayload) -> jax.Array:
    out = jnp.zeros((payload.length,), payload.values.dtype)
    return out.at[payload.indices].set(payload.values)


class ErrorFeedbackState(NamedTuple):
    residual: jax.Array


def ef_topk_step(vec: jax.Array, state: ErrorFeedbackState, k: int) -> tuple[TopKPayload, ErrorFeedbackState]:
    """Error-feedback top-k: compress (vec + residual), carry what was dropped."""
    corrected = vec + state.residual
    payload = topk_compress(corrected, k)
    sent = topk_decompress(payload)
    return payload, ErrorFeedbackState(residual=corrected - sent)


class Int8Payload(NamedTuple):
    q: jax.Array  # (n,) int8
    scales: jax.Array  # (n_chunks,) float32
    chunk: int  # static chunk size


def _chunk_mask(n: int, chunk: int) -> jax.Array:
    """(n_chunks, chunk) validity mask for a length-``n`` vector padded up to
    a whole number of chunks: padding entries must never enter the per-chunk
    scale max, so the final ragged chunk's scale depends only on real data."""
    pad = (-n) % chunk
    return (jnp.arange(n + pad) < n).reshape(-1, chunk)


def int8_compress(vec: jax.Array, chunk: int = 4096) -> Int8Payload:
    n = vec.shape[0]
    pad = (-n) % chunk
    v = jnp.pad(vec, (0, pad)).reshape(-1, chunk)
    masked = jnp.where(_chunk_mask(n, chunk), jnp.abs(v), 0.0)
    scales = jnp.max(masked, axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(v / scales[:, None]), -127, 127).astype(jnp.int8)
    return Int8Payload(q=q.reshape(-1)[:n], scales=scales, chunk=chunk)


def int8_decompress(payload: Int8Payload) -> jax.Array:
    n = payload.q.shape[0]
    pad = (-n) % payload.chunk
    q = jnp.pad(payload.q, (0, pad)).reshape(-1, payload.chunk).astype(jnp.float32)
    return (q * payload.scales[:, None]).reshape(-1)[:n]


def payload_bytes(payload) -> int:
    """Wire size of a compressed payload — used by the comm-cost accounting."""
    if isinstance(payload, TopKPayload):
        return payload.indices.size * 4 + payload.values.size * 4
    if isinstance(payload, Int8Payload):
        return payload.q.size * 1 + payload.scales.size * 4
    raise TypeError(type(payload))


def wire_bytes(mode: str, n: int, *, k: int | None = None, chunk: int | None = None) -> int:
    """Exact wire size of ONE compressed length-``n`` upload, from static
    config alone (int32 indices + f32 values, or int8 codes + f32 per-chunk
    scales — itemsizes honored). Matches ``payload_bytes`` of the payload the
    codecs actually emit; being static is what lets the simulator bill every
    compressed uplink without a device sync."""
    if mode == "topk":
        return min(k, n) * (4 + 4)
    if mode == "int8":
        return n * 1 + (-(-n // chunk)) * 4
    raise ValueError(f"wire_bytes: unknown mode {mode!r}")


# --------------------------------------------------------- batched codecs
# Row-wise (B, n) forms of the codecs above: plain traceable functions, so
# the uplink codec can fuse gather + compress + reconstruct + state update
# into one launch per cohort. Row arithmetic is independent of B.


def topk_compress_batch(mat: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Per-row top-k of a (B, n) matrix: (B, k) int32 indices + f32 values."""
    k = min(k, mat.shape[-1])
    _, idx = jax.lax.top_k(jnp.abs(mat), k)
    return idx.astype(jnp.int32), jnp.take_along_axis(mat, idx, axis=-1)


def topk_scatter_batch(idx: jax.Array, values: jax.Array, n: int) -> jax.Array:
    """Densify per-row top-k payloads back to (B, n)."""
    out = jnp.zeros((idx.shape[0], n), values.dtype)
    return out.at[jnp.arange(idx.shape[0])[:, None], idx].set(values)


def ef_topk_batch(
    mat: jax.Array, residuals: jax.Array, k: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched error-feedback top-k step over (B, n) rows.

    Returns ``(indices, values, sent, new_residuals)``: the per-row payload
    arrays, the densified transmission (what the server reconstructs from),
    and the carried residuals — exactly B independent :func:`ef_topk_step`
    applications."""
    corrected = mat + residuals
    idx, vals = topk_compress_batch(corrected, k)
    sent = topk_scatter_batch(idx, vals, mat.shape[-1])
    return idx, vals, sent, corrected - sent


@functools.partial(jax.jit, static_argnames=("k",), donate_argnums=(1,))
def ef_topk_update(mat, residuals, *, k: int):
    """Standalone jitted EF step with the residual matrix DONATED: an EF
    state held as its own (B, n) device buffer updates in place, never
    copied per step. (The uplink codec instead traces :func:`ef_topk_batch`
    inside its own launch and lets the plane's donated flush scatter own the
    write-back.)"""
    return ef_topk_batch(mat, residuals, k)


def int8_compress_batch(mat: jax.Array, chunk: int) -> tuple[jax.Array, jax.Array]:
    """Per-row int8 quantization of a (B, n) matrix: (B, n) int8 codes +
    (B, n_chunks) f32 scales, padding masked out of the scale max like
    :func:`int8_compress`."""
    B, n = mat.shape
    pad = (-n) % chunk
    v = jnp.pad(mat, ((0, 0), (0, pad))).reshape(B, -1, chunk)
    masked = jnp.where(_chunk_mask(n, chunk)[None], jnp.abs(v), 0.0)
    scales = jnp.max(masked, axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(v / scales[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(B, -1)[:, :n], scales


def int8_decompress_batch(q: jax.Array, scales: jax.Array, chunk: int) -> jax.Array:
    """Densify per-row int8 payloads back to (B, n) float32."""
    B, n = q.shape
    pad = (-n) % chunk
    qf = jnp.pad(q, ((0, 0), (0, pad))).reshape(B, -1, chunk).astype(jnp.float32)
    return (qf * scales[..., None]).reshape(B, -1)[:, :n]
