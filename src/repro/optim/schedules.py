"""Learning-rate schedules as step -> lr callables (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, alpha: float = 0.0):
    def sched(step):
        t = jnp.minimum(step.astype(jnp.float32), decay_steps) / decay_steps
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * ((1 - alpha) * cos + alpha)

    return sched


def linear_warmup_cosine(lr: float, warmup_steps: int, decay_steps: int, alpha: float = 0.1):
    def sched(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step_f - warmup_steps) / jnp.maximum(decay_steps - warmup_steps, 1), 0.0, 1.0)
        cos = lr * ((1 - alpha) * 0.5 * (1.0 + jnp.cos(jnp.pi * t)) + alpha)
        return jnp.where(step_f < warmup_steps, warm, cos)

    return sched
