from repro.optim.optimizers import Optimizer, adam, adamw, momentum, sgd
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
from repro.optim.compression import (
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
    ErrorFeedbackState,
    ef_topk_step,
)

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adam",
    "adamw",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
    "topk_compress",
    "topk_decompress",
    "int8_compress",
    "int8_decompress",
    "ErrorFeedbackState",
    "ef_topk_step",
]
