"""Adafactor (Shazeer & Stern, 2018) — factored second moments so optimizer
state is O(rows + cols) instead of O(rows * cols). This is what lets the
405B/398B-class models fit the v5e 16GB budget (see EXPERIMENTS.md §Dry-run):
AdamW needs 8 bytes/param of state; factored Adafactor needs ~0.001.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _as_schedule


class _FactoredSlot(NamedTuple):
    vr: jax.Array  # row second-moment (shape[:-1])
    vc: jax.Array  # col second-moment (shape without -2 axis)


class AdafactorState(NamedTuple):
    step: jax.Array
    slots: object  # pytree matching params: _FactoredSlot for >=2D, array for <2D


def _decay(step, d=0.8):
    t = step.astype(jnp.float32) + 1.0
    return 1.0 - t**-d


def adafactor(lr, min_dim_size_to_factor: int = 128, clip_threshold: float = 1.0, eps: float = 1e-30) -> Optimizer:
    sched = _as_schedule(lr)

    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor and p.shape[-2] >= min_dim_size_to_factor

    def init(params):
        def slot(p):
            if factored(p):
                return _FactoredSlot(
                    vr=jnp.zeros(p.shape[:-1], jnp.float32),
                    vc=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                )
            return jnp.zeros(p.shape, jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32), slots=jax.tree_util.tree_map(slot, params))

    def update(grads, state, params=None):
        del params
        step = state.step
        beta = _decay(step)
        lr_t = sched(step)

        def upd(g, s):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if isinstance(s, _FactoredSlot):
                vr = beta * s.vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s.vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                new_slot = _FactoredSlot(vr=vr, vc=vc)
            else:
                vhat = beta * s + (1 - beta) * g2
                new_slot = vhat
            u = g32 * jax.lax.rsqrt(vhat + eps)
            # update clipping by RMS (Adafactor's d=1.0 rule)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return -lr_t * u, new_slot

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state.slots)
        pairs = [upd(g, s) for g, s in zip(flat_g, flat_s)]
        updates = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
        slots = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
        return updates, AdafactorState(step=step + 1, slots=slots)

    return Optimizer(init, update)
