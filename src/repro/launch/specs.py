"""ShapeDtypeStruct input specs for every (arch x shape) cell — the dry-run
lowers against these, so no host memory is ever allocated for the 405B-class
models. Frontend-stub archs (pixtral/hubert) get precomputed patch/frame
embeddings instead of tokens, per the brief.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model import init_cache, init_params
from repro.models.steps import TrainState, make_optimizer

PyTree = Any


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def effective_microbatches(cfg: ModelConfig, shape: ShapeSpec, dp: int) -> int:
    """Largest n <= requested with n | global_batch and dp | (global_batch/n):
    every microbatch must still shard evenly over the data axes."""
    want = max(1, cfg.train.microbatches)
    per_dp = shape.global_batch // dp if shape.global_batch % dp == 0 else 1
    n = 1
    for cand in range(1, want + 1):
        if shape.global_batch % cand == 0 and (shape.global_batch // cand) % max(dp, 1) == 0:
            n = cand
    del per_dp
    return n


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.embeds_input:
        return {
            "embeds": sds((B, S, cfg.d_model), dtype),
            "labels": sds((B, S), jnp.int32),
        }
    return {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.embeds_input:
        return {"embeds": sds((B, S, cfg.d_model), dtype)}
    return {"tokens": sds((B, S), jnp.int32)}


def decode_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    return {"tokens": sds((shape.global_batch, 1), jnp.int32)}


def state_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> TrainState:
    """TrainState ShapeDtypeStructs via eval_shape — zero allocation."""
    opt = make_optimizer(cfg)

    def build():
        params = init_params(cfg, jax.random.PRNGKey(0), dtype)
        return TrainState(params=params, opt_state=opt.init(params), step=jnp.zeros((), jnp.int32))

    return jax.eval_shape(build)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, ctx_len=shape.seq_len, dtype=dtype)
    )


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> PyTree:
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    """Everything the dry-run needs to lower the cell's step function."""
    if shape.kind == "train":
        return {"state": state_specs(cfg, dtype), "batch": train_batch_specs(cfg, shape, dtype)}
    if shape.kind == "prefill":
        return {"params": param_specs(cfg, dtype), "batch": prefill_batch_specs(cfg, shape, dtype)}
    if shape.kind == "decode":
        return {
            "params": param_specs(cfg, dtype),
            "cache": cache_specs(cfg, shape, dtype),
            "batch": decode_batch_specs(cfg, shape),
        }
    raise ValueError(shape.kind)


def model_param_count(cfg: ModelConfig) -> int:
    """Exact parameter count from eval_shape (no allocation)."""
    shapes = param_specs(cfg)
    return sum(math.prod(l.shape) if l.shape else 1 for l in jax.tree_util.tree_leaves(shapes))


def model_active_param_count(cfg: ModelConfig) -> int:
    """Active params/token: total minus inactive routed experts."""
    total = model_param_count(cfg)
    if cfg.moe is None:
        return total
    moe_layers = sum(1 for l in cfg.all_layers if l.ffn == "moe")
    per_expert = 3 * cfg.d_model * cfg.moe.d_expert
    inactive = moe_layers * (cfg.moe.num_experts - cfg.moe.top_k) * per_expert
    return total - inactive
