"""Production training driver: pjit'd train loop on a named mesh with
fault-tolerant checkpointing and elastic restart.

On TPU pods this runs the full configs over the production (16,16) /
(2,16,16) meshes; on this CPU container use --mesh smoke --reduced to run
the same code path end-to-end on one device:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --mesh smoke --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCH_REGISTRY
from repro.configs.base import reduced_config
from repro.data.lm import token_stream
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.shardings import batch_shardings, param_shardings, replicated
from repro.models import init_params, make_train_step
from repro.models.steps import TrainState, make_optimizer


def make_mesh(name: str):
    if name == "smoke":
        return make_smoke_mesh()
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    raise KeyError(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--mesh", default="smoke", choices=["smoke", "pod", "multipod"])
    ap.add_argument("--reduced", action="store_true", help="CPU-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ARCH_REGISTRY[args.arch]
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = make_mesh(args.mesh)
    from repro.models import dist

    dist.set_mesh(mesh)  # flash attention runs shard_mapped on multi-device meshes
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M mesh={dict(mesh.shape)}")

    if cfg.embeds_input:
        raise SystemExit("frontend-stub archs train via input_specs embeddings; "
                         "use the dry-run for those cells")

    key = jax.random.PRNGKey(0)
    opt = make_optimizer(cfg)

    # shard params at init: init on host, device_put with the target sharding
    params = init_params(cfg, key)
    p_sh = param_shardings(cfg, mesh, params)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt.init(params), param_shardings(cfg, mesh, opt.init(params)))
    state = TrainState(params, opt_state, jnp.zeros((), jnp.int32))

    import dataclasses as dc

    from repro.configs.base import SHAPES

    shape = dc.replace(SHAPES["train_4k"], global_batch=args.batch, seq_len=args.seq)
    batch0 = {"tokens": np.zeros((args.batch, args.seq), np.int32),
              "labels": np.zeros((args.batch, args.seq), np.int32)}
    b_sh = batch_shardings(cfg, shape, mesh, batch0)
    state_sh = TrainState(p_sh, param_shardings(cfg, mesh, state.opt_state), replicated(mesh))

    step_fn = jax.jit(
        make_train_step(cfg, opt), in_shardings=(state_sh, b_sh),
        out_shardings=(state_sh, None), donate_argnums=0,
    )

    ck = Checkpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if ck is not None:
        got = ck.restore_latest(like=jax.tree_util.tree_map(np.asarray, state))
        if got is not None:
            start, restored, _ = got
            state = jax.device_put(restored, state_sh)
            print(f"restored checkpoint at step {start}")

    stream = token_stream(cfg.vocab_size, seed=0, batch=args.batch, seq=args.seq)
    t0 = time.time()
    tokens_done = 0
    with mesh:
        for i in range(start, args.steps):
            batch = jax.device_put(next(stream), b_sh)
            state, metrics = step_fn(state, batch)
            tokens_done += args.batch * args.seq
            if (i + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {i+1:5d} loss={loss:.4f} tok/s={tokens_done/dt:,.0f}")
            if ck is not None and (i + 1) % args.ckpt_every == 0:
                ck.save_async(i + 1, state, extra={"loss": float(metrics["loss"])})
    if ck is not None:
        ck.wait()
        ck.close()
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
