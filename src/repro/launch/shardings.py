"""Sharding rules: parameter/optimizer/cache/batch PartitionSpecs for every
architecture on the production mesh.

Policy (GSPMD; see DESIGN.md §5):
  * batch dims        -> ("pod", "data")           (DP across pods + within)
  * heads / FFN / d_inner dims -> "model"          (TP)
  * vocab             -> "model"
  * MoE experts       -> TP over d_expert by default (always divisible);
                         expert-parallel variant available for §Perf
  * ZeRO (train.dp_shard_params): additionally shard the first divisible,
    not-yet-sharded dim over "data" — optimizer state and params then live
    FSDP-style and XLA inserts the all-gathers.

Rules are *name + shape* driven: a leaf path's last known name selects the
logical rule; the rule is then fitted to the actual leaf rank/divisibility
(optimizer slots like Adafactor's factored vr/vc reuse their parameter's
rule truncated to their rank). Anything unmatched is replicated — correct,
just not maximally parallel, and flagged by the dry-run report.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import axis_size, batch_axes

PyTree = Any

# Logical rule per leaf name: for each dim, a priority list of mesh axes to
# try ("model"/"data"), or None (replicate). Fitted against divisibility.
_RULES: dict[str, tuple] = {
    # embedding / head
    "embed": ("model", "data"),           # (V, D)
    "lm_head": ("data", "model"),         # (D, V)
    # attention
    "wq": ("data", "model", None),        # (D, H, hd)
    "wk": ("data", "model", None),
    "wv": ("data", "model", None),
    "wo": ("model", None, "data"),        # (H, hd, D)
    # MLA
    "w_dkv": ("data", "model"),           # (D, lora+rope)
    "w_ukv": ("data", "model", None),     # (lora, H, nope+v)
    # dense ffn
    "wg": ("data", "model"),              # (D, F)  [or (E, D, De) for MoE]
    "wu": ("data", "model"),
    "wd": ("model", "data"),              # (F, D)  [or (E, De, D)]
    "router": (None, None),
    # mamba
    "w_in": ("data", "model"),            # (D, 2Di)
    "conv_w": (None, "model"),            # (dc, Di)
    "conv_b": ("model",),
    "w_x": ("model", None),               # (Di, dt_rank + 2 ds)
    "w_dt": (None, "model"),              # (dt_rank, Di)
    "dt_bias": ("model",),
    "A_log": ("model", None),             # (Di, ds)
    "D": ("model",),
    "w_out": ("model", "data"),           # (Di, D)
    # xLSTM
    "w_up": ("data", "model"),            # (D, 2Di)
    "w_i": ("model", None),
    "w_f": ("model", None),
    "f_bias": (None,),
    "w_down": ("model", "data"),          # (Di, D)
    "wgx": ("data", None, "model"),       # (D, 4, D) gate-aligned channel TP
    "wgh": ("data", None, "model"),
    "gbias": (None, "model"),
    "bias": ("model",),
    "ffn_up": ("data", "model"),
    "ffn_down": ("model", "data"),
    "b_out": (None,),
    "w_out_rnn": (None, None),
}

_MOE_RULES = {
    "wg": (None, "data", "model"),        # (E, D, De): TP over De
    "wu": (None, "data", "model"),
    "wd": (None, "model", "data"),        # (E, De, D)
}

_MOE_EP_RULES = {
    "wg": ("model", "data", None),        # (E, D, De): expert-parallel over E
    "wu": ("model", "data", None),
    "wd": ("model", None, "data"),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str) and key in _RULES or isinstance(key, str) and key in ("scale",):
            return key
        if isinstance(key, str) and not key.startswith(("slot", "mu", "nu", "vr", "vc", "slots")):
            return key
    return ""


def _is_moe_leaf(path) -> bool:
    names = [getattr(e, "key", None) for e in path]
    return "ffn" in names and any(n in ("router", "shared") or n is None for n in names) or False


def _fit(rule: tuple, shape: tuple, mesh: Mesh, zero: bool) -> P:
    """Fit a logical rule to a concrete shape: keep an axis only if the dim
    divides; 'data' axes only when ZeRO is on; truncate/extend to rank."""
    specs = []
    used: set[str] = set()
    rule = rule[: len(shape)] + (None,) * max(0, len(shape) - len(rule))
    # offset alignment: factored slots drop trailing dims; align rule from dim 0
    for dim, want in zip(shape, rule):
        axis = None
        if want == "model" and "model" in mesh.axis_names and dim % axis_size(mesh, "model") == 0 and "model" not in used:
            axis = "model"
        elif want == "data" and zero and dim % axis_size(mesh, "data") == 0 and "data" not in used:
            axis = "data"
        specs.append(axis)
        if axis:
            used.add(axis)
    return P(*specs)


def param_shardings(cfg: ModelConfig, mesh: Mesh, shapes: PyTree) -> PyTree:
    """NamedShardings for a params-shaped pytree (params, grads, or any
    optimizer slot tree whose leaf names mirror param names)."""
    zero = cfg.train.dp_shard_params
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = []
    for path, leaf in flat:
        names = [getattr(e, "key", None) for e in path]
        name = ""
        for key in reversed(names):
            if isinstance(key, str) and key in _RULES:
                name = key
                break
        moe = "ffn" in names and name in _MOE_RULES and len(leaf.shape) == 3 and cfg.moe is not None
        # 'shared' expert FFN under moe uses the dense 2-D rules
        if "shared" in names:
            moe = False
        if name == "w_h" and "wh0" in str(names):
            name = ""
        if moe:
            rule = _MOE_RULES[name]
        elif name:
            rule = _RULES[name]
        else:
            rule = (None,) * len(leaf.shape)
        # scanned-period params are STACKED: (num_periods, *logical_shape).
        # The logical rule must shift right by one dim, otherwise "model"
        # lands on d_model instead of d_ff/heads and every contraction
        # becomes partial-sums + a full-activation all-reduce (§Perf iter 2).
        if "blocks" in names and len(leaf.shape) == len(rule) + 1:
            rule = (None,) + rule
        spec = _fit(rule, leaf.shape, mesh, zero)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, batch_shapes: PyTree) -> PyTree:
    """Shard the batch dim over (pod, data); fall back to replication when
    the batch is too small (long_500k's batch=1)."""
    baxes = batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= axis_size(mesh, a)

    def spec(leaf):
        if leaf.shape and leaf.shape[0] % dp == 0:
            return NamedSharding(mesh, P(baxes, *(None,) * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec, batch_shapes)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shapes: PyTree, global_batch: int) -> PyTree:
    """Decode-buffer shardings. Batch over (pod, data) when divisible;
    otherwise (long_500k, batch=1) shard the sequence dim of attention
    buffers over "data". Head/feature dims go to "model" via divisibility.
    """
    baxes = batch_axes(mesh)
    dp = 1
    for a in baxes:
        dp *= axis_size(mesh, a)
    tp = axis_size(mesh, "model")

    def spec(path, leaf):
        names = [getattr(e, "key", None) for e in path]
        name = next((k for k in reversed(names) if isinstance(k, str)), "")
        shp = leaf.shape
        if name == "len" or not shp:
            return NamedSharding(mesh, P())
        batch_ok = shp[0] % dp == 0 and shp[0] >= dp
        b_spec = baxes if batch_ok else None
        if name in ("k", "v"):  # (B, S, KV, hd)
            kv_ok = shp[2] % tp == 0
            hd_ok = shp[3] % tp == 0
            seq_spec = None if batch_ok else ("data" if shp[1] % axis_size(mesh, "data") == 0 else None)
            if kv_ok:
                return NamedSharding(mesh, P(b_spec, seq_spec, "model", None))
            if hd_ok:
                return NamedSharding(mesh, P(b_spec, seq_spec, None, "model"))
            return NamedSharding(mesh, P(b_spec, seq_spec, None, None))
        if name in ("ckv", "krope"):  # (B, S, r)
            seq_spec = None if batch_ok else ("data" if shp[1] % axis_size(mesh, "data") == 0 else None)
            r_ok = shp[2] % tp == 0
            return NamedSharding(mesh, P(b_spec, seq_spec, "model" if r_ok else None))
        if name == "conv":  # (B, dc-1, Di)
            return NamedSharding(mesh, P(b_spec, None, "model" if shp[2] % tp == 0 else None))
        if name == "ssm":  # (B, Di, ds)
            return NamedSharding(mesh, P(b_spec, "model" if shp[1] % tp == 0 else None, None))
        if name == "C":  # (B, h, hd, hd)
            return NamedSharding(mesh, P(b_spec, None, None, "model" if shp[3] % tp == 0 else None))
        if name in ("n", "m", "c", "h"):
            last_ok = shp[-1] % tp == 0
            mid = (None,) * (len(shp) - 2)
            return NamedSharding(mesh, P(b_spec, *mid, "model" if last_ok and len(shp) > 1 else None))
        return NamedSharding(mesh, P(b_spec, *(None,) * (len(shp) - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [spec(p, l) for p, l in flat])
