"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
makes it useless for scan-over-layers programs (a 126-layer scanned model
reports ~1 layer of FLOPs). This module re-derives FLOPs / HBM bytes /
collective bytes from the optimized HLO text, multiplying each while body
by its ``known_trip_count`` (present in the backend_config emitted by XLA's
loop analysis) and recursing through fusions/calls.

Cost model:
  * flops: dot ops = 2 * |result| * |contracted dims| (batch dims fall out
    naturally since they appear in the result); elementwise ops = |result|;
    everything else 0 — matmul-dominated programs are what the MXU roofline
    term measures.
  * bytes: per *top-level* op = result + operands; fusion = parameters +
    result only (internal traffic stays on-chip) — i.e. an HBM-traffic
    model, not a "every HLO op" model; while = trips * body bytes.
  * collectives: result bytes per op, bucketed by opcode, trip-multiplied.

Validated against XLA's own cost_analysis on scan-free programs (see
tests/test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:e\d+m\d+\w*)?)\[([\d,]*)\]")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$", re.S)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*?\)\s*->\s*.+\{$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_ARG_RE = re.compile(r"%([\w.\-]+)")

_ZERO_BYTE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota",
}
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "tanh", "negate", "rsqrt", "sqrt", "log", "power",
    "and", "or", "xor", "not", "compare", "select", "clamp", "convert",
    "floor", "ceil", "sign", "cosine", "sine", "logistic", "expm1", "log1p",
    "remainder", "atan2", "cbrt",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_info(type_str: str) -> tuple[int, int]:
    """(element_count_total, byte_count_total) over possibly-tuple types."""
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # args + attrs (everything after the opening paren)
    is_root: bool = False


class HloCostModel:
    def __init__(self, hlo_text: str, skip_trailing: frozenset = frozenset()):
        """``skip_trailing``: set of (dim_-2, dim_-1) trailing-shape pairs
        whose tensors are EXCLUDED from byte accounting. The dry-run uses it
        to remove the reference attention's materialized S^2 score tensors,
        whose HBM traffic the fused Pallas kernels eliminate; the kernels'
        analytic streaming traffic is added back by the caller (see
        launch/dryrun.py and EXPERIMENTS.md §Perf iteration 1)."""
        self.skip_trailing = skip_trailing
        self.comps: dict[str, list[_Op]] = {}
        self.entry: str | None = None
        self._parse(hlo_text)
        self._symtab: dict[str, dict[str, str]] = {
            cname: {op.name: op.type_str for op in ops} for cname, ops in self.comps.items()
        }
        self._cache: dict[str, dict] = {}
        self.skipped_bytes = 0.0

    def _parse(self, text: str) -> None:
        current = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith(("HloModule", "//", "#")):
                continue
            mc = _COMP_RE.match(line)
            if mc and "=" not in line.split("(")[0]:
                current = mc.group(2)
                self.comps[current] = []
                if mc.group(1):
                    self.entry = current
                continue
            if line.startswith("}"):
                continue
            if current is None:
                continue
            mo = _OP_RE.match(line)
            if mo:
                self.comps[current].append(
                    _Op(mo.group(1), mo.group(2), mo.group(3), mo.group(4),
                        is_root=line.startswith("ROOT"))
                )

    # ------------------------------------------------------------------
    def cost(self, comp: str | None = None) -> dict:
        comp = comp or self.entry
        if comp in self._cache:
            return self._cache[comp]
        total = {"flops": 0.0, "bytes": 0.0, "collectives": defaultdict(float), "collective_count": 0.0}
        sym = self._symtab.get(comp, {})
        for op in self.comps.get(comp, []):
            self._add_op(op, sym, total)
        total["collectives"] = dict(total["collectives"])
        self._cache[comp] = total
        return total

    def _bytes(self, type_str: str) -> int:
        """Byte count of a (possibly tuple) type, excluding skip_trailing
        shapes; excluded bytes are tallied in self.skipped_bytes."""
        total = 0
        for dtype, dims in _SHAPE_RE.findall(type_str):
            if dtype not in _DTYPE_BYTES:
                continue
            d = [int(x) for x in dims.split(",") if x]
            n = 1
            for x in d:
                n *= x
            nb = n * _DTYPE_BYTES[dtype]
            if len(d) >= 2 and (d[-2], d[-1]) in self.skip_trailing:
                self.skipped_bytes += nb
                continue
            total += nb
        return total

    def _operand_bytes(self, op: _Op, sym: dict[str, str]) -> int:
        args_part = op.rest.split("), ")[0] if "), " in op.rest else op.rest.rstrip(")")
        nbytes = 0
        for ref in _ARG_RE.findall(args_part):
            t = sym.get(ref)
            if t:
                nbytes += self._bytes(t)
        return nbytes

    def _operand_bytes_list(self, op: _Op, sym: dict[str, str]) -> list[int]:
        args_part = op.rest.split("), ")[0] if "), " in op.rest else op.rest.rstrip(")")
        out = []
        for ref in _ARG_RE.findall(args_part):
            t = sym.get(ref)
            if t:
                out.append(self._bytes(t))
        return out

    def _root(self, comp: str) -> _Op | None:
        ops = self.comps.get(comp, [])
        for op in ops:
            if op.is_root:
                return op
        return ops[-1] if ops else None

    def _fusion_param_bytes(self, comp: str) -> int:
        """Bill a fusion's inputs honoring internal slicing: a parameter
        consumed ONLY by dynamic-slice/gather ops inside the body is read
        window-at-a-time (the scan-xs pattern), not in full."""
        body = self.comps.get(comp, [])
        consumers: dict[str, list[_Op]] = {}
        for o in body:
            args_part = o.rest.split("), ")[0] if "), " in o.rest else o.rest.rstrip(")")
            for ref in _ARG_RE.findall(args_part):
                consumers.setdefault(ref, []).append(o)
        total = 0
        for o in body:
            if o.opcode != "parameter":
                continue
            full = self._bytes(o.type_str)
            cs = consumers.get(o.name, [])
            if cs and all(c.opcode in ("dynamic-slice", "gather") for c in cs):
                # window billing never exceeds the full read (index scalars
                # also feed the slice op; they stay billed at scalar size)
                total += min(full, sum(self._bytes(c.type_str) for c in cs))
            else:
                total += full
        return total

    def _inplace_update_bytes(self, comp: str) -> int | None:
        """If a fusion's root is dynamic-update-slice, XLA executes it in
        place: HBM traffic is the small inputs + 2x the update region, NOT
        the full carried buffer. Returns the update-region bytes (or None)."""
        root = self._root(comp)
        if root is None or root.opcode != "dynamic-update-slice":
            return None
        sym = self._symtab.get(comp, {})
        operands = self._operand_bytes_list(root, sym)
        # operand 0 = big buffer, operand 1 = update region
        return operands[1] if len(operands) >= 2 else None

    def _add_op(self, op: _Op, sym: dict[str, str], total: dict) -> None:
        elems, _ = _shape_info(op.type_str)
        res_bytes = self._bytes(op.type_str)
        oc = op.opcode
        if oc == "while":
            trips = 1
            mt = _TRIP_RE.search(op.rest)
            if mt:
                trips = int(mt.group(1))
            mb = _BODY_RE.search(op.rest)
            if mb:
                body = self.cost(mb.group(1))
                total["flops"] += trips * body["flops"]
                total["bytes"] += trips * body["bytes"]
                for k, v in body["collectives"].items():
                    total["collectives"][k] += trips * v
                total["collective_count"] += trips * body["collective_count"]
            return
        if oc in ("fusion", "call", "async-start"):
            m = _CALLS_RE.search(op.rest) or _TO_APPLY_RE.search(op.rest)
            upd = None
            comp_name = m.group(1) if m and m.group(1) in self.comps else None
            if comp_name:
                inner = self.cost(comp_name)
                total["flops"] += inner["flops"]
                for k, v in inner["collectives"].items():
                    total["collectives"][k] += v
                total["collective_count"] += inner["collective_count"]
                upd = self._inplace_update_bytes(comp_name)
            if upd is not None and comp_name:
                # in-place DUS fusion: slice-aware inputs minus the aliased
                # buffer, plus read+write of the update region
                param_bytes = self._fusion_param_bytes(comp_name)
                biggest = max(self._operand_bytes_list(op, sym), default=0)
                total["bytes"] += max(0, param_bytes - biggest) + 2 * upd
            elif comp_name:
                # HBM traffic of a fusion = inputs (window-billed) + outputs
                total["bytes"] += res_bytes + self._fusion_param_bytes(comp_name)
            else:
                total["bytes"] += res_bytes + self._operand_bytes(op, sym)
            return
        coll = next((c for c in _COLLECTIVES if oc == c or oc == c + "-start"), None)
        if coll:
            total["collectives"][coll] += res_bytes
            total["collective_count"] += 1
            total["bytes"] += res_bytes + self._operand_bytes(op, sym)
            return
        if oc in _ZERO_BYTE_OPS or oc.endswith("-done"):
            return
        if oc == "dynamic-update-slice":
            # executed in place: read+write the update region only
            operands = self._operand_bytes_list(op, sym)
            upd = operands[1] if len(operands) >= 2 else res_bytes
            total["bytes"] += 2 * upd + sum(operands[2:])
            return
        if oc in ("dynamic-slice", "gather"):
            # reads only the sliced/gathered elements, not the whole source
            total["bytes"] += 2 * res_bytes
            return
        if oc == "scatter":
            operands = self._operand_bytes_list(op, sym)
            upd = operands[2] if len(operands) >= 3 else res_bytes
            total["bytes"] += 2 * upd + (operands[1] if len(operands) >= 2 else 0)
            return
        if oc == "dot":
            contract = 1
            mlc = _LHS_CONTRACT_RE.search(op.rest)
            first_arg = _ARG_RE.search(op.rest)
            if mlc and first_arg:
                lhs_t = sym.get(first_arg.group(1), "")
                m_sh = _SHAPE_RE.search(lhs_t)
                if m_sh:
                    dims = [int(d) for d in m_sh.group(2).split(",") if d]
                    for idx in mlc.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
            total["flops"] += 2.0 * elems * contract
            total["bytes"] += res_bytes + self._operand_bytes(op, sym)
            return
        if oc in _ELEMENTWISE:
            total["flops"] += float(elems)
        total["bytes"] += res_bytes + self._operand_bytes(op, sym)


def analyze(hlo_text: str, skip_trailing: frozenset = frozenset()) -> dict:
    """Entry-point: loop-aware {flops, bytes, collectives{op: bytes}, count}."""
    model = HloCostModel(hlo_text, skip_trailing=skip_trailing)
    out = model.cost()
    out["collective_bytes"] = float(sum(out["collectives"].values()))
    out["skipped_bytes_once"] = float(model.skipped_bytes)  # pre-trip-multiplied
    return out


def top_dots(hlo_text: str, n: int = 20) -> list[tuple[float, str, str]]:
    """Debug view: the top-n dot ops by trip-multiplied FLOPs.
    Returns (flops, computation, op line snippet)."""
    model = HloCostModel(hlo_text)
    # trip multiplier per computation: entry = 1; while bodies *= trips
    mult: dict[str, float] = {model.entry: 1.0}
    changed = True
    while changed:
        changed = False
        for cname, ops in model.comps.items():
            if cname not in mult:
                continue
            for op in ops:
                if op.opcode == "while":
                    mb = _BODY_RE.search(op.rest)
                    mt = _TRIP_RE.search(op.rest)
                    if mb:
                        m = mult[cname] * (int(mt.group(1)) if mt else 1)
                        if mult.get(mb.group(1)) != m:
                            mult[mb.group(1)] = m
                            changed = True
                elif op.opcode in ("fusion", "call", "async-start"):
                    mc = _CALLS_RE.search(op.rest) or _TO_APPLY_RE.search(op.rest)
                    if mc and mc.group(1) in model.comps:
                        if mult.get(mc.group(1), 0) < mult[cname]:
                            mult[mc.group(1)] = mult[cname]
                            changed = True
    rows = []
    for cname, ops in model.comps.items():
        sym = model._symtab[cname]
        m = mult.get(cname, 1.0)
        for op in ops:
            if op.opcode != "dot":
                continue
            elems, _ = _shape_info(op.type_str)
            contract = 1
            mlc = _LHS_CONTRACT_RE.search(op.rest)
            fa = _ARG_RE.search(op.rest)
            if mlc and fa:
                msh = _SHAPE_RE.search(sym.get(fa.group(1), ""))
                if msh:
                    dims = [int(d) for d in msh.group(2).split(",") if d]
                    for idx in mlc.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
            rows.append((m * 2.0 * elems * contract, cname,
                         f"x{m:g} {op.type_str[:60]} dot({op.rest[:120]}"))
    rows.sort(reverse=True)
    return rows[:n]
