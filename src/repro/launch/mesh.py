"""Production mesh definitions.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
is pure data parallelism across pods (or, in EchoPFL-over-pods mode, one FL
client per pod slice).

Plane mesh: the server's parameter plane (core/plane.py) shards its
(capacity, dim) row store over a dedicated "plane" axis (rows = cluster
centers / anchors / per-client last uploads) and optionally "model" (the
flat parameter dim). Built by :func:`make_plane_mesh`; selected at runtime
by the ``REPRO_PLANE_MESH`` env knob via :func:`plane_mesh_from_env`.
"""
from __future__ import annotations

import os

import jax

# TPU v5e roofline constants (per chip) — used by benchmarks/bench_roofline.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names — smoke tests and the
    quickstart use it so the same shardings lower everywhere."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_plane_mesh(row_shards: int | None = None, *, dim_shards: int = 1) -> jax.sharding.Mesh:
    """Mesh for the sharded parameter plane: axes ("plane",) or, when
    ``dim_shards > 1``, ("plane", "model"). Rows (fleet state: centers,
    anchors, per-client last uploads) spread over "plane"; the flat
    parameter dim may additionally spread over "model" for models whose
    single row outgrows one device."""
    n = len(jax.devices())
    if dim_shards < 1 or n % dim_shards != 0:
        raise ValueError(f"dim_shards {dim_shards} must divide device count {n}")
    if row_shards is None:
        row_shards = n // dim_shards
    if dim_shards == 1:
        return jax.make_mesh((row_shards,), ("plane",))
    return jax.make_mesh((row_shards, dim_shards), ("plane", "model"))


def _mesh_from_spec(spec: str) -> jax.sharding.Mesh | None:
    """Shared mesh-spec grammar: ""/"0"/"off"/"none" -> None (single-device,
    the default); "auto" -> all local devices on the "plane" axis; "R" ->
    exactly R row shards (so "1" is a 1-device mesh, not auto); "RxM" -> R
    row shards x M dim shards."""
    spec = spec.strip().lower()
    if spec in ("", "0", "off", "none"):
        return None
    if spec == "auto":
        n = len(jax.devices())
        return None if n == 1 else make_plane_mesh(n)
    if "x" in spec:
        rows, dims = (int(p) for p in spec.split("x", 1))
        return make_plane_mesh(rows, dim_shards=dims)
    return make_plane_mesh(int(spec))


def plane_mesh_from_env() -> jax.sharding.Mesh | None:
    """Mesh for the *server* parameter plane, from ``REPRO_PLANE_MESH``."""
    return _mesh_from_spec(os.environ.get("REPRO_PLANE_MESH", ""))


def fleet_mesh_from_env() -> jax.sharding.Mesh | None:
    """Mesh for the *client fleet* engine (its model plane and the batched
    ``(clients, n, dim)`` data tensors), from ``REPRO_FLEET_MESH``. Same
    grammar as ``REPRO_PLANE_MESH``; kept separate so server-plane sharding
    experiments do not silently reshard the simulated devices too."""
    return _mesh_from_spec(os.environ.get("REPRO_FLEET_MESH", ""))


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
