"""Production mesh definitions.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
is pure data parallelism across pods (or, in EchoPFL-over-pods mode, one FL
client per pod slice).
"""
from __future__ import annotations

import jax

# TPU v5e roofline constants (per chip) — used by benchmarks/bench_roofline.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names — smoke tests and the
    quickstart use it so the same shardings lower everywhere."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
