import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
)
# Lower the AD-able reference attention (clean SPMD semantics); the cost
# model substitutes the Pallas kernels' analytic traffic for its S^2 tensors.
os.environ.setdefault("REPRO_ATTN_COST_PROXY", "1")
# ^ The two lines above MUST run before any jax import/init (jax locks the
# device count on first use), hence no module docstring above them.
#
# Multi-pod dry-run: lower + compile every (architecture x input-shape)
# cell on the production meshes, prove memory fits, and extract the roofline
# terms (FLOPs / bytes from cost_analysis, collective bytes parsed from the
# partitioned HLO).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
#
# Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json
# incrementally, so a crash or timeout loses only the in-flight cell.

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY, SHAPES, supports_shape
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import specs as SP
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, batch_axes, make_production_mesh
from repro.launch.shardings import batch_shardings, cache_shardings, param_shardings, replicated
from repro.models.steps import make_prefill_step, make_serve_step, make_train_step

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"\b(f32|f16|bf16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")
_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
          "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-tensor bytes of every collective op in the partitioned HLO.
    (Per-device program -> per-device collective bytes.)"""
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip().lstrip("%")
        m = re.match(r"[\w.\-]+\s*=\s*(.+)", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = next((c for c in _COLLECTIVES if f" {c}(" in rhs or rhs.startswith(c + "(")
                   or f"{c}-start(" in rhs or f" {c}-start(" in rhs), None)
        if op is None:
            continue
        if f"{op}-done" in rhs:
            continue
        shapes = _SHAPE_RE.findall(rhs.split("(")[0] + "(")  # result type(s) only
        nbytes = 0.0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[op] += nbytes
        out["count"] += 1
    return out


def sharded_bytes(tree, shardings, mesh) -> float:
    """Per-device resident bytes implied by the shardings (exact, logical)."""
    total = 0.0
    for leaf, sh in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(shardings)):
        n = 1
        for d in leaf.shape:
            n *= d
        nbytes = n * leaf.dtype.itemsize
        spec = sh.spec if hasattr(sh, "spec") else None
        shards = 1
        if spec:
            for axes in spec:
                if axes is None:
                    continue
                for a in (axes if isinstance(axes, tuple) else (axes,)):
                    shards *= mesh.shape[a]
        total += nbytes / shards
    return total


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh) -> tuple:
    """Build the jit'd step with shardings and lower it. Returns (lowered,
    aux dict with logical per-device byte counts)."""
    dp = 1
    for a in batch_axes(mesh):
        dp *= mesh.shape[a]
    aux: dict = {}
    if shape.kind == "train":
        # §Perf execution policy: remat stays ON (measured: disabling it in
        # favor of deeper microbatching RAISED HBM traffic ~23% — XLA saves
        # far more f32 residuals without remat; see EXPERIMENTS.md §Perf,
        # refuted hypothesis). Microbatches are sized so the remat-saved
        # per-layer inputs fit a ~4GB live-activation budget.
        import math as _math

        tokens_dev = (shape.global_batch // dp if shape.global_batch % dp == 0
                      else shape.global_batch) * shape.seq_len
        saved_inputs = tokens_dev * 2.0 * cfg.d_model * cfg.num_layers
        want = max(cfg.train.microbatches, _math.ceil(saved_inputs / 4e9))
        cfg = dataclasses.replace(cfg, train=dataclasses.replace(cfg.train, microbatches=want))
        n_eff = SP.effective_microbatches(cfg, shape, dp)
        cfg = dataclasses.replace(
            cfg, train=dataclasses.replace(cfg.train, microbatches=n_eff, remat=True)
        )
        aux["microbatches"] = n_eff
        aux["remat"] = True
        spec = SP.input_specs(cfg, shape)
        state, batch = spec["state"], spec["batch"]
        state_sh = state._replace(
            params=param_shardings(cfg, mesh, state.params),
            opt_state=param_shardings(cfg, mesh, state.opt_state),
            step=replicated(mesh),
        )
        batch_sh = batch_shardings(cfg, shape, mesh, batch)
        step = make_train_step(cfg)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=0)
        args = (state, batch)
        aux["state_bytes_per_device"] = sharded_bytes(state, state_sh, mesh)
    elif shape.kind == "prefill":
        spec = SP.input_specs(cfg, shape)
        params, batch = spec["params"], spec["batch"]
        p_sh = param_shardings(cfg, mesh, params)
        b_sh = batch_shardings(cfg, shape, mesh, batch)
        jitted = jax.jit(make_prefill_step(cfg), in_shardings=(p_sh, b_sh))
        args = (params, batch)
        aux["state_bytes_per_device"] = sharded_bytes(params, p_sh, mesh)
    else:  # decode
        spec = SP.input_specs(cfg, shape)
        params, cache, batch = spec["params"], spec["cache"], spec["batch"]
        p_sh = param_shardings(cfg, mesh, params)
        c_sh = cache_shardings(cfg, mesh, cache, shape.global_batch)
        b_sh = batch_shardings(cfg, shape, mesh, batch)
        jitted = jax.jit(make_serve_step(cfg), in_shardings=(p_sh, c_sh, b_sh), donate_argnums=1)
        args = (params, cache, batch)
        aux["state_bytes_per_device"] = sharded_bytes(params, p_sh, mesh)
        aux["cache_bytes_per_device"] = sharded_bytes(cache, c_sh, mesh)
    with mesh:
        lowered = jitted.lower(*args)
    return lowered, aux


def flash_attention_analytic_bytes(cfg: ModelConfig, shape: ShapeSpec, mesh, block: int = 1024) -> float:
    """Per-device HBM traffic of the flash attention kernels (fwd + bwd) for
    one step, from the tile-streaming model the kernels implement:

        fwd  : q read nk times, k/v read nq times (per kv head), o written
        bwd  : dq kernel ~ fwd; dkv kernel streams q/do per (group, qi)
        remat: checkpointed layers recompute fwd before bwd

    These are the bytes the S^2 filter removed from the reference lowering,
    replaced by what the fused kernel actually moves (EXPERIMENTS.md §Perf)."""
    attn_layers = sum(1 for l in cfg.all_layers if l.mixer in ("attn", "attn_local"))
    if attn_layers == 0 or shape.kind == "decode":
        return 0.0
    S, B = shape.seq_len, shape.global_batch
    dp = 1
    for a in batch_axes(mesh):
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    B_l = B // dp if B % dp == 0 else B
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = max(1, H // KV)
    h_sharded = H % tp == 0 and tp > 1
    H_l = H // tp if h_sharded else H
    if h_sharded and KV % tp != 0:
        KV_l = max(1, H_l // G)
    else:
        KV_l = KV // tp if (h_sharded and KV % tp == 0) else KV
    if cfg.mla is not None:
        hd = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        hd = dv = cfg.resolved_head_dim
    blk = min(block, S)
    nq = nk = (S + blk - 1) // blk
    itemsize = 2  # bf16 activations
    per_layer = (H_l * nk * S * hd + KV_l * nq * S * (hd + dv) + H_l * S * dv) * B_l * itemsize
    passes = 4.0 if shape.kind == "train" else 1.0  # fwd + remat-fwd + dq + dkv
    return attn_layers * per_layer * passes


def roofline_terms(flops_per_dev: float, bytes_per_dev: float, coll: dict) -> dict:
    comm = sum(v for k, v in coll.items() if k != "count")
    return {
        "compute_s": flops_per_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_per_dev / HBM_BW,
        "collective_s": comm / ICI_BW,
        "collective_bytes_per_device": comm,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str, skip_existing: bool = False) -> dict:
    cfg = ARCH_REGISTRY[arch]
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    ok, reason = supports_shape(cfg, shape)
    if not ok:
        record["status"] = "SKIP"
        record["reason"] = reason
        _write(path, record)
        return record

    try:
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.devices.size
        from repro.models import dist

        with dist.use_mesh(mesh):  # flash attention runs shard_mapped
            lowered, aux = lower_cell(cfg, shape, mesh)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    record[attr] = int(v)
        # raw XLA numbers (loop bodies counted ONCE — kept for reference)
        cost = compiled.cost_analysis() or {}
        record["xla_flops_raw"] = float(cost.get("flops", 0.0))
        record["xla_bytes_raw"] = float(cost.get("bytes accessed", 0.0))
        # loop-aware analysis (while bodies x known_trip_count) — the real terms
        t2 = time.time()
        has_attn = any(l.mixer in ("attn", "attn_local") for l in cfg.all_layers)
        skip = frozenset()
        if has_attn and shape.kind in ("train", "prefill"):
            skip = frozenset({(shape.seq_len, shape.seq_len)})
        la = hlo_analyze(compiled.as_text(), skip_trailing=skip)
        record["analyze_s"] = round(time.time() - t2, 1)
        flops = float(la["flops"])
        bytes_acc = float(la["bytes"])
        if skip:
            flash_bytes = flash_attention_analytic_bytes(cfg, shape, mesh)
            record["attn_s2_bytes_skipped_once"] = la.get("skipped_bytes_once", 0.0)
            record["attn_flash_bytes_added"] = flash_bytes
            bytes_acc += flash_bytes
        record["hlo_flops_per_device"] = flops
        record["hlo_bytes_per_device"] = bytes_acc
        coll = dict(la["collectives"])
        coll["count"] = la["collective_count"]
        record["collectives"] = coll
        record.update(aux)
        record["devices"] = int(n_dev)

        terms = roofline_terms(flops, bytes_acc, coll)
        record["roofline"] = terms
        n_params = SP.model_param_count(cfg)
        n_active = SP.model_active_param_count(cfg)
        record["params"] = n_params
        record["active_params"] = n_active
        if shape.kind == "train":
            tokens = shape.seq_len * shape.global_batch
            record["model_flops"] = 6.0 * n_active * tokens
        elif shape.kind == "prefill":
            tokens = shape.seq_len * shape.global_batch
            record["model_flops"] = 2.0 * n_active * tokens
        else:
            record["model_flops"] = 2.0 * n_active * shape.global_batch
        total_hlo = flops * n_dev
        record["model_flops_ratio"] = record["model_flops"] / total_hlo if total_hlo else None
        dominant = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
        record["bottleneck"] = dominant
        record["status"] = "OK"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["status"] = "FAIL"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(path, record)
    return record


def _write(path: str, record: dict) -> None:
    with open(path, "w") as f:
        json.dump(record, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = sorted(ARCH_REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    results = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod, args.out, args.skip_existing)
                status = r["status"]
                extra = ""
                if status == "OK":
                    terms = r["roofline"]
                    extra = (f"compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s "
                             f"coll={terms['collective_s']:.4f}s bottleneck={r['bottleneck']} "
                             f"lower={r['lower_s']}s compile={r['compile_s']}s")
                elif status == "SKIP":
                    extra = r["reason"]
                else:
                    extra = r["error"][:200]
                print(f"[{status}] {arch} x {shape} x {r['mesh']}: {extra}", flush=True)
                results.append(r)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
