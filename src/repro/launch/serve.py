"""Production serving driver: prefill + batched fixed-buffer decode on a
named mesh (the decode_32k / long_500k cells' execution path).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --mesh smoke --batch 4 --prompt 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.configs.base import reduced_config
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.shardings import cache_shardings, param_shardings
from repro.models import init_cache, init_params, make_serve_step
from repro.models.steps import make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--mesh", default="smoke", choices=["smoke", "pod", "multipod"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = ARCH_REGISTRY[args.arch]
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode step")
    mesh = {"smoke": make_smoke_mesh,
            "pod": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    from repro.models import dist

    dist.set_mesh(mesh)

    params = init_params(cfg, jax.random.PRNGKey(0))
    p_sh = param_shardings(cfg, mesh, params)
    params = jax.device_put(params, p_sh)

    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=1)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt)))
    with mesh:
        t0 = time.time()
        logits, pre_cache = prefill(params, {"tokens": prompts})
        cache = init_cache(cfg, args.batch, ctx_len=args.prompt, margin=args.gen + 8)

        def graft(fixed, pre):
            if fixed.shape == pre.shape:
                return pre
            axis = next(i for i, (a, b) in enumerate(zip(fixed.shape, pre.shape)) if a != b)
            pad = [(0, 0)] * fixed.ndim
            pad[axis] = (0, fixed.shape[axis] - pre.shape[axis])
            return jnp.pad(pre, pad)

        cache = jax.tree_util.tree_map(graft, cache, pre_cache)
        cache = jax.device_put(cache, cache_shardings(cfg, mesh, cache, args.batch))
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        out = []
        t0 = time.time()
        for _ in range(args.gen):
            out.append(np.asarray(tok))
            logits, cache = serve(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    toks = np.concatenate(out, axis=1)
    print(f"prefill: {args.batch}x{args.prompt} in {t_prefill:.2f}s")
    print(f"decode:  {args.batch}x{args.gen} tokens in {t_decode:.2f}s "
          f"({args.batch*args.gen/t_decode:,.0f} tok/s, incl. first-step compile)")
    print(f"sample: {toks[0, :12].tolist()}")


if __name__ == "__main__":
    main()
