"""FedAvg [McMahan et al. 2017]: synchronous, single global model, waits
for every client each round — the paper's accuracy/communication baseline."""
from __future__ import annotations

from typing import Any

from repro.common.pytrees import tree_weighted_mean
from repro.core.server import Downlink

PyTree = Any


class FedAvg:
    name = "fedavg"
    is_synchronous = True

    def __init__(self, init_params: PyTree, client_sizes: dict[Any, int]):
        self.global_model = init_params
        self.client_sizes = client_sizes
        self.version = 0

    def initial_models(self, client_ids):
        return {cid: self.global_model for cid in client_ids}

    def model_for(self, client_id):
        return self.global_model

    def groups(self, client_ids):
        return {"global": list(client_ids)}

    def select(self, group_id, members, rnd):
        return list(members)  # waits for all devices

    def finish_round(self, group_id, uploads: dict, t: float):
        trees = list(uploads.values())
        weights = [self.client_sizes[cid] for cid in uploads]
        self.global_model = tree_weighted_mean(trees, weights)
        self.version += 1
        return [Downlink(cid, self.global_model, self.version, 0, "broadcast") for cid in uploads]

    def stats(self):
        return {"version": self.version}
