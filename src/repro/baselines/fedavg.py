"""FedAvg [McMahan et al. 2017]: synchronous, single global model, waits
for every client each round — the paper's accuracy/communication baseline.

The seed implementation averaged each round's cohort with a per-leaf,
per-client Python loop (``tree_weighted_mean``) — O(leaves × clients)
host-side dispatches at every barrier, so comm-cost head-to-heads against
the fleet-batched EchoPFL path were partly measuring Python overhead.
This port keeps the global model as ONE flat f32 vector (the same layout
the parameter plane and the client fleet use) and reduces the whole
cohort as a single fused launch over the stacked ``(B, dim)`` upload
matrix. Sample-count weights normalize in exact host float64 and cast
once to f32, so the reduction consumes identical operands regardless of
client backend — the loop-vs-fleet parity test pins the trajectories
bitwise-equal.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import flatten_spec
from repro.core.server import Downlink

PyTree = Any


@jax.jit
def _weighted_mean(ws, us):
    # one fused reduction over the stacked cohort: (B,) @ (B, dim) -> (dim,).
    # ws is pre-normalized (sums to 1 in float64, cast once to f32), so no
    # divide lives on device and the launch is a single contraction
    return jnp.tensordot(ws, us, axes=1)


class FedAvg:
    name = "fedavg"
    is_synchronous = True

    def __init__(self, init_params: PyTree, client_sizes: dict[Any, int]):
        self.spec = flatten_spec(init_params)
        self._vec = self.spec.flatten(init_params)
        self.client_sizes = client_sizes
        self.version = 0
        self._view: tuple[int, PyTree] = (0, init_params)  # (version, pytree) cache

    @property
    def global_model(self) -> PyTree:
        """Current global model as a pytree — version-cached, so repeat
        reads between rounds (every client's ``model_for`` at an eval tick)
        share one unflatten AND one object identity (what the fleet's
        eval-row cache and the simulator's broadcast coalescing key on)."""
        if self._view[0] != self.version:
            self._view = (self.version, self.spec.unflatten(self._vec))
        return self._view[1]

    def initial_models(self, client_ids):
        return {cid: self.global_model for cid in client_ids}

    def model_for(self, client_id):
        return self.global_model

    def groups(self, client_ids):
        return {"global": list(client_ids)}

    def select(self, group_id, members, rnd):
        return list(members)  # waits for all devices

    def finish_round(self, group_id, uploads: dict, t: float):
        us = jnp.stack([self.spec.flatten(p) for p in uploads.values()])
        w = np.asarray([self.client_sizes[cid] for cid in uploads], dtype=np.float64)
        ws = jnp.asarray((w / w.sum()).astype(np.float32))
        self._vec = _weighted_mean(ws, us)
        self.version += 1
        return [Downlink(cid, self.global_model, self.version, 0, "broadcast") for cid in uploads]

    def stats(self):
        return {"version": self.version}
