"""Standalone: each client trains locally, no federation — the paper's
lower-bound baseline (personalization without collaboration)."""
from __future__ import annotations

from typing import Any

from repro.core.server import Downlink

PyTree = Any


class Standalone:
    name = "standalone"
    is_synchronous = True

    def __init__(self, init_params: PyTree):
        self.init_params = init_params
        self.models: dict[Any, PyTree] = {}

    def initial_models(self, client_ids):
        return {cid: self.init_params for cid in client_ids}

    def model_for(self, client_id):
        return self.models.get(client_id, self.init_params)

    def groups(self, client_ids):
        return {cid: [cid] for cid in client_ids}

    def select(self, group_id, members, rnd):
        return list(members)

    def finish_round(self, group_id, uploads: dict, t: float):
        (cid, params), = uploads.items()
        self.models[cid] = params
        return [Downlink(cid, params, 0, 0, "local")]

    def stats(self):
        return {}
