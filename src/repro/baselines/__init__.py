from repro.baselines.fedavg import FedAvg
from repro.baselines.fedasyn import FedAsyn
from repro.baselines.fedsea import FedSEA
from repro.baselines.clusterfl import ClusterFL
from repro.baselines.oort import Oort
from repro.baselines.standalone import Standalone

__all__ = ["FedAvg", "FedAsyn", "FedSEA", "ClusterFL", "Oort", "Standalone"]
