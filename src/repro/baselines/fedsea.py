"""FedSEA [Sun et al. 2022]: semi-asynchronous — the server schedules
periodic synchronization points and aggregates whatever arrived; updates
from stragglers that miss their window are discarded (FedSEA mitigates,
but does not eliminate, the resulting error — we model the discard, which
is the behavior EchoPFL's Fig. 2 argument targets)."""
from __future__ import annotations

from typing import Any

from repro.common.pytrees import tree_weighted_mean
from repro.core.server import Downlink

PyTree = Any


class FedSEA:
    name = "fedsea"
    is_synchronous = False

    def __init__(self, init_params: PyTree, *, sync_interval: float = 120.0, staleness_window: int = 2):
        self.global_model = init_params
        self.tick_interval = sync_interval
        self.version = 0
        self.buffer: dict[Any, tuple[PyTree, int]] = {}
        self.dropped = 0

    def initial_models(self, client_ids):
        return {cid: self.global_model for cid in client_ids}

    def model_for(self, client_id):
        return self.global_model

    def handle_upload(self, client_id, params, base_version, n_samples, t):
        if self.version - base_version > 2:  # straggler beyond window: dropped
            self.dropped += 1
            return [Downlink(client_id, self.global_model, self.version, 0, "unicast")]
        self.buffer[client_id] = (params, n_samples)
        return []  # held until the next synchronization point

    def on_tick(self, t):
        if not self.buffer:
            return []
        trees = [p for p, _ in self.buffer.values()]
        weights = [n for _, n in self.buffer.values()]
        incoming = tree_weighted_mean(trees, weights)
        # blend buffered average into global (semi-async partial aggregation)
        from repro.common.pytrees import tree_lerp

        frac = min(1.0, len(self.buffer) / 4)
        self.global_model = tree_lerp(self.global_model, incoming, 0.5 * frac + 0.25)
        self.version += 1
        out = [
            Downlink(cid, self.global_model, self.version, 0, "unicast") for cid in self.buffer
        ]
        self.buffer.clear()
        return out

    def stats(self):
        return {"version": self.version, "dropped": self.dropped}
