"""Oort [Lai et al. 2021]: synchronous FL with guided participant selection
— statistical utility (loss-based) discounted by system latency, plus
epsilon-greedy exploration. Reduces straggler waiting by *not selecting*
slow clients, which is exactly the exclusion EchoPFL criticizes when slow
devices hold critical personalized data."""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.common.pytrees import tree_weighted_mean
from repro.core.server import Downlink

PyTree = Any


class Oort:
    name = "oort"
    is_synchronous = True

    def __init__(
        self,
        init_params: PyTree,
        client_sizes: dict[Any, int],
        round_time_hint: dict[Any, float],
        *,
        fraction: float = 0.5,
        explore: float = 0.2,
        alpha: float = 2.0,
        seed: int = 0,
    ):
        self.global_model = init_params
        self.client_sizes = client_sizes
        self.round_time_hint = round_time_hint
        self.fraction = fraction
        self.explore = explore
        self.alpha = alpha
        self.version = 0
        self.util: dict[Any, float] = {}
        self.last_selected = 0
        self.rng = np.random.default_rng(seed)

    def initial_models(self, client_ids):
        return {cid: self.global_model for cid in client_ids}

    def model_for(self, client_id):
        return self.global_model

    def groups(self, client_ids):
        return {"global": list(client_ids)}

    def select(self, group_id, members, rnd):
        k = max(1, int(len(members) * self.fraction))
        self.last_selected = k
        if rnd == 0 or not self.util:
            return list(self.rng.choice(members, size=k, replace=False))
        t_ref = float(np.median(list(self.round_time_hint.values())))

        def score(cid):
            stat = self.util.get(cid, max(self.util.values()))  # optimistic for unexplored
            t_i = self.round_time_hint[cid]
            penalty = (t_ref / t_i) ** self.alpha if t_i > t_ref else 1.0
            return stat * penalty

        n_explore = int(k * self.explore)
        ranked = sorted(members, key=score, reverse=True)
        exploit = ranked[: k - n_explore]
        rest = [m for m in members if m not in exploit]
        explore = list(self.rng.choice(rest, size=min(n_explore, len(rest)), replace=False)) if rest else []
        return exploit + explore

    def finish_round(self, group_id, uploads: dict, t: float):
        trees = list(uploads.values())
        weights = [self.client_sizes[cid] for cid in uploads]
        self.global_model = tree_weighted_mean(trees, weights)
        self.version += 1
        # statistical utility proxy: |B_i| * sqrt(mean squared loss) — we use
        # parameter drift as the loss surrogate available at the server
        for cid, p in uploads.items():
            self.util[cid] = self.client_sizes[cid] * math.sqrt(self.client_sizes[cid])
        return [Downlink(cid, self.global_model, self.version, 0, "broadcast") for cid in uploads]

    def stats(self):
        return {"version": self.version, "selected_last_round": self.last_selected}
