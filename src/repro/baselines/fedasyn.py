"""FedAsyn [Xie et al. 2019]: fully asynchronous single global model with
polynomial staleness weight decay — the decay is exactly what EchoPFL
rejects (it discounts slow devices' knowledge; Challenge #2)."""
from __future__ import annotations

from typing import Any

from repro.common.pytrees import tree_lerp
from repro.core.server import Downlink
from repro.core.staleness import StalenessTracker

PyTree = Any


class FedAsyn:
    name = "fedasyn"
    is_synchronous = False

    def __init__(self, init_params: PyTree, *, alpha: float = 0.6, decay_power: float = 0.5):
        self.global_model = init_params
        self.alpha = alpha
        self.decay_power = decay_power
        self.version = 0
        self.staleness = StalenessTracker()

    def initial_models(self, client_ids):
        return {cid: self.global_model for cid in client_ids}

    def model_for(self, client_id):
        return self.global_model

    def handle_upload(self, client_id, params, base_version, n_samples, t):
        staleness = max(0, self.version - base_version)
        self.staleness.record(staleness)
        weight = self.alpha * (1.0 + staleness) ** (-self.decay_power)  # stale updates decayed
        self.global_model = tree_lerp(self.global_model, params, weight)
        self.version += 1
        return [Downlink(client_id, self.global_model, self.version, 0, "unicast")]

    def stats(self):
        return {"version": self.version, "staleness": self.staleness.snapshot()}
