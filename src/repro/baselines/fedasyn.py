"""FedAsyn [Xie et al. 2019]: fully asynchronous single global model with
polynomial staleness weight decay — the decay is exactly what EchoPFL
rejects (it discounts slow devices' knowledge; Challenge #2).

The seed implementation blended per-leaf pytrees in a per-upload Python
loop — O(leaves) dispatches per arrival, and no batched ingest at all, so
comm-cost head-to-heads against the fleet-batched EchoPFL path were really
measuring Python overhead. This port keeps the global model as ONE flat
f32 vector (the same layout the parameter plane and the client fleet use)
and ingests a coalesced window of arrivals as one ``lax.scan`` chain
launch (:func:`_lerp_chain`) with a single device_get for the window's
unicast downlinks.

Bitwise discipline: both the per-event blend and the scan body emit the
canonical fenced two-op expression (see ``plane.lerp_vec``) with the
staleness-decayed weight as a *traced* f32 operand — the weight itself is
computed in exact host float64 and cast once, so per-event and coalesced
trajectories are bitwise-identical (the parity tests pin this).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytrees import flatten_spec
from repro.core.server import Downlink
from repro.core.staleness import StalenessTracker

PyTree = Any


@jax.jit
def _lerp_dyn(v, u, t):
    # dynamic-t variant of plane.lerp_vec: the same fenced two-op blend, but
    # with the per-upload weight traced (one compiled launch for every
    # staleness level instead of one jit cache entry per weight)
    m1, m2 = jax.lax.optimization_barrier(((1.0 - t) * v, t * u))
    return m1 + m2


@jax.jit
def _lerp_chain(v0, us, ts):
    # sequential-equivalent window ingest: scan the fenced blend over the
    # arrivals in event order, emitting every intermediate model (the
    # per-upload unicast downlink payloads) plus the final carry
    def step(v, ut):
        u, t = ut
        m1, m2 = jax.lax.optimization_barrier(((1.0 - t) * v, t * u))
        v2 = m1 + m2
        return v2, v2

    return jax.lax.scan(step, v0, (us, ts))


class FedAsyn:
    name = "fedasyn"
    is_synchronous = False

    def __init__(self, init_params: PyTree, *, alpha: float = 0.6, decay_power: float = 0.5):
        self.spec = flatten_spec(init_params)
        self._vec = self.spec.flatten(init_params)
        self.alpha = alpha
        self.decay_power = decay_power
        self.version = 0
        self.staleness = StalenessTracker()
        self._view: tuple[int, PyTree] = (0, init_params)  # (version, pytree) cache

    @property
    def global_model(self) -> PyTree:
        """Current global model as a pytree — version-cached, so repeat
        reads between ingests (every client's ``model_for`` at an eval
        tick) share one unflatten AND one object identity (what the fleet's
        eval-row cache and the simulator's broadcast run-coalescing key on)."""
        if self._view[0] != self.version:
            self._view = (self.version, self.spec.unflatten(self._vec))
        return self._view[1]

    def initial_models(self, client_ids):
        return {cid: self.global_model for cid in client_ids}

    def model_for(self, client_id):
        return self.global_model

    def _weight(self, base_version: int, version: int) -> np.float32:
        staleness = max(0, version - base_version)
        self.staleness.record(staleness)
        # stale updates decayed; exact host float64, one f32 cast, so the
        # per-event and chain launches consume the identical operand
        return np.float32(self.alpha * (1.0 + staleness) ** (-self.decay_power))

    def handle_upload(self, client_id, params, base_version, n_samples, t):
        w = self._weight(base_version, self.version)
        self._vec = _lerp_dyn(self._vec, self.spec.flatten(params), w)
        self.version += 1
        return [Downlink(client_id, self.global_model, self.version, 0, "unicast")]

    def handle_uploads(self, batch: list[tuple]) -> list[list[Downlink]]:
        """Batched ingest for a coalesced window of arrivals: one fused scan
        of the sequential blends (bitwise the per-event chain), one
        device_get, and the per-upload downlink models fan out as numpy
        views over the window's stacked result."""
        # each in-window arrival sees the version as bumped by the arrivals
        # before it — exactly what sequential handle_upload calls would do
        ws = np.stack([
            self._weight(bv, self.version + j)
            for j, (_, _, bv, _, _) in enumerate(batch)
        ])
        us = jnp.stack([self.spec.flatten(p) for _, p, _, _, _ in batch])
        self._vec, models = _lerp_chain(self._vec, us, ws)
        models_np = np.asarray(jax.device_get(models))
        models_np.flags.writeable = False  # leaves are views: freeze
        out = []
        for j, (cid, _p, _bv, _n, _t) in enumerate(batch):
            self.version += 1
            self._view = (self.version, self.spec.unflatten_np(models_np[j]))
            out.append([Downlink(cid, self._view[1], self.version, 0, "unicast")])
        return out

    def stats(self):
        return {"version": self.version, "staleness": self.staleness.snapshot()}
