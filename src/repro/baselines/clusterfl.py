"""ClusterFL [Ouyang et al. 2021]: synchronous clustering-based PFL.

Round 0 trains everyone from the seed and clusters the uploaded weights
(k-means over flattened parameters — the synchronous, full-information
counterpart of EchoPFL's on-arrival clustering; it is the clustering
oracle used in the paper's Fig. 11 comparison). Later rounds run FedAvg
*within* each cluster, with a per-cluster barrier (Fig. 1c): a cluster only
waits for its own slowest member.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.common.pytrees import tree_flat_vector, tree_weighted_mean
from repro.core.server import Downlink

PyTree = Any


def kmeans(x: np.ndarray, k: int, rng: np.random.Generator, iters: int = 50, restarts: int = 10) -> np.ndarray:
    """k-means with restarts (ClusterFL is the paper's clustering *oracle*,
    so it deserves a properly converged solution)."""
    best_assign, best_inertia = None, np.inf
    for _ in range(restarts):
        centers = x[rng.choice(len(x), size=k, replace=False)].copy()
        assign = np.full(len(x), -1)
        for _ in range(iters):
            d = np.linalg.norm(x[:, None] - centers[None], axis=-1)
            new_assign = np.argmin(d, axis=1)
            if (new_assign == assign).all():
                break
            assign = new_assign
            for c in range(k):
                if (assign == c).any():
                    centers[c] = x[assign == c].mean(0)
        inertia = float((np.linalg.norm(x - centers[assign], axis=-1) ** 2).sum())
        if inertia < best_inertia:
            best_inertia, best_assign = inertia, assign
    return best_assign


class ClusterFL:
    name = "clusterfl"
    is_synchronous = True

    def __init__(self, init_params: PyTree, client_sizes: dict[Any, int], *, num_clusters: int = 4, seed: int = 0):
        self.init_params = init_params
        self.client_sizes = client_sizes
        self.num_clusters = num_clusters
        self.rng = np.random.default_rng(seed)
        self.assignment: dict[Any, int] = {}
        self.centers: dict[int, PyTree] = {}
        self.versions: dict[int, int] = {}
        self._clustered = False

    def initial_models(self, client_ids):
        return {cid: self.init_params for cid in client_ids}

    def model_for(self, client_id):
        cid = self.assignment.get(client_id)
        return self.centers.get(cid, self.init_params)

    def groups(self, client_ids):
        if not self._clustered:
            return {"warmup": list(client_ids)}
        out: dict[int, list] = {}
        for client, cl in self.assignment.items():
            out.setdefault(cl, []).append(client)
        return out

    def select(self, group_id, members, rnd):
        return list(members)  # per-cluster barrier still waits for all members

    def finish_round(self, group_id, uploads: dict, t: float):
        if not self._clustered:
            vecs = np.stack([np.asarray(tree_flat_vector(p)) for p in uploads.values()])
            ids = list(uploads)
            assign = kmeans(vecs, min(self.num_clusters, len(ids)), self.rng)
            for cid, cl in zip(ids, assign):
                self.assignment[cid] = int(cl)
            for cl in set(assign.tolist()):
                members = [cid for cid in ids if self.assignment[cid] == cl]
                self.centers[cl] = tree_weighted_mean(
                    [uploads[m] for m in members], [self.client_sizes[m] for m in members]
                )
                self.versions[cl] = 1
            self._clustered = True
            return [
                Downlink(cid, self.centers[self.assignment[cid]], 1, self.assignment[cid], "broadcast")
                for cid in ids
            ]
        members = list(uploads)
        center = tree_weighted_mean(
            [uploads[m] for m in members], [self.client_sizes[m] for m in members]
        )
        self.centers[group_id] = center
        self.versions[group_id] = self.versions.get(group_id, 0) + 1
        return [
            Downlink(cid, center, self.versions[group_id], group_id, "broadcast") for cid in members
        ]

    def membership_matrix(self, client_ids: list) -> np.ndarray:
        n = len(client_ids)
        out = np.zeros((n, n), bool)
        for i, a in enumerate(client_ids):
            for j, b in enumerate(client_ids):
                out[i, j] = self.assignment.get(a) == self.assignment.get(b) and a in self.assignment
        return out

    def stats(self):
        return {"clusters": len(self.centers)}
