from repro.data.partition import dirichlet_partition, shard_partition
from repro.data.synthetic import ClientDataset, FederatedTask, make_task

__all__ = [
    "dirichlet_partition",
    "shard_partition",
    "ClientDataset",
    "FederatedTask",
    "make_task",
]
