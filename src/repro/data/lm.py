"""Synthetic LM token pipeline for the architecture training drivers.

Offline container -> no real corpora. We synthesize token streams with
enough structure (Zipfian unigram + short-range Markov back-off) that loss
decreases measurably during the example training runs, while staying
vocab-size exact for each assigned architecture.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    zipf_a: float = 1.2
    markov_order: int = 1
    markov_weight: float = 0.5
    seed: int = 0


class TokenStream:
    """Infinite iterator of (tokens, labels) next-token-prediction batches."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, min(v, 4096) + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._support = self.rng.permutation(v)[: len(ranks)]
        self._probs = probs / probs.sum()
        # Deterministic successor table: makes the stream learnable.
        self._succ = self.rng.integers(0, len(ranks), size=len(ranks))

    def _sample_seq(self, n: int) -> np.ndarray:
        cfg = self.cfg
        idx = np.empty(n, dtype=np.int64)
        idx[0] = self.rng.choice(len(self._probs), p=self._probs)
        unigram = self.rng.choice(len(self._probs), p=self._probs, size=n)
        coins = self.rng.random(n)
        for t in range(1, n):
            if coins[t] < cfg.markov_weight:
                idx[t] = self._succ[idx[t - 1]]
            else:
                idx[t] = unigram[t]
        return self._support[idx]

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        seqs = np.stack([self._sample_seq(cfg.seq_len + 1) for _ in range(cfg.batch_size)])
        return seqs[:, :-1].astype(np.int32), seqs[:, 1:].astype(np.int32)


def token_stream(vocab_size: int, seed: int = 0, batch: int = 4, seq: int = 32):
    """Infinite generator of train-step batches {"tokens", "labels"}."""
    stream = TokenStream(TokenStreamConfig(vocab_size=vocab_size, seq_len=seq,
                                           batch_size=batch, seed=seed))
    while True:
        tokens, labels = stream.next_batch()
        yield {"tokens": tokens, "labels": labels}
