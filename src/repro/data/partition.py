"""Non-IID federated partitioners.

Two standard schemes from the FL literature, matching the paper's setups:

- ``shard_partition``: each client holds data from a fixed small number of
  classes (the paper: 2-class/device for CIFAR-10-like, 3-class for
  UbiSound-like), with unbalanced within-class counts.
- ``dirichlet_partition``: class proportions per client drawn from
  Dir(alpha); alpha -> 0 is extreme heterogeneity.
"""
from __future__ import annotations

import numpy as np


def shard_partition(
    labels: np.ndarray,
    num_clients: int,
    classes_per_client: int,
    rng: np.random.Generator,
    unbalanced: bool = True,
) -> list[np.ndarray]:
    """Return per-client index arrays where each client sees a class subset."""
    num_classes = int(labels.max()) + 1
    by_class = [np.flatnonzero(labels == c) for c in range(num_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    cursor = [0] * num_classes
    out: list[np.ndarray] = []
    for i in range(num_clients):
        classes = rng.choice(num_classes, size=classes_per_client, replace=False)
        picks = []
        for c in classes:
            avail = len(by_class[c]) - cursor[c]
            base = len(by_class[c]) * classes_per_client // num_clients
            take = int(base * rng.uniform(0.5, 1.5)) if unbalanced else base
            take = max(1, min(take, avail))
            picks.append(by_class[c][cursor[c] : cursor[c] + take])
            cursor[c] = (cursor[c] + take) % max(len(by_class[c]) - 1, 1)
        out.append(np.concatenate(picks))
    return out


def dirichlet_partition(
    labels: np.ndarray,
    num_clients: int,
    alpha: float,
    rng: np.random.Generator,
    min_size: int = 8,
) -> list[np.ndarray]:
    num_classes = int(labels.max()) + 1
    n = len(labels)
    while True:
        idx_batch: list[list[int]] = [[] for _ in range(num_clients)]
        for c in range(num_classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.repeat(alpha, num_clients))
            # Cap clients already holding >= fair share.
            props = props * (np.array([len(b) for b in idx_batch]) < n / num_clients)
            props = props / props.sum()
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for b, part in zip(idx_batch, np.split(idx_c, cuts)):
                b.extend(part.tolist())
        if min(len(b) for b in idx_batch) >= min_size:
            return [np.asarray(b) for b in idx_batch]
