"""Synthetic federated tasks mirroring the paper's four applications.

The container is offline, so CIFAR-10 / HAR-UCI / UbiSound / the private
file-cleaning set are replaced by *structured* synthetic counterparts with
matched cardinalities (classes, feature dims, client counts). The generative
model is chosen so the paper's phenomena actually appear:

- K latent *data clusters* (user groups with similar behavior): each cluster
  applies its own orthogonal transform + class-prototype offsets, so models
  trained in the same latent cluster converge to nearby parameters (this is
  what makes clustering-based PFL work, and what Fig. 11 measures).
- Within a cluster, clients hold non-IID *label subsets* via shard/dirichlet
  partitioning (the paper: 2-class/device CIFAR, 3-class UbiSound).
- Optional *distribution shift* events (Fig. 18): a client's transform is
  swapped mid-run to a different latent cluster.

Tasks (paper Sec. 7.1):
  T1 image_recognition   10 classes, dim 128  (CIFAR-10-like)
  T2 har                  6 classes, dim  64  (HAR-UCI-like, 30 users)
  T3 sound_detection      9 classes, dim  96  (UbiSound-like)
  T4 file_cleaning        2 classes, dim 128  (Delete/Retain)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.partition import dirichlet_partition, shard_partition

TASKS = {
    "image_recognition": dict(num_classes=10, dim=128, classes_per_client=2),
    "har": dict(num_classes=6, dim=64, classes_per_client=3),
    "sound_detection": dict(num_classes=9, dim=96, classes_per_client=3),
    "file_cleaning": dict(num_classes=2, dim=128, classes_per_client=2),
}


@dataclasses.dataclass
class ClientDataset:
    """One client's local split. Arrays are host numpy; steps move to device."""

    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    latent_cluster: int  # ground-truth cluster id (for evaluation only)

    @property
    def n(self) -> int:
        return len(self.y_train)

    def label_histogram(self, num_classes: int) -> np.ndarray:
        return np.bincount(self.y_train, minlength=num_classes).astype(np.float64)


@dataclasses.dataclass
class FederatedTask:
    name: str
    num_classes: int
    dim: int
    clients: list[ClientDataset]
    transforms: np.ndarray  # (K, dim, dim) latent-cluster transforms

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def shift_client(self, client_id: int, new_cluster: int, rng: np.random.Generator) -> None:
        """Simulate a data-distribution shift (Fig. 18): resample this client's
        data under another latent cluster's transform."""
        c = self.clients[client_id]
        n_train, n_test = len(c.y_train), len(c.y_test)
        x, y = _sample(
            rng, self.num_classes, self.dim, n_train + n_test,
            self.transforms[new_cluster], labels=np.concatenate([c.y_train, c.y_test]),
        )
        self.clients[client_id] = ClientDataset(
            x_train=x[:n_train], y_train=y[:n_train],
            x_test=x[n_train:], y_test=y[n_train:],
            latent_cluster=new_cluster,
        )


def _prototypes(rng: np.random.Generator, num_classes: int, dim: int) -> np.ndarray:
    protos = rng.normal(size=(num_classes, dim))
    return protos / np.linalg.norm(protos, axis=1, keepdims=True) * 3.0


def _orthogonal(rng: np.random.Generator, dim: int) -> np.ndarray:
    q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
    return q


_PROTO_CACHE: dict[tuple, np.ndarray] = {}


def _sample(rng, num_classes, dim, n, transform, labels=None, noise=1.2):
    key = (num_classes, dim)
    if key not in _PROTO_CACHE:
        _PROTO_CACHE[key] = _prototypes(np.random.default_rng(12345), num_classes, dim)
    protos = _PROTO_CACHE[key]
    if labels is None:
        labels = rng.integers(0, num_classes, size=n)
    x = protos[labels] @ transform.T + noise * rng.normal(size=(n, dim))
    return x.astype(np.float32), labels.astype(np.int32)


def make_task(
    name: str,
    num_clients: int,
    rng: np.random.Generator,
    latent_clusters: int = 4,
    samples_per_client: int = 256,
    partition: str = "shard",
    dirichlet_alpha: float = 0.3,
    test_frac: float = 0.2,
) -> FederatedTask:
    spec = TASKS[name]
    num_classes, dim = spec["num_classes"], spec["dim"]
    transforms = np.stack([_orthogonal(rng, dim) for _ in range(latent_clusters)])

    # The paper's non-IID recipe ("each device contains 2-class data, and the
    # data within each class can be unbalanced"): a latent cluster is a group
    # of devices sharing the *same class subset* (plus its own feature
    # transform); within the cluster, per-class proportions are unbalanced.
    cpc = spec["classes_per_client"]
    subsets = []
    for k in range(latent_clusters):
        start = (k * cpc) % num_classes
        subset = [(start + j) % num_classes for j in range(cpc)]
        subsets.append(np.asarray(sorted(set(subset)), np.int64))

    clients: list[ClientDataset] = []
    assignment = np.sort(rng.integers(0, latent_clusters, size=num_clients))
    for k in range(latent_clusters):
        members = np.flatnonzero(assignment == k)
        for _ in members:
            n_total = samples_per_client + max(1, int(samples_per_client * test_frac))
            if partition == "dirichlet":
                props = rng.dirichlet(np.full(len(subsets[k]), dirichlet_alpha))
            else:  # unbalanced-shard: skewed but nonzero proportions
                props = rng.dirichlet(np.full(len(subsets[k]), 2.0))
            labels = rng.choice(subsets[k], size=n_total, p=props)
            x, y = _sample(rng, num_classes, dim, n_total, transforms[k], labels=labels)
            n_test = max(1, int(n_total * test_frac))
            clients.append(
                ClientDataset(
                    x_train=x[n_test:], y_train=y[n_test:],
                    x_test=x[:n_test], y_test=y[:n_test],
                    latent_cluster=k,
                )
            )
    rng.shuffle(clients)  # client id should not encode the latent cluster
    return FederatedTask(name=name, num_classes=num_classes, dim=dim, clients=clients, transforms=transforms)
