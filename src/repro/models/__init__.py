from repro.models.model import forward, init_params, init_cache
from repro.models.steps import (
    make_eval_step,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "forward",
    "init_params",
    "init_cache",
    "make_train_step",
    "make_eval_step",
    "make_prefill_step",
    "make_serve_step",
]
