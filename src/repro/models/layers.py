"""Layer primitives for the architecture zoo.

Everything is functional: ``init_*`` returns a params dict, ``apply_*``
consumes (params, activations, ...). Mixers optionally take/return a decode
cache; ``cache=None`` means full-sequence (train/prefill) mode.

Numerics policy: params in ``param_dtype``, matmuls in ``compute_dtype``,
softmax/gating/normalizers in float32.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> PyTree:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rms_norm(params: PyTree, x: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) rotated pairwise; positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (reference path — the Pallas flash kernel is the TPU hot path,
# selected in kernels/ops.py; this jnp version is the oracle + CPU path)
# ---------------------------------------------------------------------------


def attention_scores_reference(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    *,
    causal: bool,
    scale: float,
    window: int | None = None,
    softcap: float | None = None,
    q_pos0: jax.Array | int = 0,
    chunk_q: int | None = None,
) -> jax.Array:
    """Grouped-query attention with optional sliding window and logit softcap.

    KV heads are expanded to H before the einsums (Megatron-style KV
    replication). This keeps every activation's head dim == H, which GSPMD
    can shard over the model axis even when TP > KV (the (KV, G) grouped
    formulation blocks propagation there and silently replicates the O(S^2)
    attention compute — a 6x FLOP regression found in the dry-run roofline;
    see EXPERIMENTS.md §Perf iteration 1).

    For long sequences pass ``chunk_q`` to bound peak memory at
    O(chunk_q * Sk) instead of O(Sq * Sk) (memory-efficient attention).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G != 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (B, k.shape[1], KV, G, k.shape[-1]))
        k = k.reshape(B, k.shape[1], H, k.shape[-1])
        v = jnp.broadcast_to(v[:, :, :, None, :], (B, v.shape[1], KV, G, v.shape[-1]))
        v = v.reshape(B, v.shape[1], H, v.shape[-1])

    def block(q_blk, q_pos_blk):
        # q_blk: (B, sq, H, hd); scores (B, H, sq, Sk)
        s = jnp.einsum("bqhd,bshd->bhqs", q_blk, k).astype(jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = jnp.arange(k.shape[1])
        mask = jnp.ones((q_blk.shape[1], k.shape[1]), bool)
        if causal:
            mask &= q_pos_blk[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos_blk[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)
        return o

    q_positions = q_pos0 + jnp.arange(Sq)
    if chunk_q is None or Sq <= chunk_q:
        out = block(q, q_positions)
    else:
        n = Sq // chunk_q
        qs = q[:, : n * chunk_q].reshape(B, n, chunk_q, H, hd)
        ps = q_positions[: n * chunk_q].reshape(n, chunk_q)
        out = jax.lax.map(lambda args: block(*args), (qs.swapaxes(0, 1), ps))
        out = out.swapaxes(0, 1).reshape(B, n * chunk_q, H, v.shape[-1])
        if n * chunk_q < Sq:  # ragged tail
            tail = block(q[:, n * chunk_q :], q_positions[n * chunk_q :])
            out = jnp.concatenate([out, tail], axis=1)
    return out


def init_attention(key, cfg: ModelConfig, dtype) -> PyTree:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    if cfg.mla is not None:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq": _dense_init(ks[0], (d, H, qk_dim), d, dtype),
            "w_dkv": _dense_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim), d, dtype),
            "w_ukv": _dense_init(
                ks[2], (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim), m.kv_lora_rank, dtype
            ),
            "wo": _dense_init(ks[3], (H, m.v_head_dim, d), H * m.v_head_dim, dtype),
            "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        }
    return {
        "wq": _dense_init(ks[0], (d, H, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, KV, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, KV, hd), d, dtype),
        "wo": _dense_init(ks[3], (H, hd, d), H * hd, dtype),
    }


def apply_attention(
    params: PyTree,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    local: bool,
    cache: PyTree | None = None,
    pos0: jax.Array | int = 0,
    return_cache: bool = False,
):
    """Returns (out, new_cache). Cache layout:
      standard: {"k": (B, S_ctx, KV, hd), "v": ...}
      MLA:      {"ckv": (B, S_ctx, lora), "krope": (B, S_ctx, rope_dim)}
    In decode mode (cache is not None) S is the new-token count (1)."""
    if cfg.mla is not None:
        return _apply_mla(params, x, cfg, cache=cache, pos0=pos0, return_cache=return_cache)
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    positions = pos0 + jnp.arange(S)
    q = rope(q, positions, cfg.rope_theta) if not cfg.is_encoder else q
    k = rope(k, positions, cfg.rope_theta) if not cfg.is_encoder else k
    new_entries = {"k": k, "v": v}
    if cache is not None:
        k = jnp.concatenate([cache["k"], k], axis=1)
        v = jnp.concatenate([cache["v"], v], axis=1)
    scale = (
        cfg.query_pre_attn_scalar ** -0.5 if cfg.query_pre_attn_scalar is not None else hd**-0.5
    )
    # flash kernels (fwd + bwd) via ops.attention — (B,H,S,hd) layout; falls
    # back to the materialized-S^2 reference under REPRO_KERNELS=ref
    from repro.kernels import ops as K

    out = K.attention(
        jnp.swapaxes(q, 1, 2),
        jnp.swapaxes(k, 1, 2),
        jnp.swapaxes(v, 1, 2),
        causal=cfg.causal,
        scale=scale,
        window=cfg.sliding_window if local else None,
        softcap=cfg.attn_logit_softcap,
        q_pos0=pos0,
    )
    out = jnp.einsum("bhsk,hkd->bsd", out, params["wo"])
    return out, (new_entries if return_cache else None)


def _apply_mla(params, x, cfg: ModelConfig, *, cache, pos0, return_cache):
    """DeepSeek-V2 multi-head latent attention. The cache holds only the
    compressed latent (kv_lora_rank) + shared rope key — the arch's whole
    point: 512+64 dims instead of 2*16*192 per token."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])  # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    positions = pos0 + jnp.arange(S)
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])  # (B,S,lora+rope)
    ckv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank :]
    ckv = rms_norm(params["kv_norm"], ckv, cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    new_entries = {"ckv": ckv, "krope": k_rope}
    if cache is not None:
        ckv = jnp.concatenate([cache["ckv"], ckv], axis=1)
        k_rope = jnp.concatenate([cache["krope"], k_rope], axis=1)

    ukv = jnp.einsum("bsr,rhk->bshk", ckv, params["w_ukv"])
    k_nope = ukv[..., : m.qk_nope_head_dim]
    v = ukv[..., m.qk_nope_head_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (*k_nope.shape[:3], m.qk_rope_head_dim))],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    from repro.kernels import ops as K

    out = K.attention(
        jnp.swapaxes(q_full, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=cfg.causal, scale=scale, q_pos0=pos0,
    )
    out = jnp.einsum("bhsk,hkd->bsd", out, params["wo"])
    return out, (new_entries if return_cache else None)


# ---------------------------------------------------------------------------
# dense + MoE FFN
# ---------------------------------------------------------------------------


def init_dense_ffn(key, d: int, d_ff: int, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": _dense_init(k1, (d, d_ff), d, dtype),
        "wu": _dense_init(k2, (d, d_ff), d, dtype),
        "wd": _dense_init(k3, (d_ff, d), d_ff, dtype),
    }


def apply_dense_ffn(params: PyTree, x: jax.Array) -> jax.Array:
    from repro.models import dist

    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["wg"]))
    up = dist.constrain(jnp.einsum("bsd,df->bsf", x, params["wu"]), "batch", None, "model")
    h = dist.constrain(gate * up, "batch", None, "model")
    return dist.constrain(jnp.einsum("bsf,fd->bsd", h, params["wd"]), "batch", None, None)


def init_moe_ffn(key, cfg: ModelConfig, dtype) -> PyTree:
    moe = cfg.moe
    d, de, E = cfg.d_model, moe.d_expert, moe.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), d, jnp.float32),
        "wg": _dense_init(ks[1], (E, d, de), d, dtype),
        "wu": _dense_init(ks[2], (E, d, de), d, dtype),
        "wd": _dense_init(ks[3], (E, de, d), de, dtype),
    }
    if moe.num_shared:
        p["shared"] = init_dense_ffn(ks[4], d, moe.num_shared * de, dtype)
    return p


def apply_moe_ffn(
    params: PyTree,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    capacity_factor: float = 1.25,
    group_size: int = 4096,
) -> tuple[jax.Array, jax.Array]:
    """GShard-style top-k dispatch MoE (expert-parallel friendly).

    Tokens are processed in groups; within a group each token routes to its
    top-k experts subject to per-expert capacity C = ceil(k*G*cf/E); overflow
    tokens fall through (residual connection carries them). Returns
    (out, aux_loss) where aux_loss is the standard load-balancing loss.
    """
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.num_experts, moe.top_k
    T = B * S
    xt = x.reshape(T, D)
    g = min(group_size, T)
    n_groups = T // g
    xg = xt[: n_groups * g].reshape(n_groups, g, D)

    logits = jnp.einsum("ngd,de->nge", xg, params["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (n, g, E)
    topw, topi = jax.lax.top_k(probs, K)  # (n, g, K)
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)

    if cfg.moe_dropless:
        C = g  # every token always fits its experts (serving/consistency mode)
    else:
        C = max(1, int(math.ceil(K * g * capacity_factor / E)))
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)  # (n, g, K, E)
    flat = onehot.reshape(n_groups, g * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, g, K, E)
    keep = (pos_in_expert < C) * onehot
    slot = jnp.einsum("ngke,ngke->ngk", pos_in_expert, keep).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32) * jnp.sum(keep, -1, keepdims=True)
    dispatch = jnp.einsum("ngke,ngkc->ngec", keep, slot_oh)  # (n, g, E, C)
    combine = jnp.einsum("ngk,ngke,ngkc->ngec", topw, keep, slot_oh)

    expert_in = jnp.einsum("ngec,ngd->necd", dispatch.astype(xg.dtype), xg)  # (n,E,C,D)
    h_g = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in, params["wg"]))
    h_u = jnp.einsum("necd,edf->necf", expert_in, params["wu"])
    expert_out = jnp.einsum("necf,efd->necd", h_g * h_u, params["wd"])
    out = jnp.einsum("ngec,necd->ngd", combine.astype(expert_out.dtype), expert_out)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    density = jnp.mean(jnp.sum(onehot, axis=2), axis=1)  # (n, E) token fraction
    router_prob = jnp.mean(probs, axis=1)  # (n, E)
    aux = E * jnp.mean(jnp.sum(density * router_prob, axis=-1)) / K

    out_flat = out.reshape(n_groups * g, D)
    if n_groups * g < T:  # ragged tail routes dense through top-1 expert 0 path: rare; pad path
        tail = jnp.zeros((T - n_groups * g, D), out_flat.dtype)
        out_flat = jnp.concatenate([out_flat, tail], axis=0)
    y = out_flat.reshape(B, S, D)
    if moe.num_shared:
        y = y + apply_dense_ffn(params["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — chunked associative scan
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig, dtype) -> PyTree:
    mb = cfg.mamba
    d = cfg.d_model
    di, ds, dc = mb.d_inner(d), mb.d_state, mb.d_conv
    dt_rank = max(16, d // 16)
    ks = jax.random.split(key, 6)
    return {
        "w_in": _dense_init(ks[0], (d, 2 * di), d, dtype),
        "conv_w": _dense_init(ks[1], (dc, di), dc, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_x": _dense_init(ks[2], (di, dt_rank + 2 * ds), di, dtype),
        "w_dt": _dense_init(ks[3], (dt_rank, di), dt_rank, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _dense_init(ks[5], (di, d), di, dtype),
    }


def _mamba_conv(params, x_in, conv_state=None):
    """Causal depthwise conv. x_in: (B, S, Di). conv_state: (B, dc-1, Di)."""
    dc = params["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x_in.shape[0], dc - 1, x_in.shape[2]), x_in.dtype)
    else:
        pad = conv_state.astype(x_in.dtype)
    xp = jnp.concatenate([pad, x_in], axis=1)  # (B, S+dc-1, Di)
    out = sum(
        xp[:, i : i + x_in.shape[1], :] * params["conv_w"][i][None, None, :] for i in range(dc)
    )
    new_state = xp[:, -(dc - 1) :, :]
    return out + params["conv_b"][None, None, :], new_state


def _mamba_ssm_inputs(params, xc, mb):
    dt_rank = params["w_dt"].shape[0]
    ds = mb.d_state
    proj = jnp.einsum("bsi,ir->bsr", xc, params["w_x"])
    dt_r, Bs, Cs = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,Di)
    A = -jnp.exp(params["A_log"])  # (Di, ds)
    dA = jnp.exp(dt[..., None] * A[None, None])  # (B,S,Di,ds)
    dBx = dt[..., None] * Bs[:, :, None, :].astype(jnp.float32) * xc[..., None].astype(jnp.float32)
    return dA, dBx, Cs.astype(jnp.float32)


def apply_mamba(
    params: PyTree,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: PyTree | None = None,
    scan_chunk: int = 256,
):
    """Returns (out, new_cache). cache = {"conv": (B,dc-1,Di), "ssm": (B,Di,ds)}."""
    mb = cfg.mamba
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,di->bsi", x, params["w_in"])
    x_in, z = jnp.split(xz, 2, axis=-1)

    if cache is not None and S == 1:  # decode step
        xc, conv_state = _mamba_conv(params, x_in, cache["conv"])
        xc = jax.nn.silu(xc)
        dA, dBx, Cs = _mamba_ssm_inputs(params, xc, mb)
        h = cache["ssm"] * dA[:, 0] + dBx[:, 0]  # (B,Di,ds)
        y = jnp.einsum("bis,bs->bi", h, Cs[:, 0])[:, None, :]
        new_cache = {"conv": conv_state, "ssm": h}
    else:
        xc, conv_state = _mamba_conv(params, x_in, cache["conv"] if cache else None)
        xc = jax.nn.silu(xc)
        h0 = cache["ssm"] if cache else jnp.zeros((B, x_in.shape[-1], mb.d_state), jnp.float32)

        def chunk_step(h_prev, xs):
            dA_c, dBx_c, Cs_c = xs  # (B, ck, Di, ds) ...
            # associative scan within the chunk
            def combine(a, b):
                return a[0] * b[0], b[0] * a[1] + b[1]

            pA, pB = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
            h_all = pA * h_prev[:, None] + pB  # (B, ck, Di, ds)
            y_c = jnp.einsum("bcis,bcs->bci", h_all, Cs_c)
            return h_all[:, -1], y_c

        ck = min(scan_chunk, S)
        n = S // ck
        dA, dBx, Cs = _mamba_ssm_inputs(params, xc[:, : n * ck], mb)
        resh = lambda t: t.reshape(B, n, ck, *t.shape[2:]).swapaxes(0, 1)
        h_last, ys = jax.lax.scan(chunk_step, h0, (resh(dA), resh(dBx), resh(Cs)))
        y = ys.swapaxes(0, 1).reshape(B, n * ck, -1)
        if n * ck < S:  # ragged tail
            dA_t, dBx_t, Cs_t = _mamba_ssm_inputs(params, xc[:, n * ck :], mb)
            h_last, y_t = chunk_step(h_last, (dA_t, dBx_t, Cs_t))
            y = jnp.concatenate([y, y_t], axis=1)
        new_cache = {"conv": conv_state, "ssm": h_last}

    y = y.astype(x.dtype) + params["D"].astype(x.dtype)[None, None, :] * xc
    out = jnp.einsum("bsi,id->bsd", y * jax.nn.silu(z), params["w_out"])
    return out, new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (chunkwise-parallel matrix memory) + sLSTM (sequential)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    di = int(d * cfg.mlstm_proj_factor)
    h = cfg.num_heads
    hd = di // h
    ks = jax.random.split(key, 7)
    return {
        "w_up": _dense_init(ks[0], (d, 2 * di), d, dtype),
        "wq": _dense_init(ks[1], (h, hd, hd), hd, dtype),  # block-diagonal per head
        "wk": _dense_init(ks[2], (h, hd, hd), hd, dtype),
        "wv": _dense_init(ks[3], (h, hd, hd), hd, dtype),
        "w_i": _dense_init(ks[4], (di, h), di, jnp.float32),
        "w_f": _dense_init(ks[5], (di, h), di, jnp.float32),
        "f_bias": jnp.full((h,), 3.0, jnp.float32),  # forget-gate bias toward remember
        "out_norm": init_rmsnorm(di, dtype),
        "w_down": _dense_init(ks[6], (di, d), di, dtype),
    }


def apply_mlstm(
    params: PyTree,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: PyTree | None = None,
    chunk: int = 64,
):
    """Chunkwise-parallel mLSTM with stabilized exponential gating.

    cache = {"C": (B,h,hd,hd), "n": (B,h,hd), "m": (B,h)}. Within a chunk the
    output is computed attention-style with gate-derived decay masks; across
    chunks a lax.scan carries (C, n, m) — O(1) state in sequence length.
    """
    B, S, d = x.shape
    h = cfg.num_heads
    up = jnp.einsum("bsd,di->bsi", x, params["w_up"])
    x_in, z = jnp.split(up, 2, axis=-1)
    di = x_in.shape[-1]
    hd = di // h
    xh = x_in.reshape(B, S, h, hd)
    q = jnp.einsum("bshk,hkl->bshl", xh, params["wq"]) * (hd**-0.5)
    k = jnp.einsum("bshk,hkl->bshl", xh, params["wk"])
    v = jnp.einsum("bshk,hkl->bshl", xh, params["wv"])
    i_log = jnp.einsum("bsi,ih->bsh", x_in.astype(jnp.float32), params["w_i"])  # (B,S,h)
    f_log = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", x_in.astype(jnp.float32), params["w_f"]) + params["f_bias"]
    )

    if cache is None:
        C0 = jnp.zeros((B, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, h, hd), jnp.float32)
        m0 = jnp.full((B, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]

    def chunk_step(carry, xs):
        C, n, m = carry
        qc, kc, vc, ic, fc = xs  # (B, ck, h, hd) / (B, ck, h)
        ck = qc.shape[1]
        fcum = jnp.cumsum(fc, axis=1)  # (B, ck, h) log decay within chunk
        # stabilizer: per-step running max of (m_prev + fcum) and (fcum - f_t + i_t)
        log_inter = m[:, None, :] + fcum  # contribution of carry state at step t
        log_intra = fcum[:, :, None, :] - fcum[:, None, :, :] + ic[:, None, :, :]
        # intra valid only for s <= t (causal within chunk): (B, t, s, h)
        tri = jnp.tril(jnp.ones((ck, ck), bool))
        log_intra = jnp.where(tri[None, :, :, None], log_intra, -jnp.inf)
        m_new = jnp.maximum(log_inter, jnp.max(log_intra, axis=2))  # (B, ck, h)
        m_new = jnp.maximum(m_new, -1e30)
        inter_w = jnp.exp(log_inter - m_new)  # (B, ck, h)
        intra_w = jnp.exp(log_intra - m_new[:, :, None, :])  # (B,t,s,h)
        # output: inter part reads carry memory, intra part is masked attention
        o_inter = jnp.einsum("bth,bhkl,bthk->bthl", inter_w, C, qc.astype(jnp.float32))
        n_inter = jnp.einsum("bth,bhk,bthk->bth", inter_w, n, qc.astype(jnp.float32))
        s_intra = jnp.einsum("bthk,bshk->btsh", qc.astype(jnp.float32), kc.astype(jnp.float32))
        o_intra = jnp.einsum("btsh,btsh,bshl->bthl", intra_w, s_intra, vc.astype(jnp.float32))
        n_intra = jnp.einsum("btsh,btsh->bth", intra_w, s_intra)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_new)) + 1e-6
        out_c = (o_inter + o_intra) / denom[..., None]
        # carry update to end of chunk
        ftot = fcum[:, -1, :]  # (B,h)
        m_next = jnp.maximum(m + ftot, jnp.max(fcum[:, -1:, :] - fcum + ic, axis=1))
        decay_keep = jnp.exp(m + ftot - m_next)  # (B,h)
        kv_w = jnp.exp(ftot[:, None, :] - fcum + ic - m_next[:, None, :])  # (B,ck,h)
        C_next = decay_keep[..., None, None] * C + jnp.einsum(
            "bsh,bshk,bshl->bhkl", kv_w, kc.astype(jnp.float32), vc.astype(jnp.float32)
        )
        n_next = decay_keep[..., None] * n + jnp.einsum("bsh,bshk->bhk", kv_w, kc.astype(jnp.float32))
        return (C_next, n_next, m_next), out_c

    ck = min(chunk, S)
    n_chunks = S // ck
    resh = lambda t: t[:, : n_chunks * ck].reshape(B, n_chunks, ck, *t.shape[2:]).swapaxes(0, 1)
    carry, outs = jax.lax.scan(chunk_step, (C0, n0, m0), (resh(q), resh(k), resh(v), resh(i_log), resh(f_log)))
    out = outs.swapaxes(0, 1).reshape(B, n_chunks * ck, h, hd)
    if n_chunks * ck < S:
        sl = slice(n_chunks * ck, None)
        carry, tail = chunk_step(carry, (q[:, sl], k[:, sl], v[:, sl], i_log[:, sl], f_log[:, sl]))
        out = jnp.concatenate([out, tail], axis=1)
    new_cache = {"C": carry[0], "n": carry[1], "m": carry[2]}

    out = out.reshape(B, S, di).astype(x.dtype)
    out = rms_norm(params["out_norm"], out, cfg.norm_eps)
    out = out * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", out, params["w_down"]), new_cache


def init_slstm(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    df = int(d * cfg.slstm_proj_factor)
    ks = jax.random.split(key, 4)
    return {
        # gate-aligned (d, 4, d) layout: sharding the LAST dim over "model"
        # gives every device its own channel slice of all four gates, so the
        # recurrence runs fully local under shard_map (§Perf iteration C)
        "wgx": _dense_init(ks[0], (d, 4, d), d, dtype),  # i,f,z,o from input
        "wgh": _dense_init(ks[1], (d, 4, d), d, dtype),  # recurrent
        "gbias": jnp.zeros((4, d), jnp.float32),
        "ffn_up": _dense_init(ks[2], (d, df), d, dtype),
        "ffn_down": _dense_init(ks[3], (df, d), df, dtype),
    }


def apply_slstm(params: PyTree, x: jax.Array, cfg: ModelConfig, *, cache: PyTree | None = None):
    """Strictly sequential sLSTM with exponential gating + stabilizer state.

    cache = {"c": (B,D), "n": (B,D), "m": (B,D), "h": (B,D)}. No parallel
    form exists (the recurrence is non-associative through h_{t-1}) — this is
    inherent to the architecture, noted in DESIGN.md.
    """
    B, S, d = x.shape
    from repro.models import dist

    if cache is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.full((B, d), 1e-6, jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), x.dtype)
    else:
        c0, n0, m0, h0 = cache["c"], cache["n"], cache["m"], cache["h"]

    def recurrence(gx_loc, wh_loc, bias_loc, c0_, n0_, m0_, h0_, *, sharded: bool):
        """Time scan over channel-local shards. ``h`` is the only cross-
        channel coupling: it is all-gathered once per step (B x d, KBs)."""

        def step(carry, gx_t):
            c, n, m, h_full = carry
            gates = gx_t + jnp.einsum("bd,dgk->bgk", h_full, wh_loc) + bias_loc
            gates = gates.astype(jnp.float32)
            i_l, f_l, z_l, o_l = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
            f_log = jax.nn.log_sigmoid(f_l)
            m_new = jnp.maximum(f_log + m, i_l)
            i_g = jnp.exp(i_l - m_new)
            f_g = jnp.exp(f_log + m - m_new)
            c_new = f_g * c + i_g * jnp.tanh(z_l)
            n_new = f_g * n + i_g
            h_new = (jax.nn.sigmoid(o_l) * c_new / jnp.maximum(n_new, 1e-6)).astype(h_full.dtype)
            if sharded:
                h_full_new = jax.lax.all_gather(h_new, "model", axis=1, tiled=True)
            else:
                h_full_new = h_new
            return (c_new, n_new, m_new, h_full_new), h_new

        (c, n, m, hf), hs = jax.lax.scan(
            step, (c0_, n0_, m0_, h0_), gx_loc.swapaxes(0, 1),
            unroll=8 if S >= 64 else 1,
        )
        return hs.swapaxes(0, 1), c, n, m, hs[-1]

    mesh = dist.current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    gx = jnp.einsum("bsd,dgk->bsgk", x, params["wgx"])  # (B,S,4,d) input part
    if mesh is not None and tp > 1 and d % tp == 0 and S > 1:
        from jax import shard_map
        from jax.sharding import PartitionSpec as P

        baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        dpn = 1
        for a in baxes:
            dpn *= mesh.shape[a]
        b_ax = baxes if B % dpn == 0 else None
        out_sm = shard_map(
            lambda gxl, whl, bl, c_, n_, m_, h_: recurrence(
                gxl, whl, bl, c_, n_, m_, h_, sharded=True
            ),
            mesh=mesh,
            in_specs=(
                P(b_ax, None, None, "model"),   # gx: channel-sharded
                P(None, None, "model"),          # w_h columns (gate-aligned)
                P(None, "model"),                # bias
                P(b_ax, "model"), P(b_ax, "model"), P(b_ax, "model"),  # c, n, m
                P(b_ax, None),                   # h replicated across model
            ),
            out_specs=(P(b_ax, None, "model"), P(b_ax, "model"), P(b_ax, "model"),
                       P(b_ax, "model"), P(b_ax, "model")),
            check_vma=False,
        )
        hs_out, c, n, m, h_last = out_sm(gx, params["wgh"], params["gbias"], c0, n0, m0, h0)
        out, h = hs_out, h_last
    else:
        out, c, n, m, h = recurrence(
            gx, params["wgh"], params["gbias"], c0, n0, m0, h0, sharded=False
        )
    out = out + jnp.einsum(
        "bsf,fd->bsd", jax.nn.gelu(jnp.einsum("bsd,df->bsf", out, params["ffn_up"])), params["ffn_down"]
    )
    return out, {"c": c, "n": n, "m": m, "h": h}
