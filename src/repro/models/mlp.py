"""Small MLP client models for the paper's four tasks (Sec. 7.1).

These are the models the federated *protocol* experiments train on CPU;
everything is jit-cached per task config so 100+ simulated clients share
compiled functions.

Two call planes:

* per-client entry points (``local_train``, ``evaluate``,
  ``predict_distributions``) — one dispatch per client, used by the
  event-driven simulator's ``loop`` backend and by direct callers.
* fleet entry points (``fleet_local_train``, ``fleet_evaluate``,
  ``fleet_predict_distributions``) — ``jax.vmap`` over a ``(clients, ...)``
  batch with per-sample validity masks, so ragged client datasets pad to a
  common length and the whole simulated fleet trains/evaluates in ONE
  launch (see :mod:`repro.fl.fleet`). Per-client ``lr``/``epochs``/
  ``head_only`` ride along as vmapped operands: heterogeneous epoch counts
  are realized by masking scan steps past a client's budget, and partial
  fine-tuning (Sec. 4.3.3) by zero-scaling the non-head gradients — so the
  per-row arithmetic matches the per-client path exactly (bitwise on CPU
  for unpadded rows).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.paper_tasks import MLPTaskConfig

PyTree = Any


def init_mlp(cfg: MLPTaskConfig, key: jax.Array) -> PyTree:
    dims = (cfg.input_dim, *cfg.hidden, cfg.num_classes)
    params = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params.append({
            "w": jax.random.normal(k, (din, dout), jnp.float32) / jnp.sqrt(din),
            "b": jnp.zeros((dout,), jnp.float32),
        })
    return params


def mlp_forward(params: PyTree, x: jax.Array) -> jax.Array:
    for layer in params[:-1]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    return x @ params[-1]["w"] + params[-1]["b"]


def _masked_nll(params: PyTree, x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean NLL over the valid samples. With an all-ones mask this reduces
    to ``-mean(logp[y])`` exactly (the padded terms are hard zeros), which
    is what keeps the fleet path numerically aligned with ``_sgd_epoch``."""
    logits = mlp_forward(params, x)
    logp = jax.nn.log_softmax(logits)
    per = jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    per = jnp.where(mask > 0, per, 0.0)
    return -(jnp.sum(per) / jnp.maximum(jnp.sum(mask), 1.0))


@functools.partial(jax.jit, static_argnames=("head_only",))
def _sgd_epoch(params, x, y, lr, head_only: bool = False):
    def loss_fn(p):
        logits = mlp_forward(p, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    if head_only:  # partial fine-tuning after cluster expansion (Sec. 4.3.3)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, grads[:-1])
        grads = zeros + grads[-1:]
    new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def local_train(
    params: PyTree,
    x: jax.Array,
    y: jax.Array,
    *,
    epochs: int = 5,
    lr: float = 0.1,
    head_only: bool = False,
) -> tuple[PyTree, jax.Array]:
    """Per-client full-batch SGD. Returns (params, loss) with the loss as a
    *device scalar* — callers that need a python float sync explicitly; the
    simulator hot path never does, so training no longer blocks the
    dispatch pipeline on a host readback per client per round."""
    loss = jnp.zeros(())
    for _ in range(epochs):
        params, loss = _sgd_epoch(params, x, y, jnp.asarray(lr), head_only=head_only)
    return params, loss


def _scan_train(
    params: PyTree,
    x: jax.Array,  # (n, dim) — padded
    y: jax.Array,  # (n,) — padded entries hold any valid class id
    mask: jax.Array,  # (n,) float validity
    lr: jax.Array,  # () per-client learning rate
    epochs: jax.Array,  # () int32 per-client epoch budget
    head_frac: jax.Array,  # () 1.0 = head-only fine-tuning, 0.0 = full
    max_epochs: int,
) -> tuple[PyTree, jax.Array]:
    """Scan-based multi-epoch step for ONE client (the vmap operand).

    Runs ``max_epochs`` scan steps; steps at or past this client's
    ``epochs`` budget are no-ops (params and loss carried through), so a
    batch of clients with heterogeneous budgets shares one launch. Gradient
    masking reproduces ``_sgd_epoch(head_only=True)``: non-head layers see
    their gradient *selected* to an exact zero (``where``, not scaling, so
    a non-finite gradient can never leak NaN into frozen body params)."""

    def step(carry, e):
        p, last_loss = carry
        loss, grads = jax.value_and_grad(_masked_nll)(p, x, y, mask)
        freeze_body = head_frac > 0
        grads = [
            layer if i == len(grads) - 1 else jax.tree_util.tree_map(
                lambda g: jnp.where(freeze_body, jnp.zeros_like(g), g), layer
            )
            for i, layer in enumerate(grads)
        ]
        new = jax.tree_util.tree_map(lambda a, g: a - lr * g, p, grads)
        active = e < epochs
        p2 = jax.tree_util.tree_map(lambda old, nw: jnp.where(active, nw, old), p, new)
        return (p2, jnp.where(active, loss, last_loss)), None

    (params, loss), _ = jax.lax.scan(step, (params, jnp.zeros(())), jnp.arange(max_epochs))
    return params, loss


@functools.partial(jax.jit, static_argnames=("max_epochs",))
def fleet_local_train(
    params_b: PyTree,  # leaves (K, ...) — one row per client
    x: jax.Array,  # (K, n, dim)
    y: jax.Array,  # (K, n)
    mask: jax.Array,  # (K, n)
    lr: jax.Array,  # (K,)
    epochs: jax.Array,  # (K,) int32
    head_frac: jax.Array,  # (K,) 1.0 where head-only
    *,
    max_epochs: int,
) -> tuple[PyTree, jax.Array]:
    """One launch of local training for a whole client batch: vmap over
    clients of a ``lax.scan`` over epochs. Returns (batched params, (K,)
    final losses)."""
    return jax.vmap(
        functools.partial(_scan_train, max_epochs=max_epochs)
    )(params_b, x, y, mask, lr, epochs, head_frac)


@jax.jit
def evaluate(params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
    pred = jnp.argmax(mlp_forward(params, x), axis=-1)
    return jnp.mean((pred == y).astype(jnp.float32))


def _masked_accuracy(params: PyTree, x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    pred = jnp.argmax(mlp_forward(params, x), axis=-1)
    correct = jnp.where(mask > 0, (pred == y).astype(jnp.float32), 0.0)
    return jnp.sum(correct) / jnp.maximum(jnp.sum(mask), 1.0)


@jax.jit
def fleet_evaluate(params_b: PyTree, x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked accuracy for the whole fleet in one launch: (K,) accuracies
    replacing K per-client ``evaluate`` dispatches per eval tick."""
    return jax.vmap(_masked_accuracy)(params_b, x, y, mask)


@functools.partial(jax.jit, static_argnames=("num_classes",))
def predict_distributions(params: PyTree, x: jax.Array, num_classes: int):
    """Returns (predicted-label histogram F_c, mean soft-label distribution S_c)
    — the client-side ingredients of the Eq. 2/3 feedback."""
    logits = mlp_forward(params, x)
    soft = jax.nn.softmax(logits, axis=-1)
    pred = jnp.argmax(logits, axis=-1)
    hist = jnp.bincount(pred, length=num_classes).astype(jnp.float32)
    return hist, jnp.mean(soft, axis=0)


def _masked_distributions(params: PyTree, x: jax.Array, mask: jax.Array, num_classes: int):
    logits = mlp_forward(params, x)
    soft = jax.nn.softmax(logits, axis=-1)
    pred = jnp.argmax(logits, axis=-1)
    valid = (mask > 0)[:, None]
    onehot = jnp.where(valid, (pred[:, None] == jnp.arange(num_classes)[None, :]).astype(jnp.float32), 0.0)
    hist = jnp.sum(onehot, axis=0)
    smean = jnp.sum(jnp.where(valid, soft, 0.0), axis=0) / jnp.maximum(jnp.sum(mask), 1.0)
    return hist, smean


@functools.partial(jax.jit, static_argnames=("num_classes",))
def fleet_predict_distributions(params_b: PyTree, x: jax.Array, mask: jax.Array, num_classes: int):
    """Batched feedback probe: (F (K, C), S (K, C)) stacks in one launch,
    shaped to feed ``kernels.ops.chi2_feedback_all`` directly."""
    return jax.vmap(
        functools.partial(_masked_distributions, num_classes=num_classes)
    )(params_b, x, mask)
