"""Small MLP client models for the paper's four tasks (Sec. 7.1).

These are the models the federated *protocol* experiments train on CPU;
everything is jit-cached per task config so 100+ simulated clients share
compiled functions.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.paper_tasks import MLPTaskConfig

PyTree = Any


def init_mlp(cfg: MLPTaskConfig, key: jax.Array) -> PyTree:
    dims = (cfg.input_dim, *cfg.hidden, cfg.num_classes)
    params = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params.append({
            "w": jax.random.normal(k, (din, dout), jnp.float32) / jnp.sqrt(din),
            "b": jnp.zeros((dout,), jnp.float32),
        })
    return params


def mlp_forward(params: PyTree, x: jax.Array) -> jax.Array:
    for layer in params[:-1]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    return x @ params[-1]["w"] + params[-1]["b"]


@functools.partial(jax.jit, static_argnames=("head_only",))
def _sgd_epoch(params, x, y, lr, head_only: bool = False):
    def loss_fn(p):
        logits = mlp_forward(p, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    if head_only:  # partial fine-tuning after cluster expansion (Sec. 4.3.3)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, grads[:-1])
        grads = zeros + grads[-1:]
    new = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return new, loss


def local_train(
    params: PyTree,
    x: jax.Array,
    y: jax.Array,
    *,
    epochs: int = 5,
    lr: float = 0.1,
    head_only: bool = False,
) -> tuple[PyTree, float]:
    loss = jnp.zeros(())
    for _ in range(epochs):
        params, loss = _sgd_epoch(params, x, y, jnp.asarray(lr), head_only=head_only)
    return params, float(loss)


@jax.jit
def evaluate(params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
    pred = jnp.argmax(mlp_forward(params, x), axis=-1)
    return jnp.mean((pred == y).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("num_classes",))
def predict_distributions(params: PyTree, x: jax.Array, num_classes: int):
    """Returns (predicted-label histogram F_c, mean soft-label distribution S_c)
    — the client-side ingredients of the Eq. 2/3 feedback."""
    logits = mlp_forward(params, x)
    soft = jax.nn.softmax(logits, axis=-1)
    pred = jnp.argmax(logits, axis=-1)
    hist = jnp.bincount(pred, length=num_classes).astype(jnp.float32)
    return hist, jnp.mean(soft, axis=0)
