"""Model assembly: init / forward / cache for every assigned architecture.

The backbone is ``prefix`` (unrolled) + ``pattern`` × ``num_periods``
(lax.scan over stacked params — O(1) HLO in depth, so the 126-layer model
compiles as fast as the 16-layer one). Decode uses fixed-size KV/state
buffers updated in place (donation-friendly: no cache reallocation per step).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import layers as L

PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype) -> PyTree:
    k_mix, k_ffn = jax.random.split(key)
    p: dict[str, Any] = {"norm1": L.init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = L.init_attention(k_mix, cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = L.init_mamba(k_mix, cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = L.init_mlstm(k_mix, cfg, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = L.init_slstm(k_mix, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
        if spec.ffn == "dense":
            p["ffn"] = L.init_dense_ffn(k_ffn, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = L.init_moe_ffn(k_ffn, cfg, dtype)
    if cfg.use_post_norm:
        p["post_norm1"] = L.init_rmsnorm(cfg.d_model, dtype)
        if spec.ffn != "none":
            p["post_norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
    return p


def _init_period(key, cfg: ModelConfig, dtype) -> PyTree:
    keys = jax.random.split(key, len(cfg.pattern))
    return {f"slot{i}": _init_layer(keys[i], spec, cfg, dtype) for i, spec in enumerate(cfg.pattern)}


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> PyTree:
    k_embed, k_prefix, k_body, k_head = jax.random.split(key, 4)
    embed_scale = 1.0 / math.sqrt(cfg.d_model)  # keeps tied-logit variance O(1)
    params: dict[str, Any] = {
        "embed": (
            jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model), jnp.float32) * embed_scale
        ).astype(dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.prefix:
        pkeys = jax.random.split(k_prefix, len(cfg.prefix))
        params["prefix"] = [
            _init_layer(pkeys[i], spec, cfg, dtype) for i, spec in enumerate(cfg.prefix)
        ]
    if cfg.num_periods:
        bkeys = jax.random.split(k_body, cfg.num_periods)
        params["blocks"] = jax.vmap(lambda k: _init_period(k, cfg, dtype))(bkeys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(k_head, (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# caches (decode buffers)
# ---------------------------------------------------------------------------


def _init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, buf_len: int, dtype) -> PyTree:
    if spec.mixer in ("attn", "attn_local"):
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "ckv": jnp.zeros((batch, buf_len, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, buf_len, m.qk_rope_head_dim), dtype),
            }
        hd = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, buf_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, buf_len, cfg.num_kv_heads, hd), dtype),
        }
    if spec.mixer == "mamba":
        di = cfg.mamba.d_inner(cfg.d_model)
        return {
            "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, cfg.mamba.d_state), jnp.float32),
        }
    if spec.mixer == "mlstm":
        di = int(cfg.d_model * cfg.mlstm_proj_factor)
        h = cfg.num_heads
        hd = di // h
        return {
            "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32),
        }
    if spec.mixer == "slstm":
        d = cfg.d_model
        return {
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.full((batch, d), 1e-6, jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), dtype),
        }
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, ctx_len: int, dtype=jnp.float32, margin: int = 128) -> PyTree:
    """Fixed-size decode buffers sized for ``ctx_len`` context + ``margin``
    generated tokens. ``len`` counts valid tokens already cached."""
    buf = ctx_len + margin
    cache: dict[str, Any] = {"len": jnp.asarray(ctx_len, jnp.int32)}
    if cfg.prefix:
        cache["prefix"] = [
            _init_layer_cache(spec, cfg, batch, buf, dtype) for spec in cfg.prefix
        ]
    if cfg.num_periods:
        def one(_):
            return {
                f"slot{i}": _init_layer_cache(spec, cfg, batch, buf, dtype)
                for i, spec in enumerate(cfg.pattern)
            }
        cache["blocks"] = jax.vmap(one)(jnp.arange(cfg.num_periods))
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_layer(
    lp: PyTree, spec: LayerSpec, cfg: ModelConfig, x, *, cache, pos0, decode, collect=False
):
    h = L.rms_norm(lp["norm1"], x, cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        if decode:
            mix, new_cache = _attn_decode(lp["mixer"], h, cfg, spec.mixer == "attn_local", cache, pos0)
        else:
            mix, new_cache = L.apply_attention(
                lp["mixer"], h, cfg, local=spec.mixer == "attn_local", pos0=pos0,
                return_cache=collect,
            )
    elif spec.mixer == "mamba":
        mix, new_cache = L.apply_mamba(lp["mixer"], h, cfg, cache=cache)
    elif spec.mixer == "mlstm":
        mix, new_cache = L.apply_mlstm(lp["mixer"], h, cfg, cache=cache)
    elif spec.mixer == "slstm":
        mix, new_cache = L.apply_slstm(lp["mixer"], h, cfg, cache=cache)
    else:
        raise ValueError(spec.mixer)
    if cfg.use_post_norm:
        mix = L.rms_norm(lp["post_norm1"], mix, cfg.norm_eps)
    from repro.models import dist

    # pin the residual stream batch-sharded / d_model-replicated: left free,
    # GSPMD shards it over "model", turning every D-contraction into
    # full-d_ff partial sums + all-reduce (§Perf iteration 2)
    x = dist.constrain(x + mix, "batch", None, None)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h2 = L.rms_norm(lp["norm2"], x, cfg.norm_eps)
        if spec.ffn == "dense":
            f = L.apply_dense_ffn(lp["ffn"], h2)
        else:
            f, aux = L.apply_moe_ffn(lp["ffn"], h2, cfg)
        if cfg.use_post_norm:
            f = L.rms_norm(lp["post_norm2"], f, cfg.norm_eps)
        x = dist.constrain(x + f, "batch", None, None)
    return x, new_cache, aux


def _attn_decode(mp, h, cfg: ModelConfig, local: bool, cache, pos0):
    """One-token attention against the fixed-size buffer, in-place update."""
    B = h.shape[0]
    if cfg.mla is not None:
        return _mla_decode_absorbed(mp, h, cfg, cache, pos0)
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", h, mp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, mp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, mp["wv"])
    positions = pos0 + jnp.arange(1)
    if not cfg.is_encoder:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    k_buf = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0))
    v_buf = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0))
    scale = cfg.query_pre_attn_scalar ** -0.5 if cfg.query_pre_attn_scalar is not None else hd**-0.5
    out = L.attention_scores_reference(
        q, k_buf.astype(h.dtype), v_buf.astype(h.dtype),
        causal=True, scale=scale,
        window=cfg.sliding_window if local else None,
        softcap=cfg.attn_logit_softcap, q_pos0=pos0,
    )
    out = jnp.einsum("bshk,hkd->bsd", out, mp["wo"])
    return out, {"k": k_buf, "v": v_buf}


def _mla_decode_absorbed(mp, h, cfg: ModelConfig, cache, pos0):
    """MLA decode with weight absorption: attention runs directly in the
    512-dim latent space — the cache is never up-projected. This is the
    beyond-naive decode path (see EXPERIMENTS.md §Perf)."""
    m = cfg.mla
    H = cfg.num_heads
    q = jnp.einsum("bsd,dhk->bshk", h, mp["wq"])  # (B,1,H,nope+rope)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    positions = pos0 + jnp.arange(1)
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", h, mp["w_dkv"])
    ckv_new, krope_new = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank :]
    ckv_new = L.rms_norm(mp["kv_norm"], ckv_new, cfg.norm_eps)
    krope_new = L.rope(krope_new[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    ckv_buf = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos0, 0))
    krope_buf = jax.lax.dynamic_update_slice(
        cache["krope"], krope_new.astype(cache["krope"].dtype), (0, pos0, 0)
    )

    w_uk = mp["w_ukv"][..., : m.qk_nope_head_dim]  # (lora, H, nope)
    w_uv = mp["w_ukv"][..., m.qk_nope_head_dim :]  # (lora, H, v)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)  # absorb: q in latent space
    s_nope = jnp.einsum("bshr,btr->bhst", q_abs, ckv_buf.astype(q_abs.dtype))
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, krope_buf.astype(q_rope.dtype))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (s_nope + s_rope).astype(jnp.float32) * scale
    t_pos = jnp.arange(ckv_buf.shape[1])
    s = jnp.where((t_pos <= pos0)[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", p.astype(ckv_buf.dtype), ckv_buf)
    out = jnp.einsum("bshr,rhk->bshk", o_lat, w_uv)  # back to per-head v space
    out = jnp.einsum("bshk,hkd->bsd", out, mp["wo"])
    return out, {"ckv": ckv_buf, "krope": krope_buf}


def _sinusoidal(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle)).at[:, 1::2].set(jnp.cos(angle[:, : d // 2]))
    return pe.astype(dtype)


def forward(
    cfg: ModelConfig,
    params: PyTree,
    batch: dict[str, jax.Array],
    cache: PyTree | None = None,
    return_cache: bool = False,
) -> tuple[jax.Array, jax.Array, PyTree | None]:
    """Returns (logits, moe_aux_loss, new_cache).

    batch: {"tokens": (B,S) int32} or {"embeds": (B,S,D)} for frontend-stub
    archs. Decode mode iff ``cache`` is not None (then S == 1 and the new
    token goes to buffer slot ``cache["len"]``). ``return_cache=True`` in
    full-sequence mode collects prefill caches (exact-length buffers).
    """
    decode = cache is not None
    collect = decode or return_cache
    pos0 = cache["len"] if decode else 0
    if "tokens" in batch:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"]
    if cfg.query_pre_attn_scalar is not None:  # gemma scales embeddings
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.is_encoder:
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {"len": pos0 + x.shape[1]} if collect else None

    for i, spec in enumerate(cfg.prefix):
        c_i = cache["prefix"][i] if decode else None
        x, nc, aux = _apply_layer(
            params["prefix"][i], spec, cfg, x, cache=c_i, pos0=pos0, decode=decode, collect=collect
        )
        aux_total += aux
        if collect:
            new_cache.setdefault("prefix", []).append(nc)

    if cfg.num_periods:
        def period_fn(carry, xs):
            x_c, aux_c = carry
            if decode:
                lp, lc = xs
            else:
                lp, lc = xs, {}
            ncs = {}
            for i, spec in enumerate(cfg.pattern):
                x_c, nc, aux = _apply_layer(
                    lp[f"slot{i}"], spec, cfg, x_c,
                    cache=lc.get(f"slot{i}"), pos0=pos0, decode=decode, collect=collect,
                )
                aux_c += aux
                ncs[f"slot{i}"] = nc if nc is not None else 0
            return (x_c, aux_c), (ncs if collect else 0)

        body = period_fn
        if cfg.train.remat and not decode and not collect:
            body = jax.checkpoint(period_fn, prevent_cse=False)
        xs = (params["blocks"], cache["blocks"]) if decode else params["blocks"]
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
        if collect:
            new_cache["blocks"] = ys

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    if cfg.final_logit_softcap is not None:
        cap = cfg.final_logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits, aux_total, new_cache
