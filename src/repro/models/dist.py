"""Distribution context for model internals.

The launch layer (dryrun / train / serve) registers the active mesh here so
that shape-aware layers (flash attention under shard_map) can map themselves
onto per-device local shapes. Tests and single-device examples leave it
unset and get the plain single-device code path.
"""
from __future__ import annotations

import contextlib

import jax

_MESH: jax.sharding.Mesh | None = None


def set_mesh(mesh: jax.sharding.Mesh | None) -> None:
    global _MESH
    _MESH = mesh


def current_mesh() -> jax.sharding.Mesh | None:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    prev = _MESH
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(prev)


def constrain(x: jax.Array, *dims: str | None) -> jax.Array:
    """with_sharding_constraint by logical dim names ("batch" | "model" |
    None), fitted to divisibility. No-op without a registered mesh.

    GSPMD occasionally replicates large layer intermediates (the
    "involuntary full rematerialization" path) instead of keeping them
    TP-sharded; pinning the FFN/MoE intermediates removes d_ff-sized
    all-reduces from the backward pass (EXPERIMENTS.md §Perf iteration 2)."""
    mesh = _MESH
    if mesh is None or mesh.devices.size == 1:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]
    spec = []
    for size, want in zip(x.shape, dims):
        if want == "batch" and size % dp == 0:
            spec.append(baxes)
        elif want == "model" and size % mesh.shape.get("model", 1) == 0:
            spec.append("model")
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
