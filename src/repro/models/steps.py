"""Step factories: train (with microbatch gradient accumulation), eval,
prefill, and single-token serve. These are the functions the launcher
pjit's over the production mesh and the dry-run lowers.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.optim.adafactor import adafactor
from repro.optim.optimizers import Optimizer, adamw, apply_updates, clip_by_global_norm, momentum

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    step: jax.Array


def make_optimizer(cfg: ModelConfig) -> Optimizer:
    t = cfg.train
    if t.optimizer == "adafactor":
        return adafactor(t.learning_rate)
    if t.optimizer == "sgdm":
        return momentum(t.learning_rate, 0.9)
    return adamw(t.learning_rate)


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean next-token CE in fp32. Labels >= vocab (pad region) are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    valid = (labels >= 0) & (labels < vocab)
    ce = jnp.where(valid, logz - gold, 0.0)
    return jnp.sum(ce) / jnp.maximum(jnp.sum(valid), 1)


def _loss_fn(cfg: ModelConfig, params: PyTree, batch: dict) -> tuple[jax.Array, dict]:
    logits, aux, _ = forward(cfg, params, batch)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    loss = ce + 0.01 * aux
    return loss, {"loss": loss, "ce": ce, "moe_aux": aux}


def make_train_step(cfg: ModelConfig, optimizer: Optimizer | None = None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``cfg.train.microbatches > 1`` accumulates gradients over microbatches
    with a lax.scan — this bounds activation memory (the §Perf memory lever
    for the 400B models) while keeping the global batch semantics exact.
    """
    opt = optimizer or make_optimizer(cfg)
    n_micro = max(1, cfg.train.microbatches)

    def split_micro(batch):
        def r(x):
            b = x.shape[0]
            assert b % n_micro == 0, f"global batch {b} not divisible by {n_micro} microbatches"
            return x.reshape(n_micro, b // n_micro, *x.shape[1:])

        return jax.tree_util.tree_map(r, batch)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        grad_fn = jax.value_and_grad(lambda p, mb: _loss_fn(cfg, p, mb), has_aux=True)

        if n_micro == 1:
            (_, metrics), grads = grad_fn(state.params, batch)
        else:
            micro = split_micro(batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro, g_acc, g
                )
                m_acc = jax.tree_util.tree_map(lambda a, b: a + b / n_micro, m_acc, m)
                return (g_acc, m_acc), 0

            g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            m0 = {"loss": jnp.zeros((), jnp.float32), "ce": jnp.zeros((), jnp.float32), "moe_aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), micro)

        grads = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state, step=state.step + 1)
        metrics = dict(metrics, step=new_state.step)
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params: PyTree, batch: dict) -> dict:
        logits, _, _ = forward(cfg, params, batch)
        ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        pred = jnp.argmax(logits, axis=-1)
        acc = jnp.mean((pred == batch["labels"]).astype(jnp.float32))
        return {"ce": ce, "accuracy": acc}

    return eval_step


def make_prefill_step(cfg: ModelConfig):
    """Full-context forward producing logits + exact-length KV/state caches."""

    def prefill(params: PyTree, batch: dict) -> tuple[jax.Array, PyTree]:
        logits, _, cache = forward(cfg, params, batch, return_cache=True)
        return logits[:, -1:, :], cache

    return prefill


def make_serve_step(cfg: ModelConfig):
    """One-token decode against fixed-size buffers (donate the cache arg!)."""

    def serve(params: PyTree, cache: PyTree, batch: dict) -> tuple[jax.Array, PyTree]:
        logits, _, new_cache = forward(cfg, params, batch, cache=cache)
        return logits, new_cache

    return serve
