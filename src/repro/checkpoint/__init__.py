from repro.checkpoint.checkpointer import Checkpointer, latest_step, restore_pytree, save_pytree

__all__ = ["Checkpointer", "save_pytree", "restore_pytree", "latest_step"]
