"""Fault-tolerant checkpointing (no orbax dependency).

Guarantees needed for 1000+-node runs:

- **Atomicity**: writes go to ``<dir>/tmp.<uuid>`` then ``os.replace`` into
  place; a crash mid-write never corrupts the latest valid checkpoint.
- **Manifest**: every step directory carries ``manifest.json`` with the tree
  structure, leaf dtypes/shapes and a payload checksum; restore verifies it.
- **Async**: ``Checkpointer.save_async`` snapshots leaves to host memory
  synchronously (cheap) and writes on a background thread so the train loop
  never blocks on disk.
- **Retention**: keep the most recent ``keep`` checkpoints, never deleting a
  step that has not been superseded by a *verified* newer one.
- **Elastic restart**: ``latest_step`` + ``restore_pytree`` let a rescheduled
  job resume from whatever survived, including the EchoPFL server state
  (cluster centers, Top-K records, RNN predictor weights).

Leaves are stored as one ``.npz`` per checkpoint; pytree structure is encoded
as JSON paths, so the restore side needs no template pytree (but can check
against one).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import re
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


def _paths_and_leaves(tree: PyTree) -> tuple[list[str], list[np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
    leaves = [np.asarray(v) for _, v in flat]
    return paths, leaves


def _checksum(leaves: list[np.ndarray]) -> str:
    h = hashlib.sha256()
    for leaf in leaves:
        h.update(str(leaf.shape).encode())
        h.update(str(leaf.dtype).encode())
        h.update(np.ascontiguousarray(leaf).tobytes()[:65536])  # prefix hash: cheap, catches truncation
    return h.hexdigest()


def save_pytree(directory: str, tree: PyTree, extra: dict | None = None) -> None:
    """Atomically write ``tree`` (+ JSON-serializable ``extra``) to ``directory``.

    Crash-safe at every point: the payload is staged in a ``tmp.<uuid>``
    sibling (fsynced, manifest written last), an existing ``directory``
    is renamed aside rather than deleted, and only then does the staged
    dir rename into place. A kill anywhere in that sequence leaves either
    the old checkpoint or the new one fully intact under a name
    ``latest_step``/``restore_pytree`` will accept — never a half-written
    step, and never a window where the previous checkpoint is already
    destroyed but the new one not yet visible (the old rmtree-then-replace
    overwrite had exactly that window)."""
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, f"tmp.{uuid.uuid4().hex}")
    old = None
    os.makedirs(tmp)
    try:
        paths, leaves = _paths_and_leaves(tree)
        with open(os.path.join(tmp, "leaves.npz"), "wb") as f:
            np.savez(f, **{str(i): leaf for i, leaf in enumerate(leaves)})
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "paths": paths,
            "shapes": [list(x.shape) for x in leaves],
            "dtypes": [str(x.dtype) for x in leaves],
            "checksum": _checksum(leaves),
            "extra": extra or {},
        }
        # manifest last: its presence is what marks a step dir as valid
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(directory):
            old = os.path.join(parent, f"tmp.old.{uuid.uuid4().hex}")
            os.replace(directory, old)
        try:
            os.replace(tmp, directory)
        except BaseException:
            if old is not None and not os.path.exists(directory):
                os.replace(old, directory)  # roll the old checkpoint back
                old = None
            raise
    finally:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
        if old is not None and os.path.isdir(old):
            shutil.rmtree(old, ignore_errors=True)


def restore_pytree(directory: str, like: PyTree | None = None, verify: bool = True) -> tuple[PyTree, dict]:
    """Restore a pytree saved by :func:`save_pytree`. Returns (tree, extra)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(directory, "leaves.npz")) as z:
        leaves = [z[str(i)] for i in range(len(manifest["paths"]))]
    if verify and _checksum(leaves) != manifest["checksum"]:
        raise IOError(f"checkpoint {directory} failed checksum verification")
    if like is not None:
        ref_paths, ref_leaves = _paths_and_leaves(like)
        if ref_paths != manifest["paths"]:
            raise ValueError(
                "checkpoint tree structure mismatch: "
                f"{set(manifest['paths']) ^ set(ref_paths)}"
            )
        treedef = jax.tree_util.tree_structure(like)
        leaves = [leaf.astype(ref.dtype) for leaf, ref in zip(leaves, ref_leaves)]
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
    # No template: rebuild as {path: leaf} dict.
    return dict(zip(manifest["paths"], leaves)), manifest["extra"]


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(root, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class Checkpointer:
    """Step-indexed checkpoint manager with an async writer thread."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._queue: queue.Queue = queue.Queue()
        self._errors: list[BaseException] = []
        self._worker = threading.Thread(target=self._drain, daemon=True)
        self._worker.start()

    def _drain(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save_pytree(self._dir(step), tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._errors.append(e)
            finally:
                self._queue.task_done()

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in (_STEP_RE.match(n) for n in os.listdir(self.root)) if m
        )
        for step in steps[: -self.keep]:
            shutil.rmtree(self._dir(step), ignore_errors=True)

    def save(self, step: int, tree: PyTree, extra: dict | None = None) -> None:
        save_pytree(self._dir(step), tree, extra)
        self._gc()

    def save_async(self, step: int, tree: PyTree, extra: dict | None = None) -> None:
        # Snapshot to host numpy NOW so later in-place donation can't corrupt it.
        snapshot = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), tree)
        self._queue.put((step, snapshot, extra))

    def wait(self) -> None:
        self._queue.join()
        if self._errors:
            raise self._errors.pop()

    def restore_latest(self, like: PyTree | None = None) -> tuple[int, PyTree, dict] | None:
        step = latest_step(self.root)
        if step is None:
            return None
        tree, extra = restore_pytree(self._dir(step), like=like)
        return step, tree, extra

    def close(self) -> None:
        self._queue.put(None)
        self._worker.join(timeout=10)
