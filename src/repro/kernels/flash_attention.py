"""Flash attention (causal / sliding-window / softcap, GQA, dv != dk) as a
Pallas TPU kernel with explicit BlockSpec VMEM tiling.

TPU adaptation (vs. the CUDA flash-attention algorithm): one fused pass with
online softmax; the (block_q x block_k) tile pair lives in VMEM, the MXU
consumes (block_q, hd) x (hd, block_k) matmuls with hd padded to a lane
multiple of 128, and the running (m, l, acc) statistics sit in VMEM scratch
that persists across the sequential innermost grid dimension (TPU grids
execute serially per core, so scratch carries state instead of CUDA's
shared-memory reductions).

The kernel also emits the log-sum-exp rows, which the backward kernels
(flash_attention_bwd.py) consume to recompute probability tiles instead of
storing the O(S^2) matrix — that recomputation is what keeps attention HBM
traffic at O(S^2 * d / block) instead of O(S^2).

Grid: (B, H, Sq/block_q, Sk/block_k) — the k-block axis is innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, softcap: float | None,
    block_q: int, block_k: int, q_pos0: int, num_k_blocks: int, kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (block_k, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_pos0 + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len  # never attend to padded key slots
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (block_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (bq, bk)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l_safe))[:, 0]


def _blocks(sq: int, sk: int, block_q: int, block_k: int) -> tuple[int, int]:
    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (sk - 1).bit_length()))
    return bq, bk


def flash_attention_with_lse(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Sk, hd)
    v: jax.Array,  # (B, KV, Sk, dv)
    *,
    causal: bool = True,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
    q_pos0: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (o: (B,H,Sq,dv), lse: (B,H,Sq) fp32)."""
    B, H, Sq, hd = q.shape
    KV, Sk, dv = k.shape[1], k.shape[2], v.shape[3]
    if H % KV != 0:
        raise ValueError(f"GQA requires num_heads ({H}) divisible by kv_heads ({KV})")
    if k.shape[:3] != v.shape[:3] or k.shape[0] != B or k.shape[3] != hd:
        raise ValueError(f"inconsistent shapes q={q.shape} k={k.shape} v={v.shape}")
    G = H // KV
    scale = hd**-0.5 if scale is None else scale

    # Pad to hardware-aligned tiles: head dims to 128 lanes, seqs to blocks.
    hd_p = math.ceil(hd / 128) * 128
    dv_p = math.ceil(dv / 128) * 128
    block_q, block_k = _blocks(Sq, Sk, block_q, block_k)
    sq_p = math.ceil(Sq / block_q) * block_q
    sk_p = math.ceil(Sk / block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - Sq), (0, hd_p - hd)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - Sk), (0, hd_p - hd)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - Sk), (0, dv_p - dv)))
    nq, nk = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, q_pos0=q_pos0, num_k_blocks=nk, kv_len=Sk,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd_p), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd_p), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dv_p), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_q, dv_p), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, H, sq_p, dv_p), q.dtype),
            jax.ShapeDtypeStruct((B, H, sq_p), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),     # running sum l
            pltpu.VMEM((block_q, dv_p), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return o[:, :, :Sq, :dv], lse[:, :, :Sq]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
    q_pos0: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    o, _ = flash_attention_with_lse(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_pos0=q_pos0, block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return o
