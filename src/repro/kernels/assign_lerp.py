"""Fused on-arrival assignment + mixed-rate center update (Eq. 1 + Sec. 4).

Every upload triggers the same two hot steps: find the L1-nearest center,
then blend the upload into it at the mix rate b. ``assign_and_lerp`` fuses
them into one device-resident pass: the streaming one-vs-many L1 kernel
produces the distance vector, the argmin stays on device, and a
scalar-prefetch kernel reads *only* the winning center row (the argmin
index steers the BlockSpec index map) to emit the blended row — the full
(C, N) center matrix is never re-read, and nothing round-trips through the
host between distance, argmin, and update.

The caller applies hysteresis host-side: when the argmin is vetoed (the
client stays in its previous cluster), the precomputed blended row is
simply discarded and a plain row lerp runs instead.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.l1_distance import l1_distance


def _select_lerp_kernel(idx_ref, c_ref, u_ref, o_ref, *, beta: float):
    del idx_ref  # consumed by the index maps
    c = c_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    # two-op form pinned (no FMA contraction): the blend must emit the same
    # bits as plane.lerp_vec and the coalesced ingest scan, whatever fusion
    # context this kernel lowers in (see assign_and_lerp_ref's rationale)
    m1, m2 = jax.lax.optimization_barrier(((1.0 - beta) * c, beta * u))
    o_ref[...] = m1 + m2


def _select_lerp(
    centers: jax.Array,  # (C, N)
    u: jax.Array,  # (N,)
    idx: jax.Array,  # () int32 — which center row to blend
    beta: float,
    *,
    block_n: int,
    interpret: bool,
) -> jax.Array:
    C, N = centers.shape
    n_p = math.ceil(N / block_n) * block_n
    cp = jnp.pad(centers, ((0, 0), (0, n_p - N)))
    up = jnp.pad(u, (0, n_p - N)).reshape(1, n_p)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_p // block_n,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda n, idx_ref: (idx_ref[0], n)),
            pl.BlockSpec((1, block_n), lambda n, idx_ref: (0, n)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda n, idx_ref: (0, n)),
    )
    out = pl.pallas_call(
        functools.partial(_select_lerp_kernel, beta=beta),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, n_p), jnp.float32),
        interpret=interpret,
    )(jnp.reshape(idx, (1,)).astype(jnp.int32), cp, up)
    return out[0, :N]


def assign_and_lerp(
    u: jax.Array,  # (N,) arriving flattened upload
    centers: jax.Array,  # (C, N) stacked cluster centers (plane rows)
    beta: float,  # mix rate b
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (dists (C,) fp32, idx () int32 argmin, blended (N,) fp32)
    where ``blended = (1 - beta) * centers[idx] + beta * u``."""
    (N,) = u.shape
    dists = l1_distance(u, centers, block_n=block_n, interpret=interpret)
    idx = jnp.argmin(dists).astype(jnp.int32)
    lerp_block = min(block_n, max(128, 1 << (N - 1).bit_length()))
    blended = _select_lerp(
        centers, u, idx, beta, block_n=lerp_block, interpret=interpret
    )
    return dists, idx, blended
