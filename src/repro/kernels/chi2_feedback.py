"""Client feedback statistic (paper Eq. 2/3) as a Pallas TPU kernel.

g(v_c, Pi_i) = chi2(F_pred, F_true) * Var(S_soft), batched over M clients:
the server evaluates feedback for a whole refinement round at once. One
fused VPU pass over (block_m, J) tiles; J (number of classes) is small, so
the tile is padded to the 128-lane boundary with a validity mask.

Grid: (M / block_m,).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chi2_kernel(fp_ref, ft_ref, ss_ref, o_ref, *, j_valid: int):
    fp = fp_ref[...].astype(jnp.float32)  # (block_m, Jp)
    ft = ft_ref[...].astype(jnp.float32)
    ss = ss_ref[...].astype(jnp.float32)
    jp = fp.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, fp.shape, 1) < j_valid

    chi2 = jnp.sum(jnp.where(valid, jnp.square(fp - ft) / jnp.maximum(ft, 1e-6), 0.0), axis=1)
    mean = jnp.sum(jnp.where(valid, ss, 0.0), axis=1, keepdims=True) / j_valid
    var = jnp.sum(jnp.where(valid, jnp.square(ss - mean), 0.0), axis=1) / j_valid
    o_ref[:, 0] = chi2 * var


def chi2_feedback(
    f_pred: jax.Array,  # (M, J)
    f_true: jax.Array,  # (M, J)
    s_soft: jax.Array,  # (M, J)
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    M, J = f_pred.shape
    j_p = math.ceil(J / 128) * 128
    block_m = min(block_m, max(8, 1 << (M - 1).bit_length()))
    m_p = math.ceil(M / block_m) * block_m
    pad = lambda x: jnp.pad(x, ((0, m_p - M), (0, j_p - J)))
    fp, ft, ss = pad(f_pred), pad(f_true), pad(s_soft)
    grid = (m_p // block_m,)
    spec = pl.BlockSpec((block_m, j_p), lambda i: (i, 0))

    out = pl.pallas_call(
        functools.partial(_chi2_kernel, j_valid=J),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_p, 1), jnp.float32),
        interpret=interpret,
    )(fp, ft, ss)
    return out[:M, 0]
