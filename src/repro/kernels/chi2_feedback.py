"""Client feedback statistic (paper Eq. 2/3) as a Pallas TPU kernel.

g(v_c, Pi_i) = chi2(F_pred, F_true) * Var(S_soft), batched over M clients:
the server evaluates feedback for a whole refinement round at once. One
fused VPU pass over (block_m, J) tiles; J (number of classes) is small, so
the tile is padded to the 128-lane boundary with a validity mask.

Grid: (M / block_m,).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chi2_kernel(fp_ref, ft_ref, ss_ref, o_ref, *, j_valid: int):
    fp = fp_ref[...].astype(jnp.float32)  # (block_m, Jp)
    ft = ft_ref[...].astype(jnp.float32)
    ss = ss_ref[...].astype(jnp.float32)
    jp = fp.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, fp.shape, 1) < j_valid

    chi2 = jnp.sum(jnp.where(valid, jnp.square(fp - ft) / jnp.maximum(ft, 1e-6), 0.0), axis=1)
    mean = jnp.sum(jnp.where(valid, ss, 0.0), axis=1, keepdims=True) / j_valid
    var = jnp.sum(jnp.where(valid, jnp.square(ss - mean), 0.0), axis=1) / j_valid
    o_ref[:, 0] = chi2 * var


def chi2_feedback(
    f_pred: jax.Array,  # (M, J)
    f_true: jax.Array,  # (M, J)
    s_soft: jax.Array,  # (M, J)
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    M, J = f_pred.shape
    j_p = math.ceil(J / 128) * 128
    block_m = min(block_m, max(8, 1 << (M - 1).bit_length()))
    m_p = math.ceil(M / block_m) * block_m
    pad = lambda x: jnp.pad(x, ((0, m_p - M), (0, j_p - J)))
    fp, ft, ss = pad(f_pred), pad(f_true), pad(s_soft)
    grid = (m_p // block_m,)
    spec = pl.BlockSpec((block_m, j_p), lambda i: (i, 0))

    out = pl.pallas_call(
        functools.partial(_chi2_kernel, j_valid=J),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_p, 1), jnp.float32),
        interpret=interpret,
    )(fp, ft, ss)
    return out[:M, 0]


def _chi2_seg_kernel(fp_ref, ft_ref, ss_ref, oh_ref, g_ref, sum_ref, *, j_valid: int):
    mi = pl.program_id(0)

    @pl.when(mi == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)

    fp = fp_ref[...].astype(jnp.float32)  # (block_m, Jp)
    ft = ft_ref[...].astype(jnp.float32)
    ss = ss_ref[...].astype(jnp.float32)
    valid = jax.lax.broadcasted_iota(jnp.int32, fp.shape, 1) < j_valid

    chi2 = jnp.sum(jnp.where(valid, jnp.square(fp - ft) / jnp.maximum(ft, 1e-6), 0.0), axis=1)
    mean = jnp.sum(jnp.where(valid, ss, 0.0), axis=1, keepdims=True) / j_valid
    var = jnp.sum(jnp.where(valid, jnp.square(ss - mean), 0.0), axis=1) / j_valid
    g = chi2 * var
    g_ref[:, 0] = g
    # segment reduction: one-hot membership scatters each member's g into
    # its cluster's accumulator; padded rows carry an all-zero one-hot.
    oh = oh_ref[...].astype(jnp.float32)  # (block_m, Sp)
    sum_ref[...] += jnp.sum(oh * g[:, None], axis=0, keepdims=True)


def chi2_feedback_segmented(
    f_pred: jax.Array,  # (M, J) all members of all clusters, stacked
    f_true: jax.Array,  # (M, J)
    s_soft: jax.Array,  # (M, J)
    seg_onehot: jax.Array,  # (M, S) fp one-hot cluster membership
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One launch over every member of every cluster.

    Returns (g (M,), seg_sum (S,)): the per-member feedback statistic plus
    per-cluster sums of g accumulated inside the same kernel — the server
    turns those into cluster-mean feedback without a second pass.
    """
    M, J = f_pred.shape
    S = seg_onehot.shape[1]
    j_p = math.ceil(J / 128) * 128
    s_p = math.ceil(S / 128) * 128
    block_m = min(block_m, max(8, 1 << (M - 1).bit_length()))
    m_p = math.ceil(M / block_m) * block_m
    pad = lambda x: jnp.pad(x, ((0, m_p - M), (0, j_p - J)))
    fp, ft, ss = pad(f_pred), pad(f_true), pad(s_soft)
    oh = jnp.pad(seg_onehot, ((0, m_p - M), (0, s_p - S)))
    grid = (m_p // block_m,)
    spec = pl.BlockSpec((block_m, j_p), lambda i: (i, 0))

    g, seg = pl.pallas_call(
        functools.partial(_chi2_seg_kernel, j_valid=J),
        grid=grid,
        in_specs=[spec, spec, spec, pl.BlockSpec((block_m, s_p), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, s_p), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_p, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, s_p), jnp.float32),
        ],
        interpret=interpret,
    )(fp, ft, ss, oh)
    return g[:M, 0], seg[0, :S]
