"""Flash attention backward pass as two Pallas TPU kernels.

The forward kernel saves only (o, lse); the backward recomputes each
(block_q x block_k) probability tile in VMEM — the classic recomputation
trade that keeps attention HBM traffic O(S^2 * d / block) in both passes.

  dq kernel : grid (B, H, nq, nk)   — inner loop over k blocks, dq tile
              accumulates in VMEM scratch, written once at the last ki.
  dkv kernel: grid (B, KV, nk, G*nq) — inner loop over (query-group, q
              block) pairs so GQA's dk/dv accumulate over all G query
              heads of the kv head without cross-core reductions.

Math per tile (recomputed exactly as the forward):
  s  = (q k^T) * scale ;  t = tanh(s / cap), s <- cap * t   (if softcap)
  p  = exp(s - lse)          (masked entries 0)
  dv += p^T do
  dp = do v^T
  ds = p * (dp - D),  D = rowsum(do * o)    (precomputed outside)
  ds <- ds * (1 - t^2)                       (softcap chain rule)
  dq += ds k * scale ;  dk += ds^T q * scale
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tile_ds_p(q, k, lse_tile, *, scale, softcap, causal, window,
               q_pos0, q_pos_base, k_pos_base, q_len, kv_len, block_q, block_k):
    """Recompute (p, s->ds chain factor, mask) for one tile. Returns
    (p, chain) where chain is d(softcap)/d(s_raw) (ones if no softcap)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap is not None:
        t = jnp.tanh(s / softcap)
        s = softcap * t
        chain = 1.0 - t * t
    else:
        chain = None
    q_pos = q_pos0 + q_pos_base + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_pos_base + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    q_row = q_pos - q_pos0
    mask = (k_pos < kv_len) & (q_row < q_len)  # padded rows/cols contribute 0
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    p = jnp.where(mask, jnp.exp(s - lse_tile[:, None]), 0.0)
    return p, chain


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref, dq_scr,
    *, scale, causal, window, softcap, block_q, block_k,
    q_pos0, num_k_blocks, q_len, kv_len,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    dsum = dsum_ref[0, 0]

    p, chain = _tile_ds_p(
        q, k, lse, scale=scale, softcap=softcap, causal=causal, window=window,
        q_pos0=q_pos0, q_pos_base=qi * block_q, k_pos_base=ki * block_k,
        q_len=q_len, kv_len=kv_len, block_q=block_q, block_k=block_k,
    )
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))  # (bq, bk)
    ds = p * (dp - dsum[:, None])
    if chain is not None:
        ds = ds * chain
    dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ()))) * scale

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dk_ref, dv_ref, dk_scr, dv_scr,
    *, scale, causal, window, softcap, block_q, block_k,
    q_pos0, num_q_blocks, num_inner, q_len, kv_len,
):
    ki = pl.program_id(2)
    gi = pl.program_id(3)  # linearized (query-group g, q block qi)
    qi = gi % num_q_blocks

    @pl.when(gi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    dsum = dsum_ref[0, 0]

    p, chain = _tile_ds_p(
        q, k, lse, scale=scale, softcap=softcap, causal=causal, window=window,
        q_pos0=q_pos0, q_pos_base=qi * block_q, k_pos_base=ki * block_k,
        q_len=q_len, kv_len=kv_len, block_q=block_q, block_k=block_k,
    )
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))  # (bk, dv)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum[:, None])
    if chain is not None:
        ds = ds * chain
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ()))) * scale

    @pl.when(gi == num_inner - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,   # (B, H, Sq, hd)
    k: jax.Array,   # (B, KV, Sk, hd)
    v: jax.Array,   # (B, KV, Sk, dv)
    o: jax.Array,   # (B, H, Sq, dv)
    lse: jax.Array,  # (B, H, Sq) fp32
    do: jax.Array,  # (B, H, Sq, dv)
    *,
    causal: bool = True,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
    q_pos0: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, H, Sq, hd = q.shape
    KV, Sk, dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // KV
    scale = hd**-0.5 if scale is None else scale

    hd_p = math.ceil(hd / 128) * 128
    dv_p = math.ceil(dv / 128) * 128
    from repro.kernels.flash_attention import _blocks

    block_q, block_k = _blocks(Sq, Sk, block_q, block_k)
    sq_p = math.ceil(Sq / block_q) * block_q
    sk_p = math.ceil(Sk / block_k) * block_k
    nq, nk = sq_p // block_q, sk_p // block_k

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - Sq), (0, hd_p - hd)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - Sk), (0, hd_p - hd)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - Sk), (0, dv_p - dv)))
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, sq_p - Sq), (0, dv_p - dv)))
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, sq_p - Sq)))
    # D = rowsum(do * o): tiny elementwise pre-pass outside the kernels
    dsum = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    dsump = jnp.pad(dsum, ((0, 0), (0, 0), (0, sq_p - Sq)))

    common = dict(scale=scale, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, q_pos0=q_pos0,
                  q_len=Sq, kv_len=Sk)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, num_k_blocks=nk, **common),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd_p), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd_p), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dv_p), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_q, dv_p), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd_p), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_p, hd_p), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd_p), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dsump)

    num_inner = G * nq
    dk, dvv = pl.pallas_call(
        functools.partial(_dkv_kernel, num_q_blocks=nq, num_inner=num_inner, **common),
        grid=(B, KV, nk, num_inner),
        in_specs=[
            # q/do/lse/dsum blocks walk over (g, qi); head = kv*G + g
            pl.BlockSpec((1, 1, block_q, hd_p),
                         lambda b, kv, ki, gi, g=G, n=nq: (b, kv * g + gi // n, gi % n, 0)),
            pl.BlockSpec((1, 1, block_k, hd_p), lambda b, kv, ki, gi: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dv_p), lambda b, kv, ki, gi: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, block_q, dv_p),
                         lambda b, kv, ki, gi, g=G, n=nq: (b, kv * g + gi // n, gi % n, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, kv, ki, gi, g=G, n=nq: (b, kv * g + gi // n, gi % n)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, kv, ki, gi, g=G, n=nq: (b, kv * g + gi // n, gi % n)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_k, hd_p), lambda b, kv, ki, gi: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dv_p), lambda b, kv, ki, gi: (b, kv, ki, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, KV, sk_p, hd_p), k.dtype),
            jax.ShapeDtypeStruct((B, KV, sk_p, dv_p), v.dtype),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_k, hd_p), jnp.float32),
            pltpu.VMEM((block_k, dv_p), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dsump)

    return (
        dq[:, :, :Sq, :hd],
        dk[:, :, :Sk, :hd],
        dvv[:, :, :Sk, :dv],
    )
