from repro.kernels.ops import chi2_feedback, flash_attention, l1_distance, merge_attention

__all__ = ["flash_attention", "l1_distance", "merge_attention", "chi2_feedback"]
