from repro.kernels.ops import (
    assign_and_lerp,
    chi2_feedback,
    chi2_feedback_all,
    flash_attention,
    l1_distance,
    l1_distance_pairwise,
    merge_attention,
)

__all__ = [
    "flash_attention",
    "l1_distance",
    "l1_distance_pairwise",
    "assign_and_lerp",
    "merge_attention",
    "chi2_feedback",
    "chi2_feedback_all",
]
