"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose the
kernels (interpret=True on CPU) against these across shape/dtype sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, H, Sq, hd)
    k: jax.Array,  # (B, KV, Sk, hd)
    v: jax.Array,  # (B, KV, Sk, hd)
    *,
    causal: bool = True,
    scale: float | None = None,
    window: int | None = None,
    softcap: float | None = None,
    q_pos0: int = 0,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    KV, Sk, dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // KV
    scale = hd**-0.5 if scale is None else scale
    qg = q.reshape(B, KV, G, Sq, hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_pos0 + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v)
    return o.reshape(B, H, Sq, dv)


def l1_distance_ref(u: jax.Array, centers: jax.Array) -> jax.Array:
    """u: (N,), centers: (C, N) -> (C,) L1 distances (Eq. 1)."""
    return jnp.sum(jnp.abs(centers.astype(jnp.float32) - u.astype(jnp.float32)[None, :]), axis=1)


def merge_attention_ref(
    v_main: jax.Array, v_aux: jax.Array, v_trained: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Algorithm 1 lines 2-6: returns (merged, alpha).

    dir_assume = v_aux - v_main (assumed optimization direction)
    dir_post   = v_trained - v_main (posterior direction after local training)
    alpha      = relu(dir_assume * dir_post) / max(dir_assume * dir_post)
    merged     = alpha * v_aux + (1 - alpha) * v_main
    """
    da = (v_aux - v_main).astype(jnp.float32)
    dp = (v_trained - v_main).astype(jnp.float32)
    p = da * dp
    denom = jnp.maximum(jnp.max(p), 1e-12)
    alpha = jnp.maximum(p, 0.0) / denom
    merged = alpha * v_aux.astype(jnp.float32) + (1.0 - alpha) * v_main.astype(jnp.float32)
    return merged.astype(v_main.dtype), alpha


def l1_distance_pairwise_ref(xs: jax.Array, centers: jax.Array) -> jax.Array:
    """xs: (M, N), centers: (C, N) -> (M, C) pairwise L1 distances."""
    x = xs.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    return jnp.sum(jnp.abs(x[:, None, :] - c[None, :, :]), axis=-1)


def assign_and_lerp_ref(
    u: jax.Array, centers: jax.Array, beta: float
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """u: (N,), centers: (C, N) -> (dists (C,), argmin idx, blended row).

    The blend's two products are fenced apart so XLA can never contract
    the mul-add into an FMA: the same expression inlines into contexts of
    very different sizes (a standalone per-upload jit, the event-coalesced
    ingest scan), and contraction decisions vary with the surrounding
    fusion — which would make the blended center's last ulp depend on HOW
    the upload was dispatched. Batched and per-event server trajectories
    must be bitwise-identical, so the two-op form is pinned here."""
    dists = l1_distance_ref(u, centers)
    idx = jnp.argmin(dists).astype(jnp.int32)
    best = centers[idx].astype(jnp.float32)
    m1, m2 = jax.lax.optimization_barrier(
        ((1.0 - beta) * best, beta * u.astype(jnp.float32))
    )
    return dists, idx, m1 + m2


def chi2_feedback_segmented_ref(
    f_pred: jax.Array,  # (M, J)
    f_true: jax.Array,  # (M, J)
    s_soft: jax.Array,  # (M, J)
    seg_onehot: jax.Array,  # (M, S)
) -> tuple[jax.Array, jax.Array]:
    """Every member of every cluster in one batch: (g (M,), seg_sum (S,))."""
    g = chi2_feedback_ref(f_pred, f_true, s_soft)
    seg_sum = jnp.sum(seg_onehot.astype(jnp.float32) * g[:, None], axis=0)
    return g, seg_sum


def chi2_feedback_ref(
    f_pred: jax.Array,  # (M, J) predicted label histograms
    f_true: jax.Array,  # (M, J) expected label histograms
    s_soft: jax.Array,  # (M, J) mean predicted soft-label distributions
) -> jax.Array:
    """Eq. 2/3: chi-squared statistic x Var(S_c), batched over M clients."""
    f_pred = f_pred.astype(jnp.float32)
    f_true = f_true.astype(jnp.float32)
    chi2 = jnp.sum(jnp.square(f_pred - f_true) / jnp.maximum(f_true, 1e-6), axis=-1)
    var = jnp.var(s_soft.astype(jnp.float32), axis=-1)
    return chi2 * var
