"""Public jit'd entry points for the Pallas kernels.

On TPU these dispatch to the compiled kernels; elsewhere (this CPU
container, unit tests) they run the same kernel bodies in interpret mode
or fall back to the jnp oracle for speed. The protocol layer calls only
these wrappers, so swapping the backend never touches coordination code.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.assign_lerp import assign_and_lerp as _assign_lerp_kernel
from repro.kernels.chi2_feedback import chi2_feedback as _chi2_kernel
from repro.kernels.chi2_feedback import chi2_feedback_segmented as _chi2_seg_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.l1_distance import l1_distance as _l1_kernel
from repro.kernels.l1_pairwise import l1_distance_pairwise as _l1_pairwise_kernel
from repro.kernels.merge_attention import merge_attention as _merge_kernel


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


_FORCE = os.environ.get("REPRO_KERNELS", "auto")  # auto | pallas | ref


def _use_pallas() -> bool:
    if _FORCE == "pallas":
        return True
    if _FORCE == "ref":
        return False
    return _on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "scale", "window", "softcap", "q_pos0"))
def flash_attention(q, k, v, *, causal=True, scale=None, window=None, softcap=None, q_pos0=0):
    if _use_pallas():
        return _flash_kernel(
            q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
            q_pos0=q_pos0, interpret=not _on_tpu(),
        )
    return ref.flash_attention_ref(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap, q_pos0=q_pos0
    )


# ---------------------------------------------------------------------------
# Trainable attention: flash forward + flash backward kernels via custom_vjp.
# This is what the model's train/prefill path calls — it is the difference
# between O(S^2) attention HBM traffic (materialized score matrices, the
# paper-naive baseline measured with REPRO_KERNELS=ref) and the
# O(S^2 * d / block) streaming traffic of the fused kernels (see
# EXPERIMENTS.md §Perf iteration 1).
# ---------------------------------------------------------------------------
from repro.kernels.flash_attention import flash_attention_with_lse as _flash_fwd_lse
from repro.kernels.flash_attention_bwd import flash_attention_bwd as _flash_bwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _attention_trainable(q, k, v, causal, scale, window, softcap, q_pos0, interpret):
    o, _ = _flash_fwd_lse(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_pos0=q_pos0, interpret=interpret,
    )
    return o


def _attention_fwd(q, k, v, causal, scale, window, softcap, q_pos0, interpret):
    o, lse = _flash_fwd_lse(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_pos0=q_pos0, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _attention_bwd(causal, scale, window, softcap, q_pos0, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(
        q, k, v, o, lse, do, causal=causal, scale=scale, window=window,
        softcap=softcap, q_pos0=q_pos0, interpret=interpret,
    )
    return dq, dk, dv


_attention_trainable.defvjp(_attention_fwd, _attention_bwd)


def _local_attention(q, k, v, *, causal, scale, window, softcap, q_pos0):
    """Per-device attention on local (B, H, S, hd) shards.

    REPRO_ATTN_COST_PROXY=1 (set by the dry-run) lowers the AD-able jnp
    reference instead of the interpret-mode kernels: interpret lowering
    copies full loop-carried arrays per grid step (a CPU emulation artifact
    a Mosaic kernel does not have), which poisons byte accounting. The cost
    model then filters the reference's S^2 tensors and substitutes the
    kernels' analytic streaming traffic (hlo_cost.skip_trailing +
    dryrun.flash_attention_analytic_bytes)."""
    if _FORCE == "ref" or os.environ.get("REPRO_ATTN_COST_PROXY") == "1":
        return ref.flash_attention_ref(
            q, k, v, causal=causal, scale=scale, window=window,
            softcap=softcap, q_pos0=q_pos0,
        )
    return _attention_trainable(
        q, k, v, causal, scale, window, softcap, q_pos0, not _on_tpu()
    )


def attention(q, k, v, *, causal=True, scale=None, window=None, softcap=None, q_pos0=0):
    """Training/prefill attention entry point (B, H, Sq, hd) x (B, KV, Sk, *).

    Under a registered mesh (repro.models.dist) the computation runs inside
    shard_map on per-device local shapes: batch over ("pod","data"), heads
    over "model" when divisible. GQA with fewer KV heads than the TP width
    keeps K/V replicated and slices the per-rank KV group inside the shard —
    the standard TP layout for GQA.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import dist

    mesh = dist.current_mesh()
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    kw = dict(causal=causal, scale=scale, window=window, softcap=softcap, q_pos0=q_pos0)
    if mesh is None or mesh.devices.size == 1:
        return _local_attention(q, k, v, **kw)

    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    b_ax = baxes if B % dp == 0 else None
    h_sharded = H % tp == 0 and tp > 1
    kv_sharded = h_sharded and KV % tp == 0
    h_ax = "model" if h_sharded else None
    kv_ax = "model" if kv_sharded else None

    G = H // KV
    h_local = H // tp if h_sharded else H

    def body(ql, kl, vl):
        if h_sharded and not kv_sharded:
            # slice this rank's KV group out of the replicated K/V
            rank = jax.lax.axis_index("model")
            kv_need = max(1, h_local // G)
            kv0 = rank * h_local // G
            kl_ = jax.lax.dynamic_slice_in_dim(kl, kv0, kv_need, axis=1)
            vl_ = jax.lax.dynamic_slice_in_dim(vl, kv0, kv_need, axis=1)
        else:
            kl_, vl_ = kl, vl
        return _local_attention(ql, kl_, vl_, **kw)

    from jax import shard_map

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(b_ax, h_ax, None, None), P(b_ax, kv_ax, None, None),
                  P(b_ax, kv_ax, None, None)),
        out_specs=P(b_ax, h_ax, None, None),
        check_vma=False,
    )(q, k, v)


@jax.jit
def l1_distance(u, centers):
    if _use_pallas():
        return _l1_kernel(u, centers, interpret=not _on_tpu())
    return ref.l1_distance_ref(u, centers)


@jax.jit
def merge_attention(v_main, v_aux, v_trained):
    if _use_pallas():
        return _merge_kernel(v_main, v_aux, v_trained, interpret=not _on_tpu())
    return ref.merge_attention_ref(v_main, v_aux, v_trained)[0]


@jax.jit
def chi2_feedback(f_pred, f_true, s_soft):
    if _use_pallas():
        return _chi2_kernel(f_pred, f_true, s_soft, interpret=not _on_tpu())
    return ref.chi2_feedback_ref(f_pred, f_true, s_soft)


@jax.jit
def l1_distance_pairwise(xs, centers):
    """(M, N) x (C, N) -> (M, C) L1 matrix in one launch (plane hot path)."""
    if _use_pallas():
        return _l1_pairwise_kernel(xs, centers, interpret=not _on_tpu())
    return ref.l1_distance_pairwise_ref(xs, centers)


@functools.partial(jax.jit, static_argnames=("beta",))
def assign_and_lerp(u, centers, beta):
    """Fused Eq. 1 argmin + mixed-rate center blend: (dists, idx, blended)."""
    if _use_pallas():
        return _assign_lerp_kernel(u, centers, beta, interpret=not _on_tpu())
    return ref.assign_and_lerp_ref(u, centers, beta)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments):
    """Cluster-segmented feedback: every member of every cluster in one
    launch. ``seg_ids`` maps each row to its cluster slot in [0,
    num_segments); returns (g (M,), seg_sum (num_segments,))."""
    onehot = (seg_ids[:, None] == jnp.arange(num_segments)[None, :]).astype(jnp.float32)
    if _use_pallas():
        return _chi2_seg_kernel(f_pred, f_true, s_soft, onehot, interpret=not _on_tpu())
    return ref.chi2_feedback_segmented_ref(f_pred, f_true, s_soft, onehot)
