"""Public jit'd entry points for the Pallas kernels.

On TPU these dispatch to the compiled kernels; elsewhere (this CPU
container, unit tests) they run the same kernel bodies in interpret mode
or fall back to the jnp oracle for speed. The protocol layer calls only
these wrappers, so swapping the backend never touches coordination code.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import plane_sharded, ref
from repro.kernels.assign_lerp import assign_and_lerp as _assign_lerp_kernel
from repro.kernels.chi2_feedback import chi2_feedback as _chi2_kernel
from repro.kernels.chi2_feedback import chi2_feedback_segmented as _chi2_seg_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.l1_distance import l1_distance as _l1_kernel
from repro.kernels.l1_pairwise import l1_distance_pairwise as _l1_pairwise_kernel
from repro.kernels.merge_attention import merge_attention as _merge_kernel


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


_FORCE = os.environ.get("REPRO_KERNELS", "auto")  # auto | pallas | ref


def _use_pallas() -> bool:
    if _FORCE == "pallas":
        return True
    if _FORCE == "ref":
        return False
    return _on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "scale", "window", "softcap", "q_pos0"))
def flash_attention(q, k, v, *, causal=True, scale=None, window=None, softcap=None, q_pos0=0):
    if _use_pallas():
        return _flash_kernel(
            q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
            q_pos0=q_pos0, interpret=not _on_tpu(),
        )
    return ref.flash_attention_ref(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap, q_pos0=q_pos0
    )


# ---------------------------------------------------------------------------
# Trainable attention: flash forward + flash backward kernels via custom_vjp.
# This is what the model's train/prefill path calls — it is the difference
# between O(S^2) attention HBM traffic (materialized score matrices, the
# paper-naive baseline measured with REPRO_KERNELS=ref) and the
# O(S^2 * d / block) streaming traffic of the fused kernels (see
# EXPERIMENTS.md §Perf iteration 1).
# ---------------------------------------------------------------------------
from repro.kernels.flash_attention import flash_attention_with_lse as _flash_fwd_lse
from repro.kernels.flash_attention_bwd import flash_attention_bwd as _flash_bwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _attention_trainable(q, k, v, causal, scale, window, softcap, q_pos0, interpret):
    o, _ = _flash_fwd_lse(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_pos0=q_pos0, interpret=interpret,
    )
    return o


def _attention_fwd(q, k, v, causal, scale, window, softcap, q_pos0, interpret):
    o, lse = _flash_fwd_lse(
        q, k, v, causal=causal, scale=scale, window=window, softcap=softcap,
        q_pos0=q_pos0, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _attention_bwd(causal, scale, window, softcap, q_pos0, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd(
        q, k, v, o, lse, do, causal=causal, scale=scale, window=window,
        softcap=softcap, q_pos0=q_pos0, interpret=interpret,
    )
    return dq, dk, dv


_attention_trainable.defvjp(_attention_fwd, _attention_bwd)


def _local_attention(q, k, v, *, causal, scale, window, softcap, q_pos0):
    """Per-device attention on local (B, H, S, hd) shards.

    REPRO_ATTN_COST_PROXY=1 (set by the dry-run) lowers the AD-able jnp
    reference instead of the interpret-mode kernels: interpret lowering
    copies full loop-carried arrays per grid step (a CPU emulation artifact
    a Mosaic kernel does not have), which poisons byte accounting. The cost
    model then filters the reference's S^2 tensors and substitutes the
    kernels' analytic streaming traffic (hlo_cost.skip_trailing +
    dryrun.flash_attention_analytic_bytes).

    Backend dispatch follows the documented ``REPRO_KERNELS`` contract
    (same rule as ``flash_attention`` above): ``auto`` lowers the kernels
    only on TPU and the AD-able jnp oracle elsewhere — interpret-mode
    execution is a per-grid-step interpreter loop, ~100x slower than the
    oracle under a wide vmap (the LM fleet's cohort launches), and is
    reserved for the explicit ``pallas`` CI parity sweeps."""
    if os.environ.get("REPRO_ATTN_COST_PROXY") == "1" or not _use_pallas():
        return ref.flash_attention_ref(
            q, k, v, causal=causal, scale=scale, window=window,
            softcap=softcap, q_pos0=q_pos0,
        )
    return _attention_trainable(
        q, k, v, causal, scale, window, softcap, q_pos0, not _on_tpu()
    )


def attention(q, k, v, *, causal=True, scale=None, window=None, softcap=None, q_pos0=0):
    """Training/prefill attention entry point (B, H, Sq, hd) x (B, KV, Sk, *).

    Under a registered mesh (repro.models.dist) the computation runs inside
    shard_map on per-device local shapes: batch over ("pod","data"), heads
    over "model" when divisible. GQA with fewer KV heads than the TP width
    keeps K/V replicated and slices the per-rank KV group inside the shard —
    the standard TP layout for GQA.
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import dist

    mesh = dist.current_mesh()
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    kw = dict(causal=causal, scale=scale, window=window, softcap=softcap, q_pos0=q_pos0)
    if mesh is None or mesh.devices.size == 1:
        return _local_attention(q, k, v, **kw)

    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    b_ax = baxes if B % dp == 0 else None
    h_sharded = H % tp == 0 and tp > 1
    kv_sharded = h_sharded and KV % tp == 0
    h_ax = "model" if h_sharded else None
    kv_ax = "model" if kv_sharded else None

    G = H // KV
    h_local = H // tp if h_sharded else H

    def body(ql, kl, vl):
        if h_sharded and not kv_sharded:
            # slice this rank's KV group out of the replicated K/V
            rank = jax.lax.axis_index("model")
            kv_need = max(1, h_local // G)
            kv0 = rank * h_local // G
            kl_ = jax.lax.dynamic_slice_in_dim(kl, kv0, kv_need, axis=1)
            vl_ = jax.lax.dynamic_slice_in_dim(vl, kv0, kv_need, axis=1)
        else:
            kl_, vl_ = kl, vl
        return _local_attention(ql, kl_, vl_, **kw)

    from jax import shard_map

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(b_ax, h_ax, None, None), P(b_ax, kv_ax, None, None),
                  P(b_ax, kv_ax, None, None)),
        out_specs=P(b_ax, h_ax, None, None),
        check_vma=False,
    )(q, k, v)


@jax.jit
def l1_distance(u, centers):
    if _use_pallas():
        return _l1_kernel(u, centers, interpret=not _on_tpu())
    return ref.l1_distance_ref(u, centers)


@jax.jit
def merge_attention(v_main, v_aux, v_trained):
    if _use_pallas():
        return _merge_kernel(v_main, v_aux, v_trained, interpret=not _on_tpu())
    return ref.merge_attention_ref(v_main, v_aux, v_trained)[0]


def _chi2_local(f_pred, f_true, s_soft):
    if _use_pallas():
        return _chi2_kernel(f_pred, f_true, s_soft, interpret=not _on_tpu())
    return ref.chi2_feedback_ref(f_pred, f_true, s_soft)


@jax.jit
def _chi2_single(f_pred, f_true, s_soft):
    return _chi2_local(f_pred, f_true, s_soft)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "dim_axis"))
def _chi2_mesh(f_pred, f_true, s_soft, mesh, axis, dim_axis=None):
    return plane_sharded.chi2_rows_sharded(
        f_pred, f_true, s_soft, mesh, axis, _chi2_local, dim_axis=dim_axis
    )


def chi2_feedback(f_pred, f_true, s_soft, *, mesh=None, axis="plane", dim_axis="model"):
    """Per-row Eq. 2/3 feedback statistic, (M, J) -> (M,) in one launch.

    With a plane mesh, the M probe rows shard over ``axis`` — and over the
    model axis too when one is active (the feedback operands have no model
    dim, so it contributes row-parallelism) — and every shard scores only
    its rows (per-row arithmetic is shard-local, so scores are
    bitwise-identical to the single-device launch). This is the
    dissolve/expand probe path: it goes sharded only when the flagged-pair
    count crosses the plane's ``mesh_min_rows`` threshold."""
    ms = _model_axis_size(mesh, dim_axis) if mesh is not None else 1
    if _mesh_active(mesh, axis) or ms > 1:
        M = f_pred.shape[0]
        da = dim_axis if ms > 1 else None
        row_axes = (axis, da) if da is not None else (axis,)
        f_pred = _to_mesh_rows(mesh, axis, jnp.asarray(f_pred), row_axes=row_axes)
        f_true = _to_mesh_rows(mesh, axis, jnp.asarray(f_true), fill=1, row_axes=row_axes)
        s_soft = _to_mesh_rows(mesh, axis, jnp.asarray(s_soft), row_axes=row_axes)
        return _chi2_mesh(f_pred, f_true, s_soft, mesh=mesh, axis=axis, dim_axis=da)[:M]
    return _chi2_single(f_pred, f_true, s_soft)


# ---------------------------------------------------------------------------
# Batched plane kernels. Each public wrapper takes an optional plane mesh:
# with ``mesh=None`` (default) the single-device path runs unchanged; with a
# row-sharded mesh the same kernel bodies run per-shard inside shard_map
# (see kernels/plane_sharded.py for the reduction points). Mesh and axis are
# static jit arguments, so each (mesh, shape) pair compiles once.
# ---------------------------------------------------------------------------


def _mesh_active(mesh, axis: str) -> bool:
    return mesh is not None and axis in mesh.axis_names and mesh.shape[axis] > 1


def _model_compute_on() -> bool:
    """The model-axis compute knob (REPRO_PLANE_MODEL_COMPUTE): ``on`` (the
    default) lets an R×M plane mesh shard kernel *compute* over the flat
    parameter dim; ``off`` reverts to replicating operands over the model
    axis (storage may still shard — the PR-2 behavior). Read per call so
    tests can flip it without reimporting."""
    return os.environ.get("REPRO_PLANE_MODEL_COMPUTE", "on").lower() not in (
        "off", "0", "none", "false"
    )


def _model_axis_size(mesh, dim_axis) -> int:
    """Model-axis extent usable for compute (1 when absent/disabled)."""
    if mesh is None or dim_axis is None or not _model_compute_on():
        return 1
    if dim_axis not in mesh.axis_names:
        return 1
    return int(mesh.shape[dim_axis])


def _dim_shards(mesh, dim_axis, dim: int) -> int:
    """Shard count for splitting a flat parameter dim over the model axis:
    the axis extent when it divides ``dim``, else 1 (fall back to
    replicated operands, mirroring the plane's storage rule)."""
    m = _model_axis_size(mesh, dim_axis)
    return m if m > 1 and dim % m == 0 else 1


def _to_mesh(mesh, *arrays):
    """Replicate *small, genuinely replicated* operands (the arriving upload
    vector, the center matrix every query row scores against) onto the mesh
    before a sharded launch. The plane serves small reads committed to a
    single device (plane._localize), and a jit spanning the whole mesh
    rejects single-device-committed inputs rather than resharding them — so
    the dispatch layer moves them here. Arrays already living on the mesh's
    device set pass through untouched."""
    from jax.sharding import NamedSharding, PartitionSpec

    devices = frozenset(mesh.devices.flat)
    rep = NamedSharding(mesh, PartitionSpec())
    out = []
    for x in arrays:
        sharding = getattr(x, "sharding", None)
        if sharding is not None and sharding.device_set == devices:
            out.append(x)
        else:
            out.append(jax.device_put(x, rep))
    return out


def _to_mesh_rows(mesh, axis, x, fill=0, *, row_axes=None, dim_axis=None):
    """Place a row-batched operand *sharded* over ``axis`` (rows padded up
    to the shard count first). The fleet-scale operand — an (M, dim) upload
    matrix, (M, J) feedback rows — must never be materialized whole on
    every device; replicate-then-reshard would cost shard_count x the
    sharded footprint on exactly the path sharding exists to relieve.

    ``row_axes`` spreads the rows over several mesh axes jointly (the chi2
    kernels recruit the model axis for row-parallelism); ``dim_axis``
    additionally shards the trailing dim (the L1 kernels' partial-sum
    operands — the caller guarantees divisibility)."""
    from jax.sharding import NamedSharding, PartitionSpec

    axes = tuple(row_axes) if row_axes is not None else (axis,)
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    rows = x.shape[0]
    rows_p = -(-rows // shards) * shards
    if rows_p != rows:
        x = jnp.pad(
            jnp.asarray(x),
            ((0, rows_p - rows),) + ((0, 0),) * (x.ndim - 1),
            constant_values=fill,
        )
    trailing = [None] * (x.ndim - 1)
    if dim_axis is not None and trailing:
        trailing[-1] = dim_axis
    rows_spec = axes[0] if len(axes) == 1 else axes
    want = NamedSharding(mesh, PartitionSpec(rows_spec, *trailing))
    sharding = getattr(x, "sharding", None)
    if sharding is not None and sharding.is_equivalent_to(want, x.ndim):
        return x
    return jax.device_put(x, want)


def _to_mesh_dim(mesh, dim_axis, *arrays):
    """Place small operands with only the trailing dim sharded over the
    model axis (replicated over rows): the arriving upload vector and the
    center matrix of a dim-sharded launch. Arrays already laid out that way
    (a plane ``take`` off a dim-sharded row store) pass through."""
    from jax.sharding import NamedSharding, PartitionSpec

    out = []
    for x in arrays:
        want = NamedSharding(
            mesh, PartitionSpec(*(None,) * (x.ndim - 1), dim_axis)
        )
        sharding = getattr(x, "sharding", None)
        if sharding is not None and sharding.is_equivalent_to(want, x.ndim):
            out.append(x)
        else:
            out.append(jax.device_put(x, want))
    return out


def _l1_pairwise_local(xs, centers):
    if _use_pallas():
        return _l1_pairwise_kernel(xs, centers, interpret=not _on_tpu())
    return ref.l1_distance_pairwise_ref(xs, centers)


def _l1_local(u, centers):
    if _use_pallas():
        return _l1_kernel(u, centers, interpret=not _on_tpu())
    return ref.l1_distance_ref(u, centers)


def _chi2_seg_local(f_pred, f_true, s_soft, onehot):
    if _use_pallas():
        return _chi2_seg_kernel(f_pred, f_true, s_soft, onehot, interpret=not _on_tpu())
    return ref.chi2_feedback_segmented_ref(f_pred, f_true, s_soft, onehot)


@jax.jit
def _l1_pairwise_single(xs, centers):
    return _l1_pairwise_local(xs, centers)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "dim_axis"))
def _l1_pairwise_mesh(xs, centers, mesh, axis, dim_axis=None):
    return plane_sharded.l1_pairwise_sharded(
        xs, centers, mesh, axis, _l1_pairwise_local, dim_axis=dim_axis
    )


def l1_distance_pairwise(xs, centers, *, mesh=None, axis="plane", dim_axis="model"):
    """(M, N) x (C, N) -> (M, C) L1 matrix in one launch (plane hot path).

    With a plane mesh, the M query rows shard over ``axis`` and each shard
    scores only its rows (identical per-row arithmetic). With a model axis
    whose extent divides N, the flat dim shards too: each shard scores its
    dim chunk and a psum over ``dim_axis`` completes the matrix."""
    ds = _dim_shards(mesh, dim_axis, xs.shape[-1]) if mesh is not None else 1
    if _mesh_active(mesh, axis) or ds > 1:
        M = xs.shape[0]
        da = dim_axis if ds > 1 else None
        xs = _to_mesh_rows(mesh, axis, xs, dim_axis=da)
        if da is not None:
            (centers,) = _to_mesh_dim(mesh, da, centers)
        else:
            (centers,) = _to_mesh(mesh, centers)
        return _l1_pairwise_mesh(xs, centers, mesh=mesh, axis=axis, dim_axis=da)[:M]
    return _l1_pairwise_single(xs, centers)


@functools.partial(jax.jit, static_argnames=("beta",))
def _assign_lerp_single(u, centers, beta):
    if _use_pallas():
        return _assign_lerp_kernel(u, centers, beta, interpret=not _on_tpu())
    return ref.assign_and_lerp_ref(u, centers, beta)


@functools.partial(jax.jit, static_argnames=("beta", "valid_rows", "mesh", "axis", "dim_axis"))
def _assign_lerp_mesh(u, centers, beta, valid_rows, mesh, axis, dim_axis=None):
    return plane_sharded.assign_lerp_sharded(
        u, centers, beta, mesh, axis, _l1_local, valid_rows=valid_rows,
        dim_axis=dim_axis,
    )


def assign_and_lerp(u, centers, beta, *, mesh=None, axis="plane", dim_axis="model"):
    """Fused Eq. 1 argmin + mixed-rate center blend: (dists, idx, blended).

    With a plane mesh, the C center rows shard over ``axis``; distances
    all_gather, the argmin replicates, and the winning row is fetched with
    a one-hot psum — the full center matrix never moves. With a model axis
    whose extent divides N, the dim shards too: per-shard partial L1 sums
    psum into the distances and each model shard blends only its own chunk
    of the winning row."""
    ds = _dim_shards(mesh, dim_axis, u.shape[-1]) if mesh is not None else 1
    if _mesh_active(mesh, axis) or ds > 1:
        C = centers.shape[0]
        da = dim_axis if ds > 1 else None
        centers = _to_mesh_rows(mesh, axis, centers, dim_axis=da)
        if da is not None:
            (u,) = _to_mesh_dim(mesh, da, u)
        else:
            (u,) = _to_mesh(mesh, u)
        return _assign_lerp_mesh(
            u, centers, beta, valid_rows=C, mesh=mesh, axis=axis, dim_axis=da
        )
    return _assign_lerp_single(u, centers, beta)


@functools.partial(jax.jit, static_argnames=("beta", "switch_margin", "with_stats"))
def _ingest_chain_jit(U, centers, bcast, num_centers, prev_idx, forced_idx, valid, beta, switch_margin,
                      with_stats=False):
    C = centers.shape[0]
    # padded center rows (C is pow2-padded so the jit cache does not grow a
    # new entry every time a cluster expands or merges) can never win: the
    # per-row distances are computed as usual, then masked to +inf. The
    # real rows' distances are untouched, so decisions stay bitwise.
    row_valid = jnp.arange(C) < num_centers

    def step(cmat, inp):
        u, prev, forced, ok = inp
        # Optimization barriers fence each sub-expression into the same
        # isolated form the per-event path lowers as its own jit (the
        # assign kernel, plane.lerp_vec, plane.l1_vec): without them XLA
        # fuses/contracts across the scan body and the blends and L1 stats
        # drift from the sequential trajectory by an ulp — enough to flip a
        # downstream RNN broadcast decision. Bitwise parity is the contract.
        # _l1_local: the exact Eq. 1 arithmetic of the active backend, the
        # same dispatch rule assign_and_lerp feeds its argmin
        u, cmat_in = jax.lax.optimization_barrier((u, cmat))
        dists = jax.lax.optimization_barrier(_l1_local(u, cmat_in))
        dists = jnp.where(row_valid, dists, jnp.float32(jnp.inf))
        amin = jnp.argmin(dists).astype(jnp.int32)  # first-index ties, like np.argmin
        has_prev = prev >= 0
        d_prev = jnp.where(has_prev, dists[jnp.clip(prev, 0, C - 1)], jnp.float32(jnp.inf))
        veto = has_prev & (prev != amin) & (dists[amin] > (1.0 - switch_margin) * d_prev)
        cid = jnp.where(forced >= 0, forced, jnp.where(veto, prev, amin)).astype(jnp.int32)
        c_old = jax.lax.optimization_barrier(cmat_in[cid])
        # the canonical blend: every per-event flavor (the fused assign
        # kernel's winner blend, plane.lerp_row's veto/forced lerp) emits
        # this exact folded-beta, fenced two-op expression, so ONE form here
        # covers them all — no select, whose operands XLA is free to
        # re-derive with contracted arithmetic when it sinks the pick into
        # the surrounding fusion
        m1, m2 = jax.lax.optimization_barrier(
            ((1.0 - beta) * c_old, beta * u.astype(jnp.float32))
        )
        c_new = jax.lax.optimization_barrier(m1 + m2)
        b_row = jax.lax.optimization_barrier(bcast[cid])
        change = jnp.sum(jnp.abs(c_new - c_old))
        gap_before = jnp.sum(jnp.abs(c_old - b_row))
        gap_after = jnp.sum(jnp.abs(c_new - b_row))
        cmat = jnp.where(ok, cmat.at[cid].set(c_new), cmat)
        out = (cid, c_new, change, gap_before, gap_after)
        if with_stats:
            # guard telemetry riding the same launch/sync: the post-blend
            # center L1 norm (NaN/Inf propagate through the sum, so one
            # scalar covers both the finite gate and the blowup bound)
            out = out + (jnp.sum(jnp.abs(c_new)),)
        return cmat, out

    _, outs = jax.lax.scan(step, centers.astype(jnp.float32), (U, prev_idx, forced_idx, valid))
    return outs


def ingest_chain(U, centers, bcast, prev_idx, forced_idx, valid, *, beta,
                 switch_margin=0.1, num_centers=None, with_stats=False):
    """Sequential-equivalent batched server ingest: one launch scanning the
    fused assign+lerp over a window of concurrently-arrived uploads.

    Per step ``j`` (in event order) against the LIVE center matrix — each
    step sees every earlier step's blend, exactly like N sequential
    ``handle_upload`` calls:

      * Eq. 1 distances + argmin via the backend assign kernel,
      * host-identical hysteresis (``switch_margin``) with per-upload
        ``prev_idx`` (-1 = first upload) and ``forced_idx`` (>= 0 pins a
        partial-finetune member to its cluster, skipping the argmin),
      * the mixed-rate blend written into the carried center matrix,
      * the predictor statistics the per-event path reads back per upload:
        L1 change of the blended center and its gap to the broadcast
        anchor before/after (``bcast`` is the window-start anchor matrix;
        the caller recomputes the gaps of uploads that land after an
        intra-window broadcast, which moves the anchor).

    Returns per-step ``(cid (S,), blended (S, dim), change (S,),
    gap_before (S,), gap_after (S,))`` — plus the post-blend center L1
    norm ``cnorm (S,)`` when ``with_stats`` (the ingest guard's late
    NaN/blowup detector, riding the launch and sync the caller already
    pays; ``with_stats=False`` compiles the exact pre-guard program).
    Rows where ``valid`` is False leave
    the carried centers untouched and their outputs are ignored. ``U`` must
    be pre-padded by the caller (pad rows invalid), and ``centers``/
    ``bcast`` may carry zero-padding rows above ``num_centers`` (a traced
    count, masked to +inf distance) — so the jit cache stays O(log window)
    x O(log clusters)."""
    C = centers.shape[0]
    return _ingest_chain_jit(
        jnp.asarray(U), centers, bcast,
        jnp.int32(C if num_centers is None else num_centers),
        jnp.asarray(prev_idx, jnp.int32), jnp.asarray(forced_idx, jnp.int32),
        jnp.asarray(valid, jnp.bool_), beta, switch_margin, with_stats,
    )


@jax.jit
def _predictor_chain_jit(params, pre, post, lab_table, fb_table, learn_gate,
                         decide_gate, fb_gate, start, lr):
    from repro.core.broadcast import rnn_chain_step

    S = lab_table.shape[0]
    pos = jnp.arange(S, dtype=jnp.int32)

    def step(carry, inp):
        p, fire = carry
        pre_j, post_j, lab_row, fb_row, lg_j, dg_j, fg_j, pos_j = inp
        new_p, want_rnn = rnn_chain_step(
            p, pre_j, post_j, lab_row[fire], lg_j, dg_j, lr, start
        )
        want = jnp.where(fg_j, fb_row[fire], want_rnn)
        fire = jnp.where(want, pos_j + 1, fire)
        return (new_p, fire), want

    (final, _), wants = jax.lax.scan(
        step, (params, jnp.int32(0)),
        (pre, post, lab_table, fb_table, learn_gate, decide_gate, fb_gate, pos),
    )
    return final, wants


def predictor_chain(params, pre, post, lab_table, fb_table, learn_gate,
                    decide_gate, fb_gate, start, lr):
    """Fused broadcast-predictor chain: every learn/decide step one cluster
    accumulates over a coalesced window in ONE launch, instead of two
    dispatches plus a blocking sync per upload.

    One ``lax.scan`` walks the cluster's steps in chronological order with
    its RNN tree as carry; each step runs the cond-gated SGD on the
    pre-observe record window then the cond-gated broadcast decision on the
    post-observe window. The carry is the single NATIVE-shape tree — this
    is load-bearing for the bitwise contract with the per-upload
    `_rnn_sgd`/`_rnn_want` path. Cross-cluster batching was tried twice and
    both forms break it or don't pay:

      * one B-stacked tree with a gathered (h, h) slice per step makes XLA
        lower the dots against sliced operands with a different
        accumulation order, an ulp off the serial graph (vmapping clusters
        drifts the same way);
      * a tuple-of-B-trees carry with ``lax.switch`` per step IS bitwise,
        but both its compile time and its per-step runtime grow with the
        branch count — at fleet scale (many clusters per window) it lost
        more than the saved dispatches, and every distinct cluster count
        recompiled.

    Cluster chains are fully independent (each step touches only its own
    cluster's tree), so per-cluster launches lose nothing semantically: the
    caller fires one launch per touched cluster and syncs all their
    decisions with one blocking gather per window.

    The chain resolves the label/decision circularity IN-SCAN rather than
    by host fixpoint iteration: a step's Eq. 4 label and a cold-start
    fallback decision depend on the cluster's broadcast anchor, and
    within one window the anchor can only be the pre-window anchor or the
    blended vector of an earlier fired step of the SAME chain. The caller
    enumerates those candidates and precomputes, per step, a boolean row
    over "last fired chain position" (host float64 arithmetic, identical
    to the serial rules — no float compare happens on device). The scan
    carries the fired-position index alongside the RNN tree: each step
    gathers its label from ``lab_table[fire]``, a fallback step gathers
    its decision from ``fb_table[fire]``, and a fired want advances
    ``fire`` to its own position. Every step therefore executes exactly
    once per window, with no relaunches.

    Ragged Top-K windows (predictor ``k`` varies with cluster size) are
    front-padded to ``K``; ``start`` (scalar: K minus the real window
    length, fixed for the cluster) marks where the real window begins and
    the RNN holds its hidden state at zero before it, so valid steps see
    exactly the serial operands.

    Shapes: params is one RNN pytree; pre/post (S, K, 1); lab_table (S,
    S+1) int32 and fb_table (S, S+1) bool, column 0 meaning "pre-window
    anchor" and column q+1 meaning "step q fired last"; the three gates
    are (S,) bool (fb_gate marks cold-start fallback steps, which skip
    both RNN bodies); start/lr scalars. Callers pow2-pad S and K (pad
    steps have all gates False — an identity rewrite that skips both RNN
    bodies via the step's conds). Returns (final params tree, wants (S,)
    bool) covering RNN and fallback decisions alike.

    Operands stay host-side numpy right up to the jit boundary: the
    launch is fired once per cluster per window, and eager
    ``jnp.asarray`` staging cost more dispatch time than the chain saved.
    The numpy scalars keep strong dtypes (a weak python float for ``lr``
    would change promotion inside the SGD and break the bitwise match)."""
    return _predictor_chain_jit(
        params, np.asarray(pre, np.float32), np.asarray(post, np.float32),
        np.asarray(lab_table, np.int32), np.asarray(fb_table, np.bool_),
        np.asarray(learn_gate, np.bool_), np.asarray(decide_gate, np.bool_),
        np.asarray(fb_gate, np.bool_), np.int32(start), np.float32(lr),
    )


@functools.partial(jax.jit, static_argnames=("num_segments",))
def _chi2_all_single(f_pred, f_true, s_soft, seg_ids, num_segments):
    onehot = (seg_ids[:, None] == jnp.arange(num_segments)[None, :]).astype(jnp.float32)
    return _chi2_seg_local(f_pred, f_true, s_soft, onehot)


@functools.partial(jax.jit, static_argnames=("num_segments", "mesh", "axis", "dim_axis"))
def _chi2_all_mesh(f_pred, f_true, s_soft, seg_ids, num_segments, mesh, axis, dim_axis=None):
    onehot = (seg_ids[:, None] == jnp.arange(num_segments)[None, :]).astype(jnp.float32)
    return plane_sharded.chi2_all_sharded(
        f_pred, f_true, s_soft, onehot, mesh, axis, _chi2_seg_local, dim_axis=dim_axis
    )


def chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments, *, mesh=None,
                      axis="plane", dim_axis="model"):
    """Cluster-segmented feedback: every member of every cluster in one
    launch. ``seg_ids`` maps each row to its cluster slot in [0,
    num_segments); returns (g (M,), seg_sum (num_segments,)). With a plane
    mesh, member rows shard over ``axis`` — plus the model axis when one is
    active (row-parallelism; per-member g stays bitwise) — and segment sums
    psum over every sharded axis."""
    ms = _model_axis_size(mesh, dim_axis) if mesh is not None else 1
    if _mesh_active(mesh, axis) or ms > 1:
        M = f_pred.shape[0]
        da = dim_axis if ms > 1 else None
        row_axes = (axis, da) if da is not None else (axis,)
        f_pred = _to_mesh_rows(mesh, axis, f_pred, row_axes=row_axes)
        f_true = _to_mesh_rows(mesh, axis, f_true, row_axes=row_axes)
        s_soft = _to_mesh_rows(mesh, axis, s_soft, row_axes=row_axes)
        # padded members get segment -1: a one-hot row of zeros, so they
        # never contribute to any cluster's sum
        seg_ids = _to_mesh_rows(
            mesh, axis, jnp.asarray(seg_ids, jnp.int32), fill=-1, row_axes=row_axes
        )
        g, seg = _chi2_all_mesh(
            f_pred, f_true, s_soft, seg_ids, num_segments, mesh=mesh, axis=axis,
            dim_axis=da,
        )
        return g[:M], seg
    return _chi2_all_single(f_pred, f_true, s_soft, seg_ids, num_segments)
