"""On-arrival clustering distance (paper Eq. 1) as a Pallas TPU kernel.

Computes L1(u, v_c) for one arriving flattened parameter vector ``u``
against all ``C`` cluster centers. At assigned-architecture scale
(N = 1e9..4e11 after sharding) this is a pure HBM-bandwidth-bound streaming
reduction: each (1, block_n) tile of ``u`` and (1, block_n) tile of each
center is pulled into VMEM once, |u - v| is reduced on the VPU, and a
(1, 1) fp32 accumulator in the output ref carries the partial sum across
the sequential inner grid dimension.

Grid: (C, N / block_n), block_n = 64k lanes (512 sublanes x 128 lanes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _l1_kernel(u_ref, c_ref, o_ref):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    u = u_ref[...].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(jnp.abs(u - c))


def l1_distance(
    u: jax.Array,  # (N,)
    centers: jax.Array,  # (C, N)
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    (N,) = u.shape
    C = centers.shape[0]
    block_n = min(block_n, max(128, 1 << (N - 1).bit_length()))
    n_p = math.ceil(N / block_n) * block_n
    # Zero padding is exact for L1: |0 - 0| contributes nothing.
    up = jnp.pad(u, (0, n_p - N)).reshape(1, n_p)
    cp = jnp.pad(centers, ((0, 0), (0, n_p - N)))
    nk = n_p // block_n

    out = pl.pallas_call(
        _l1_kernel,
        grid=(C, nk),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda c, k: (0, k)),
            pl.BlockSpec((1, block_n), lambda c, k: (c, k)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda c, k: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 1), jnp.float32),
        interpret=interpret,
    )(up, cp)
    return out[:, 0]


def pairwise_l1(
    vectors: jax.Array,  # (M, N)
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    """(M, M) pairwise L1 matrix — used by the ClusterFL baseline and the
    clustering-quality benchmark. Reuses the streaming kernel row by row."""
    fn = functools.partial(l1_distance, centers=vectors, block_n=block_n, interpret=interpret)
    return jax.vmap(lambda row: fn(row))(vectors)
