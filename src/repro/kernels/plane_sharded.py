"""Row-sharded execution of the batched plane kernels (fleet-scale path).

When the parameter plane shards its ``(capacity, dim)`` row store over a
``plane`` mesh axis, the batched coordination kernels must consume sharded
operands without gathering fleet state onto one device. Each wrapper here
runs the single-device kernel body (Pallas on TPU, jnp oracle elsewhere)
inside ``shard_map`` on the *local* row shard and stitches the global
answer with one collective:

  * ``l1_pairwise_sharded`` — query rows shard over ``plane``; every shard
    scores its rows against the (replicated) centers. No reduction: the
    (M, C) output is row-sharded and reassembles on exit.
  * ``assign_lerp_sharded`` — center rows shard over ``plane``; local
    distance vectors ``all_gather`` into the full (C,) vector, the argmin
    is computed redundantly on every shard, and the winning center row is
    recovered with a one-hot ``psum`` (only its owner contributes), so the
    blend never moves the whole center matrix.
  * ``chi2_all_sharded`` — member rows shard over ``plane``; per-cluster
    segment sums are partial per shard and ``psum`` into the global sums.
  * ``chi2_rows_sharded`` — the dissolve/expand probe matrix: rows shard
    over ``plane`` with no reduction (per-row scores reassemble on exit).

Per-row arithmetic (distances, feedback statistics, the blended row) is
bitwise-identical to the single-device kernels — each row's reduction runs
unchanged on whichever shard owns it — so server *decisions* (assignments,
merges, broadcasts) are trajectory-identical under sharding. Only the
cross-shard ``psum`` of segment sums may differ from sequential
accumulation in the last ulp, and that value feeds reporting, not control
flow.

Padding and placement are owned by the dispatch layer (``ops._to_mesh_rows``
pads row counts up to a shard multiple and device_puts the operand with the
row sharding; ``ops._to_mesh`` replicates the small operands): the wrappers
here assume shard-divisible inputs and handle only the *masking* —
padded center rows go to ``+inf`` distance before any argmin
(``valid_rows``), padded member rows carry an all-zero segment one-hot
(segment id -1) — while the dispatch slices padded query rows off the
output.

Meshes with an extra ``model`` axis additionally shard the *compute* over
the flat parameter dim (``dim_axis``), so a row wider than one device
never materializes whole anywhere:

  * the L1 kernels run the single-device kernel body on each shard's dim
    chunk — a chunk's L1 IS the partial sum over those coordinates — and
    one ``psum`` over ``dim_axis`` stitches the full per-row distances
    (last-ulp vs the single-device flat reduction; the R×M subprocess
    trajectory harness in tests/test_model_axis_plane.py pins that the
    server's *decisions* and blended centers stay identical);
  * the assign blend is elementwise, so each model shard blends only its
    own dim chunk of the winning row — per-element arithmetic unchanged,
    bitwise-identical to the single-device blend;
  * the chi2 kernels spread their member/probe *rows* over both axes
    (the feedback operands have no model dim — per-row arithmetic stays
    shard-local and bitwise; segment sums psum over both axes).

The dispatch layer only passes ``dim_axis`` when the model axis is real
(present, >1 shards, knob on) and — for the L1 kernels — the flat dim is
shard-divisible; otherwise these wrappers replicate over it exactly as
before (the plane may still *store* ``dim`` sharded; shard_map reshards
on entry).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

try:  # stable path in newer jax; experimental in the pinned 0.4.x
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def l1_pairwise_sharded(
    xs: jax.Array,  # (M_padded, N) query rows, shard-divisible
    centers: jax.Array,  # (C, N) replicated over rows (dim-sharded w/ dim_axis)
    mesh: jax.sharding.Mesh,
    axis: str,
    local_fn: Callable[[jax.Array, jax.Array], jax.Array],
    dim_axis: str | None = None,
) -> jax.Array:
    """(M_padded, C) pairwise L1 with M sharded over ``axis``; the caller
    slices the padded query rows off. With ``dim_axis`` the flat dim also
    shards: each shard's kernel body scores only its dim chunk (a partial
    L1 sum) and one ``psum`` over ``dim_axis`` yields the full matrix."""
    if dim_axis is None:
        return shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(axis, None), P(None, None)),
            out_specs=P(axis, None),
            check_rep=False,
        )(xs, centers)

    def body(x_local, c_local):
        return jax.lax.psum(local_fn(x_local, c_local), dim_axis)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, dim_axis), P(None, dim_axis)),
        out_specs=P(axis, None),
        check_rep=False,
    )(xs, centers)


def assign_lerp_sharded(
    u: jax.Array,  # (N,) arriving upload, replicated
    centers: jax.Array,  # (C_padded, N) center rows, sharded over ``axis``
    beta: float,
    mesh: jax.sharding.Mesh,
    axis: str,
    local_dist_fn: Callable[[jax.Array, jax.Array], jax.Array],
    valid_rows: int | None = None,
    dim_axis: str | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded fused Eq. 1 argmin + blend: (dists (C,), idx (), blended (N,)).

    ``valid_rows`` is the true center count; the shard-padding rows above
    it are masked to ``+inf`` so they can never win the argmin. With
    ``dim_axis`` the upload and the center rows arrive dim-chunked: the
    kernel body scores each shard's chunk (a partial L1 sum), a ``psum``
    over ``dim_axis`` completes the distances, and after the replicated
    argmin each model shard blends only its own chunk of the winning row
    (elementwise — bitwise-identical per element to the full-row blend)."""
    C = valid_rows if valid_rows is not None else centers.shape[0]
    cp = centers

    def body(u_full, c_local):
        rows_local = c_local.shape[0]
        row0 = jax.lax.axis_index(axis) * rows_local
        d_local = local_dist_fn(u_full, c_local)
        if dim_axis is not None:
            d_local = jax.lax.psum(d_local, dim_axis)  # partial chunk sums
        gids = row0 + jnp.arange(rows_local)
        d_local = jnp.where(gids < C, d_local, jnp.inf)  # mask padded rows
        d_full = jax.lax.all_gather(d_local, axis).reshape(-1)
        idx = jnp.argmin(d_full).astype(jnp.int32)
        # one-hot cross-shard row fetch: only the owner contributes nonzero
        li = jnp.clip(idx - row0, 0, rows_local - 1)
        row = jax.lax.dynamic_index_in_dim(c_local, li, 0, keepdims=False)
        owned = (idx >= row0) & (idx < row0 + rows_local)
        row = jax.lax.psum(jnp.where(owned, row, 0.0), axis)
        blended = (1.0 - beta) * row.astype(jnp.float32) + beta * u_full.astype(jnp.float32)
        return d_full, idx, blended

    d_full, idx, blended = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dim_axis), P(axis, dim_axis)),
        out_specs=(P(None), P(), P(dim_axis)),
        check_rep=False,
    )(u, cp)
    return d_full[:C], idx, blended


def chi2_rows_sharded(
    f_pred: jax.Array,  # (M_padded, J) probe rows, sharded over ``axis``
    f_true: jax.Array,  # (M_padded, J)
    s_soft: jax.Array,  # (M_padded, J)
    mesh: jax.sharding.Mesh,
    axis: str,
    local_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    dim_axis: str | None = None,
) -> jax.Array:
    """Sharded per-row feedback scores (the dissolve/expand probe matrix):
    every shard scores only its own probe rows — no reduction at all, the
    (M_padded,) output is row-sharded and reassembles on exit; the caller
    slices the padded rows off. With ``dim_axis`` the probe rows spread
    over BOTH mesh axes (the feedback operands have no model dim, so the
    model shards contribute row-parallelism; per-row arithmetic stays
    shard-local and bitwise)."""
    rows = (axis, dim_axis) if dim_axis is not None else axis
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(rows, None),) * 3,
        out_specs=P(rows),
        check_rep=False,
    )(f_pred, f_true, s_soft)


def chi2_all_sharded(
    f_pred: jax.Array,  # (M_padded, J) member rows, sharded over ``axis``
    f_true: jax.Array,  # (M_padded, J)
    s_soft: jax.Array,  # (M_padded, J)
    seg_onehot: jax.Array,  # (M_padded, S) membership one-hot; zero rows for padding
    mesh: jax.sharding.Mesh,
    axis: str,
    local_fn: Callable[..., tuple[jax.Array, jax.Array]],
    dim_axis: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sharded segmented feedback: (g (M_padded,), seg_sum (S,) psum'd
    globally); the caller slices the padded member rows off ``g``. With
    ``dim_axis`` the member rows spread over BOTH mesh axes and the
    segment-sum psum runs over both (the partial chi2 contributions;
    per-member g stays shard-local and bitwise)."""
    rows = (axis, dim_axis) if dim_axis is not None else axis
    psum_axes = (axis, dim_axis) if dim_axis is not None else axis

    def body(fp_l, ft_l, ss_l, oh_l):
        g_local, seg_local = local_fn(fp_l, ft_l, ss_l, oh_l)
        return g_local, jax.lax.psum(seg_local, psum_axes)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(rows, None),) * 4,
        out_specs=(P(rows), P(None)),
        check_rep=False,
    )(f_pred, f_true, s_soft, seg_onehot)
