"""Row-sharded execution of the batched plane kernels (fleet-scale path).

When the parameter plane shards its ``(capacity, dim)`` row store over a
``plane`` mesh axis, the batched coordination kernels must consume sharded
operands without gathering fleet state onto one device. Each wrapper here
runs the single-device kernel body (Pallas on TPU, jnp oracle elsewhere)
inside ``shard_map`` on the *local* row shard and stitches the global
answer with one collective:

  * ``l1_pairwise_sharded`` — query rows shard over ``plane``; every shard
    scores its rows against the (replicated) centers. No reduction: the
    (M, C) output is row-sharded and reassembles on exit.
  * ``assign_lerp_sharded`` — center rows shard over ``plane``; local
    distance vectors ``all_gather`` into the full (C,) vector, the argmin
    is computed redundantly on every shard, and the winning center row is
    recovered with a one-hot ``psum`` (only its owner contributes), so the
    blend never moves the whole center matrix.
  * ``chi2_all_sharded`` — member rows shard over ``plane``; per-cluster
    segment sums are partial per shard and ``psum`` into the global sums.
  * ``chi2_rows_sharded`` — the dissolve/expand probe matrix: rows shard
    over ``plane`` with no reduction (per-row scores reassemble on exit).

Per-row arithmetic (distances, feedback statistics, the blended row) is
bitwise-identical to the single-device kernels — each row's reduction runs
unchanged on whichever shard owns it — so server *decisions* (assignments,
merges, broadcasts) are trajectory-identical under sharding. Only the
cross-shard ``psum`` of segment sums may differ from sequential
accumulation in the last ulp, and that value feeds reporting, not control
flow.

Padding and placement are owned by the dispatch layer (``ops._to_mesh_rows``
pads row counts up to a shard multiple and device_puts the operand with the
row sharding; ``ops._to_mesh`` replicates the small operands): the wrappers
here assume shard-divisible inputs and handle only the *masking* —
padded center rows go to ``+inf`` distance before any argmin
(``valid_rows``), padded member rows carry an all-zero segment one-hot
(segment id -1) — while the dispatch slices padded query rows off the
output. Meshes with an extra ``model`` axis replicate these kernels'
operands over it (the plane may still *store* ``dim`` sharded; shard_map
reshards on entry).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

try:  # stable path in newer jax; experimental in the pinned 0.4.x
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def l1_pairwise_sharded(
    xs: jax.Array,  # (M_padded, N) query rows, shard-divisible
    centers: jax.Array,  # (C, N) replicated
    mesh: jax.sharding.Mesh,
    axis: str,
    local_fn: Callable[[jax.Array, jax.Array], jax.Array],
) -> jax.Array:
    """(M_padded, C) pairwise L1 with M sharded over ``axis``; the caller
    slices the padded query rows off."""
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(axis, None),
        check_rep=False,
    )(xs, centers)


def assign_lerp_sharded(
    u: jax.Array,  # (N,) arriving upload, replicated
    centers: jax.Array,  # (C_padded, N) center rows, sharded over ``axis``
    beta: float,
    mesh: jax.sharding.Mesh,
    axis: str,
    local_dist_fn: Callable[[jax.Array, jax.Array], jax.Array],
    valid_rows: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded fused Eq. 1 argmin + blend: (dists (C,), idx (), blended (N,)).

    ``valid_rows`` is the true center count; the shard-padding rows above
    it are masked to ``+inf`` so they can never win the argmin."""
    C = valid_rows if valid_rows is not None else centers.shape[0]
    cp = centers

    def body(u_full, c_local):
        rows_local = c_local.shape[0]
        row0 = jax.lax.axis_index(axis) * rows_local
        d_local = local_dist_fn(u_full, c_local)
        gids = row0 + jnp.arange(rows_local)
        d_local = jnp.where(gids < C, d_local, jnp.inf)  # mask padded rows
        d_full = jax.lax.all_gather(d_local, axis).reshape(-1)
        idx = jnp.argmin(d_full).astype(jnp.int32)
        # one-hot cross-shard row fetch: only the owner contributes nonzero
        li = jnp.clip(idx - row0, 0, rows_local - 1)
        row = jax.lax.dynamic_index_in_dim(c_local, li, 0, keepdims=False)
        owned = (idx >= row0) & (idx < row0 + rows_local)
        row = jax.lax.psum(jnp.where(owned, row, 0.0), axis)
        blended = (1.0 - beta) * row.astype(jnp.float32) + beta * u_full.astype(jnp.float32)
        return d_full, idx, blended

    d_full, idx, blended = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None), P(axis, None)),
        out_specs=(P(None), P(), P(None)),
        check_rep=False,
    )(u, cp)
    return d_full[:C], idx, blended


def chi2_rows_sharded(
    f_pred: jax.Array,  # (M_padded, J) probe rows, sharded over ``axis``
    f_true: jax.Array,  # (M_padded, J)
    s_soft: jax.Array,  # (M_padded, J)
    mesh: jax.sharding.Mesh,
    axis: str,
    local_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
) -> jax.Array:
    """Sharded per-row feedback scores (the dissolve/expand probe matrix):
    every shard scores only its own probe rows — no reduction at all, the
    (M_padded,) output is row-sharded and reassembles on exit; the caller
    slices the padded rows off."""
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(axis, None),) * 3,
        out_specs=P(axis),
        check_rep=False,
    )(f_pred, f_true, s_soft)


def chi2_all_sharded(
    f_pred: jax.Array,  # (M_padded, J) member rows, sharded over ``axis``
    f_true: jax.Array,  # (M_padded, J)
    s_soft: jax.Array,  # (M_padded, J)
    seg_onehot: jax.Array,  # (M_padded, S) membership one-hot; zero rows for padding
    mesh: jax.sharding.Mesh,
    axis: str,
    local_fn: Callable[..., tuple[jax.Array, jax.Array]],
) -> tuple[jax.Array, jax.Array]:
    """Sharded segmented feedback: (g (M_padded,), seg_sum (S,) psum'd
    globally); the caller slices the padded member rows off ``g``."""

    def body(fp_l, ft_l, ss_l, oh_l):
        g_local, seg_local = local_fn(fp_l, ft_l, ss_l, oh_l)
        return g_local, jax.lax.psum(seg_local, axis)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis, None),) * 4,
        out_specs=(P(axis), P(None)),
        check_rep=False,
    )(f_pred, f_true, s_soft, seg_onehot)
