"""Cluster-merge weight aggregation (paper Algorithm 1) as Pallas TPU kernels.

Line 2-6 of Algorithm 1, fused over flattened parameter vectors:

    da     = v_aux - v_main          (assumed optimization direction)
    dp     = v_trained - v_main      (posterior direction after local pass)
    p      = da * dp                 (per-weight agreement)
    alpha  = relu(p) / max(p)        (attention map, global-max normalized)
    merged = alpha * v_aux + (1 - alpha) * v_main

A naive jnp composition makes 5 HBM round-trips over N (~1e9..4e11)
elements; the fused form needs exactly two passes (a max-reduction, then a
blend that re-reads the three inputs once and writes once) — the minimum
possible given the global normalizer. Pass 1 accumulates a running max in a
(1,1) VMEM output ref across the sequential grid; pass 2 is a pure
elementwise VPU kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -3.4e38


def _max_kernel(vm_ref, va_ref, vt_ref, o_ref):
    ki = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _NEG)

    vm = vm_ref[...].astype(jnp.float32)
    p = (va_ref[...].astype(jnp.float32) - vm) * (vt_ref[...].astype(jnp.float32) - vm)
    o_ref[0, 0] = jnp.maximum(o_ref[0, 0], jnp.max(p))


def _blend_kernel(vm_ref, va_ref, vt_ref, pmax_ref, o_ref):
    vm = vm_ref[...].astype(jnp.float32)
    va = va_ref[...].astype(jnp.float32)
    p = (va - vm) * (vt_ref[...].astype(jnp.float32) - vm)
    denom = jnp.maximum(pmax_ref[0, 0], 1e-12)
    alpha = jnp.maximum(p, 0.0) / denom
    o_ref[...] = (alpha * va + (1.0 - alpha) * vm).astype(o_ref.dtype)


def merge_attention(
    v_main: jax.Array,  # (N,)
    v_aux: jax.Array,  # (N,)
    v_trained: jax.Array,  # (N,) main model after one local training pass
    *,
    block_n: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    (N,) = v_main.shape
    block_n = min(block_n, max(128, 1 << (N - 1).bit_length()))
    n_p = math.ceil(N / block_n) * block_n
    pad = lambda x: jnp.pad(x, (0, n_p - N)).reshape(1, n_p)
    vm, va, vt = pad(v_main), pad(v_aux), pad(v_trained)
    nk = n_p // block_n
    vec_spec = pl.BlockSpec((1, block_n), lambda k: (0, k))

    # Padding note: padded lanes give p = 0, which only matters if every real
    # p < 0; relu() zeroes those lanes in the blend anyway, so exactness holds.
    pmax = pl.pallas_call(
        _max_kernel,
        grid=(nk,),
        in_specs=[vec_spec, vec_spec, vec_spec],
        out_specs=pl.BlockSpec((1, 1), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(vm, va, vt)

    merged = pl.pallas_call(
        _blend_kernel,
        grid=(nk,),
        in_specs=[vec_spec, vec_spec, vec_spec, pl.BlockSpec((1, 1), lambda k: (0, 0))],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((1, n_p), v_main.dtype),
        interpret=interpret,
    )(vm, va, vt, pmax)
    return merged[0, :N]
