"""Many-vs-many L1 distance (Eq. 1, batched) as a tiled Pallas TPU kernel.

``l1_distance_pairwise(xs, cs)`` computes the (M, C) matrix of L1 distances
between every query vector and every center in a single launch — the merge
candidate search (``nearest_pair``), feedback-corrective reassignment, and
cluster dissolution all reduce to one call on the plane's stacked rows,
where the seed implementation looped the one-vs-many kernel row by row.

Grid: (M / block_m, C / block_c, N / block_n); the innermost n-dimension is
sequential, so each (block_m, block_c) output tile accumulates its partial
sums in fp32 across n-steps. The VPU does the |x - c| broadcast reduction
on a (block_m, block_c, block_n) tile; block sizes keep that tile well
under VMEM (8 * 8 * 8192 * 4 B = 2 MiB).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, c_ref, o_ref):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (block_m, block_n)
    c = c_ref[...].astype(jnp.float32)  # (block_c, block_n)
    o_ref[...] += jnp.sum(jnp.abs(x[:, None, :] - c[None, :, :]), axis=-1)


def l1_distance_pairwise(
    xs: jax.Array,  # (M, N)
    centers: jax.Array,  # (C, N)
    *,
    block_m: int = 8,
    block_c: int = 8,
    block_n: int = 8192,
    interpret: bool = False,
) -> jax.Array:
    M, N = xs.shape
    C = centers.shape[0]
    block_m = min(block_m, max(1, 1 << (M - 1).bit_length()))
    block_c = min(block_c, max(1, 1 << (C - 1).bit_length()))
    block_n = min(block_n, max(128, 1 << (N - 1).bit_length()))
    m_p = math.ceil(M / block_m) * block_m
    c_p = math.ceil(C / block_c) * block_c
    n_p = math.ceil(N / block_n) * block_n
    # Zero padding in N is exact for L1; padded M/C rows are sliced off.
    xp = jnp.pad(xs, ((0, m_p - M), (0, n_p - N)))
    cp = jnp.pad(centers, ((0, c_p - C), (0, n_p - N)))

    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(m_p // block_m, c_p // block_c, n_p // block_n),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda m, c, n: (m, n)),
            pl.BlockSpec((block_c, block_n), lambda m, c, n: (c, n)),
        ],
        out_specs=pl.BlockSpec((block_m, block_c), lambda m, c, n: (m, c)),
        out_shape=jax.ShapeDtypeStruct((m_p, c_p), jnp.float32),
        interpret=interpret,
    )(xp, cp)
    return out[:M, :C]
