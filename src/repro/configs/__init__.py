from repro.configs.base import (
    ARCH_REGISTRY,
    SHAPES,
    LayerSpec,
    MLASpec,
    MambaSpec,
    ModelConfig,
    MoESpec,
    ShapeSpec,
    TrainSpec,
    get_config,
    register_arch,
    supports_shape,
)

# Importing the arch modules populates ARCH_REGISTRY.
from repro.configs import (  # noqa: F401  (registration side effects)
    command_r_35b,
    deepseek_v2_lite_16b,
    gemma2_2b,
    granite_moe_3b_a800m,
    hubert_xlarge,
    jamba_1_5_large_398b,
    llama3_2_1b,
    llama3_405b,
    paper_tasks,
    pixtral_12b,
    tiny_lm,
    xlstm_1_3b,
)
from repro.configs.tiny_lm import TINY_LM

__all__ = [
    "ARCH_REGISTRY",
    "TINY_LM",
    "SHAPES",
    "LayerSpec",
    "MLASpec",
    "MambaSpec",
    "ModelConfig",
    "MoESpec",
    "ShapeSpec",
    "TrainSpec",
    "get_config",
    "register_arch",
    "supports_shape",
]
