"""Llama-3.2 1B [hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="llama3.2-1b",
        family="dense",
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        pattern=(LayerSpec("attn", "dense"),),
        num_periods=16,
        tie_embeddings=True,
        rope_theta=500000.0,
        train=TrainSpec(optimizer="adamw", microbatches=1, remat=True),
    )
)
