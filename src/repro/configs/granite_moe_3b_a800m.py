"""IBM Granite MoE 3B-a800m [hf:ibm-granite; hf].

32L d_model=1536 24H (GQA kv=8), MoE 40 experts top-8, d_expert=512,
vocab 49155 (padded to 49408 for TP-16 sharding).
"""
from repro.configs.base import LayerSpec, ModelConfig, MoESpec, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        pattern=(LayerSpec("attn", "moe"),),
        num_periods=32,
        moe=MoESpec(num_experts=40, top_k=8, d_expert=512),
        tie_embeddings=True,
        rope_theta=10000.0,
        train=TrainSpec(optimizer="adamw", microbatches=1, remat=True),
    )
)
