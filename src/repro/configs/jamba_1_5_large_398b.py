"""Jamba-1.5 Large 398B [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576, Mamba:attention 7:1
interleave, MoE 16 experts top-2 on every other layer. Period of 8:
mamba x4 / attn at slot 4 / mamba x3, with dense/MoE FFNs alternating.

long_500k RUNS for this arch: Mamba state is O(1) in sequence; only the
9 attention layers keep a (data-axis-sharded) KV cache.
"""
from repro.configs.base import LayerSpec, MambaSpec, ModelConfig, MoESpec, TrainSpec, register_arch

_PERIOD = (
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "dense"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"),
    LayerSpec("mamba", "moe"),
)

CONFIG = register_arch(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        pattern=_PERIOD,
        num_periods=9,
        moe=MoESpec(num_experts=16, top_k=2, d_expert=24576),
        mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
        rope_theta=10000.0,
        train=TrainSpec(optimizer="adafactor", microbatches=16, remat=True, dp_shard_params=True),
    )
)
