"""tiny_lm: CI-sized decoder for the federated LM personalization task.

Not one of the ten assigned architectures — this is the REPRO_TASK=lm
workload's frozen base, sized so transformer-path tests and the ci.sh LM
smoke leg run in seconds on CPU (d_model 64, 2 layers, 256-token vocab).
GQA (2 query heads per KV head) is deliberate: the LM fleet path then
exercises the grouped flash-attention kernels, not just MHA.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

TINY_LM = register_arch(
    ModelConfig(
        name="tiny_lm",
        family="dense",
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        pattern=(LayerSpec("attn", "dense"),),
        num_periods=2,
        head_dim=16,
        tie_embeddings=True,
        rope_theta=10000.0,
        train=TrainSpec(optimizer="sgdm", remat=False),
        notes="CI-sized frozen base for the EchoPFL LM personalization task",
    )
)
