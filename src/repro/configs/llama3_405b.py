"""Llama-3 405B [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

Memory policy (v5e 16GB x 256): adafactor (factored second moment),
bf16 params, microbatch accumulation x16, remat, ZeRO param/state
sharding over the data axis. See EXPERIMENTS.md §Dry-run.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="llama3-405b",
        family="dense",
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        pattern=(LayerSpec("attn", "dense"),),
        num_periods=126,
        rope_theta=500000.0,
        train=TrainSpec(optimizer="adafactor", microbatches=16, remat=True, dp_shard_params=True),
    )
)
