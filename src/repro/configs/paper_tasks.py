"""The paper's own client models (Sec. 7.1), used by the protocol-level
experiments and benchmarks. Small MLPs matching the paper's model sizes:

  T1 image recognition:  2 conv + 1 fc   -> here: 2 hidden-layer MLP on the
  T2 HAR:                2 fc                synthetic feature tasks (the
  T3 sound detection:    2 conv + 2 fc       synthetic data is featurized,
  T4 file cleaning:      2 conv + 2 fc       so convs become dense layers)

These run real federated training on CPU inside the benchmarks, so they
must stay tiny. They use the same init/apply machinery as the big zoo so
the EchoPFL core is exercised identically.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MLPTaskConfig:
    name: str
    input_dim: int
    hidden: tuple[int, ...]
    num_classes: int


PAPER_TASKS: dict[str, MLPTaskConfig] = {
    "image_recognition": MLPTaskConfig("image_recognition", 128, (128, 64), 10),
    "har": MLPTaskConfig("har", 64, (64,), 6),
    "sound_detection": MLPTaskConfig("sound_detection", 96, (96, 64), 9),
    "file_cleaning": MLPTaskConfig("file_cleaning", 128, (64, 32), 2),
}
