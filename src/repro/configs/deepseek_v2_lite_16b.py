"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf].

27L d_model=2048 16H, MLA (kv_lora=512), MoE 64 routed top-6 + 2 shared,
d_expert=1408, vocab 102400. First layer uses a dense FFN (per the HF
config: first_k_dense_replace=1), remaining 26 layers are MoE.
"""
from repro.configs.base import LayerSpec, MLASpec, ModelConfig, MoESpec, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=192,  # qk_nope(128) + qk_rope(64)
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        prefix=(LayerSpec("attn", "dense"),),
        pattern=(LayerSpec("attn", "moe"),),
        num_periods=26,
        mla=MLASpec(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
        moe=MoESpec(num_experts=64, top_k=6, d_expert=1408, num_shared=2),
        rope_theta=10000.0,
        train=TrainSpec(optimizer="adamw", microbatches=4, remat=True, dp_shard_params=True),
        notes="MLA caches the 512-dim latent + 64-dim rope key instead of full KV.",
    )
)
