"""Pixtral 12B [hf:mistralai/Pixtral-12B-2409; unverified].

Decoder backbone (mistral-nemo style): 40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072. The pixtral-ViT modality frontend is a STUB per
the brief: ``input_specs`` supplies precomputed patch embeddings of shape
(batch, seq, d_model); the backbone consumes embeddings directly
(``embeds_input=True``) and predicts text tokens.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="pixtral-12b",
        family="vlm",
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        pattern=(LayerSpec("attn", "dense"),),
        num_periods=40,
        embeds_input=True,
        rope_theta=1_000_000.0,
        train=TrainSpec(optimizer="adamw", microbatches=2, remat=True, dp_shard_params=True),
    )
)
