"""Gemma-2 2B [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000. Alternating
local (sliding-window 4096) and global attention, attention/final logit
softcapping, post-norms, fixed query scale 1/sqrt(256).
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="gemma2-2b",
        family="dense",
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        pattern=(LayerSpec("attn_local", "dense"), LayerSpec("attn", "dense")),
        num_periods=13,
        sliding_window=4096,
        final_logit_softcap=30.0,
        attn_logit_softcap=50.0,
        query_pre_attn_scalar=256.0,
        use_post_norm=True,
        tie_embeddings=True,
        rope_theta=10000.0,
        train=TrainSpec(optimizer="adamw", microbatches=1, remat=True),
        notes="long_500k skipped: every other layer is global full attention.",
    )
)
