"""xLSTM 1.3B [arXiv:2405.04517; unverified].

48 blocks, d_model=2048, 4 heads, vocab 50304, d_ff=0 (blocks carry their
own projections). Mix of mLSTM (matrix-memory, chunkwise-parallel) and
sLSTM (scalar-memory, strictly sequential) blocks at 7:1.

long_500k RUNS: recurrent state is O(1) in sequence length.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

_PERIOD = tuple([LayerSpec("mlstm", "none")] * 7 + [LayerSpec("slstm", "none")])

CONFIG = register_arch(
    ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        head_dim=512,
        d_ff=0,
        vocab_size=50304,
        pattern=_PERIOD,
        num_periods=6,
        tie_embeddings=True,
        train=TrainSpec(optimizer="adamw", microbatches=1, remat=True),
    )
)
