"""Config system: one declarative ModelConfig covers all 10 assigned
architecture families (dense / MoE / MLA / VLM / audio-encoder / hybrid
Mamba / xLSTM).

A model is ``prefix`` (unrolled layers) followed by ``pattern`` repeated
``num_periods`` times (scanned — keeps HLO size O(1) in depth for the
126-layer models). Each layer is a (mixer, ffn) pair:

  mixer: "attn" | "attn_local" | "mamba" | "mlstm" | "slstm"
  ffn:   "dense" | "moe" | "none"
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Mixer = Literal["attn", "attn_local", "mamba", "mlstm", "slstm"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ffn: Ffn = "dense"


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0  # shared (always-on) experts, DeepSeek-style
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLASpec:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int | None = None  # V2-Lite projects q directly


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Execution policy knobs that make each (arch x shape) cell fit + run fast.

    These are the §Perf levers: microbatching bounds activation memory,
    remat bounds residual memory, the optimizer choice bounds state memory
    (adafactor for the 400B-class models), and dp_shard_params turns on
    ZeRO/FSDP-style parameter+state sharding over the data axis.
    """

    optimizer: Literal["adamw", "adafactor", "sgdm"] = "adamw"
    microbatches: int = 1
    remat: bool = True
    dp_shard_params: bool = False
    learning_rate: float = 3e-4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...]
    num_periods: int
    prefix: tuple[LayerSpec, ...] = ()
    head_dim: int | None = None
    moe: MoESpec | None = None
    mla: MLASpec | None = None
    mamba: MambaSpec | None = None
    causal: bool = True
    is_encoder: bool = False
    sliding_window: int = 4096
    rope_theta: float = 10000.0
    final_logit_softcap: float | None = None
    attn_logit_softcap: float | None = None
    query_pre_attn_scalar: float | None = None  # gemma2: fixed 1/sqrt(256) scale
    use_post_norm: bool = False  # gemma2 applies RMSNorm after mixer/ffn too
    tie_embeddings: bool = False
    embeds_input: bool = False  # vlm/audio: frontend stub feeds embeddings
    # dropless MoE: expert capacity = group size, so no token ever overflows.
    # Decode (1 token/step) is naturally dropless; enabling this makes the
    # full forward bit-consistent with incremental decode (serving/test mode;
    # training keeps capacity_factor dispatch for efficiency).
    moe_dropless: bool = False
    norm_eps: float = 1e-6
    train: TrainSpec = TrainSpec()
    # xLSTM block internals
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    notes: str = ""

    # ---- derived ----
    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.pattern) * self.num_periods

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP=16 shards evenly."""
        return math.ceil(self.vocab_size / 256) * 256

    @property
    def all_layers(self) -> tuple[LayerSpec, ...]:
        return self.prefix + self.pattern * self.num_periods

    @property
    def subquadratic(self) -> bool:
        """True if decode state stays O(1)-ish in sequence length (SSM/hybrid)."""
        mixers = {layer.mixer for layer in self.all_layers}
        return mixers.issubset({"mamba", "mlstm", "slstm"}) or (
            self.family in ("hybrid", "ssm")
        )

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS and comm accounting)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            n += d * self.padded_vocab
        for layer in self.all_layers:
            n += self._mixer_params(layer.mixer, d, hd)
            n += self._ffn_params(layer.ffn, d)
            n += 2 * d  # norms
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE top-k only) — for 6*N_active*D."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.padded_vocab * d
        if not self.tie_embeddings:
            n += d * self.padded_vocab
        for layer in self.all_layers:
            n += self._mixer_params(layer.mixer, d, hd)
            if layer.ffn == "moe":
                assert self.moe is not None
                active = self.moe.top_k + self.moe.num_shared
                n += active * 3 * d * self.moe.d_expert + d * self.moe.num_experts
            else:
                n += self._ffn_params(layer.ffn, d)
            n += 2 * d
        n += d
        return n

    def _mixer_params(self, mixer: str, d: int, hd: int) -> int:
        if mixer in ("attn", "attn_local"):
            if self.mla is not None:
                m = self.mla
                qdim = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                n = d * qdim  # q proj (no lora in Lite)
                n += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # compressed kv + rope k
                n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                n += self.num_heads * m.v_head_dim * d  # out proj
                return n
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o
        if mixer == "mamba":
            assert self.mamba is not None
            di, ds, dc = self.mamba.d_inner(d), self.mamba.d_state, self.mamba.d_conv
            n = d * 2 * di  # in proj (x, z)
            n += di * dc  # conv
            n += di * (ds * 2 + 1) + di  # B, C, dt projections (x -> dt low rank simplified) + dt bias
            n += di * ds + di  # A_log, D
            n += di * d  # out proj
            return n
        if mixer == "mlstm":
            di = int(d * self.mlstm_proj_factor)
            n = d * 2 * di  # up proj (x, z)
            n += 3 * di * di // max(self.num_heads, 1)  # q,k,v block-diag proj (per-head)
            n += 3 * di  # i, f gates + norm
            n += di * d  # down proj
            return n
        if mixer == "slstm":
            di = d
            n = 4 * di * di + 4 * di * di  # input + recurrent weights (i,f,z,o)
            n += 4 * di
            n += int(d * self.slstm_proj_factor) * d * 2  # post-block FFN up/down
            return n
        raise ValueError(mixer)

    def _ffn_params(self, ffn: str, d: int) -> int:
        if ffn == "dense":
            return 3 * d * self.d_ff  # swiglu: gate, up, down
        if ffn == "moe":
            assert self.moe is not None
            total = (self.moe.num_experts + self.moe.num_shared) * 3 * d * self.moe.d_expert
            total += d * self.moe.num_experts  # router
            return total
        if ffn == "none":
            return 0
        raise ValueError(ffn)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register_arch(config: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[config.name] = config
    return config


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]


def supports_shape(config: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not). Skip rules per the brief + DESIGN.md §4."""
    if config.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not config.subquadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic state"
    return True, ""


def reduced_config(config: ModelConfig, d_model: int = 64, periods: int = 2) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (per-arch requirement)."""
    scale = d_model / config.d_model
    heads = max(2, min(config.num_heads, 4))
    kv = max(1, min(config.num_kv_heads, heads))
    kw: dict = dict(
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=max(8, d_model // heads),
        d_ff=max(16, int(config.d_ff * scale)) if config.d_ff else 0,
        vocab_size=min(config.vocab_size, 512),
        num_periods=periods,
        prefix=config.prefix[: min(len(config.prefix), 1)],
        train=dataclasses.replace(config.train, microbatches=1, dp_shard_params=False),
    )
    if config.moe is not None:
        kw["moe"] = dataclasses.replace(
            config.moe, num_experts=4, top_k=min(config.moe.top_k, 2), d_expert=max(16, int(config.moe.d_expert * scale))
        )
    if config.mla is not None:
        kw["mla"] = MLASpec(kv_lora_rank=16, qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8)
        kw["head_dim"] = 8
    if config.mamba is not None:
        kw["mamba"] = dataclasses.replace(config.mamba, d_state=8)
    if config.sliding_window:
        kw["sliding_window"] = 16
    return dataclasses.replace(config, **kw)
