"""Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000, no biases.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="command-r-35b",
        family="dense",
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab_size=256000,
        pattern=(LayerSpec("attn", "dense"),),
        num_periods=40,
        tie_embeddings=True,
        rope_theta=8_000_000.0,
        train=TrainSpec(optimizer="adamw", microbatches=4, remat=True, dp_shard_params=True),
    )
)
