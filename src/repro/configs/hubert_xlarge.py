"""HuBERT X-Large [arXiv:2106.07447; unverified].

Encoder-only (bidirectional) transformer: 48L d_model=1280 16H d_ff=5120,
output vocabulary = 504 cluster units (masked-prediction targets), padded
to 512 for sharding. The wav2vec2-style convolutional waveform frontend is
a STUB: ``input_specs`` supplies precomputed frame embeddings
(batch, frames, d_model). No decode step exists (encoder-only) — decode
shapes are skipped per the brief.
"""
from repro.configs.base import LayerSpec, ModelConfig, TrainSpec, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        pattern=(LayerSpec("attn", "dense"),),
        num_periods=48,
        causal=False,
        is_encoder=True,
        embeds_input=True,
        train=TrainSpec(optimizer="adamw", microbatches=1, remat=True),
    )
)
