"""Shared low-level utilities: pytree math, rng, timing, logging."""
from repro.common.pytrees import (
    tree_add,
    tree_axpy,
    tree_flat_vector,
    tree_l1,
    tree_l2,
    tree_lerp,
    tree_num_params,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_flat_vector",
    "tree_l1",
    "tree_l2",
    "tree_lerp",
    "tree_num_params",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
]
