"""Deterministic RNG plumbing: named key derivation so every subsystem is
reproducible independently of call order."""
from __future__ import annotations

import hashlib

import jax
import numpy as np


def derive_key(root: jax.Array, *names: str | int) -> jax.Array:
    """Derive a subkey from a root key by hashing a name path.

    Unlike sequential ``split`` chains, adding a consumer never perturbs the
    streams of existing consumers — important for the async simulator where
    client event order is nondeterministic.
    """
    key = root
    for name in names:
        digest = hashlib.sha256(str(name).encode()).digest()
        salt = int.from_bytes(digest[:4], "little")
        key = jax.random.fold_in(key, salt)
    return key


def np_rng(seed: int | str) -> np.random.Generator:
    if isinstance(seed, str):
        seed = int.from_bytes(hashlib.sha256(seed.encode()).digest()[:8], "little")
    return np.random.default_rng(seed)
