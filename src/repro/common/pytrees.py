"""Pytree arithmetic used across the EchoPFL coordination layer.

All protocol-level operations (L1 clustering distance, Algorithm-1 merge,
broadcast decision rule) are defined on parameter *pytrees*. These helpers
keep that arithmetic in one place so the server, baselines, and tests agree
on semantics. Everything is jit-compatible.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_lerp(a: PyTree, b: PyTree, t) -> PyTree:
    """(1 - t) * a + t * b — the asynchronous mixing step (FedAsyn-style)."""
    return jax.tree_util.tree_map(lambda ai, bi: (1.0 - t) * ai + t * bi, a, b)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_l1(a: PyTree, b: PyTree | None = None) -> jax.Array:
    """Sum of absolute (differences of) leaves — Eq. 1's L1 distance."""
    if b is None:
        parts = [jnp.sum(jnp.abs(x)) for x in jax.tree_util.tree_leaves(a)]
    else:
        parts = [
            jnp.sum(jnp.abs(x - y))
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
        ]
    return jnp.sum(jnp.stack(parts)) if parts else jnp.zeros(())


def tree_l2(a: PyTree, b: PyTree | None = None) -> jax.Array:
    if b is None:
        parts = [jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(a)]
    else:
        parts = [
            jnp.sum(jnp.square(x - y))
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
        ]
    return jnp.sqrt(jnp.sum(jnp.stack(parts))) if parts else jnp.zeros(())


class FlattenSpec:
    """Precomputed flatten/unflatten plan for one pytree structure.

    ``tree_flat_vector``/``tree_unflatten_vector`` historically re-derived
    the treedef, leaf shapes, and offsets on *every* call — measurable pure
    Python overhead on the server hot path, where every arriving upload is
    flattened and every downlink materialized. A spec derives that plan
    once per (treedef, shapes, dtypes) and jit-caches the two adapters, so
    repeat calls are a single compiled dispatch. Obtain specs via
    :func:`flatten_spec`, which memoizes them globally.
    """

    def __init__(self, template: PyTree, dtype=jnp.float32):
        leaves, treedef = jax.tree_util.tree_flatten(template)
        self.treedef = treedef
        self.shapes = tuple(tuple(jnp.shape(x)) for x in leaves)
        self.dtypes = tuple(jnp.result_type(x) for x in leaves)
        self.sizes = tuple(math.prod(s) if s else 1 for s in self.shapes)
        offsets, off = [], 0
        for n in self.sizes:
            offsets.append(off)
            off += n
        self.offsets = tuple(offsets)
        self.dim = off
        self.dtype = jnp.dtype(dtype)
        self.flatten = jax.jit(self._flatten)
        self.unflatten = jax.jit(self._unflatten)

    def _flatten(self, tree: PyTree) -> jax.Array:
        leaves = jax.tree_util.tree_leaves(tree)
        if not leaves:
            return jnp.zeros((0,), self.dtype)
        return jnp.concatenate([jnp.ravel(x).astype(self.dtype) for x in leaves])

    def _unflatten(self, vec: jax.Array) -> PyTree:
        out = [
            jnp.reshape(vec[off : off + n], shape).astype(dt)
            for off, n, shape, dt in zip(self.offsets, self.sizes, self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def unflatten_np(self, vec) -> PyTree:
        """Host-side unflatten: numpy views over one flat row, zero device
        dispatches — for fanning a batched device result back out into many
        per-item protocol pytrees (same layout plan as :meth:`unflatten`)."""
        out = [
            vec[off : off + n].reshape(shape).astype(dt, copy=False)
            for off, n, shape, dt in zip(self.offsets, self.sizes, self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, out)


_SPEC_CACHE: dict = {}


def flatten_spec(template: PyTree, dtype=jnp.float32) -> FlattenSpec:
    """Memoized :class:`FlattenSpec` for ``template``'s structure."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    key = (
        treedef,
        tuple((tuple(jnp.shape(x)), jnp.result_type(x)) for x in leaves),
        jnp.dtype(dtype),
    )
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        spec = _SPEC_CACHE[key] = FlattenSpec(template, dtype)
    return spec


def tree_flat_vector(a: PyTree, dtype=jnp.float32) -> jax.Array:
    """Flatten a parameter pytree into a single 1-D vector (stable leaf order)."""
    return flatten_spec(a, dtype).flatten(a)


def tree_unflatten_vector(vec: jax.Array, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_flat_vector` against a template pytree."""
    return flatten_spec(like).unflatten(vec)


def tree_num_params(a: PyTree) -> int:
    return sum(math.prod(x.shape) if x.shape else 1 for x in jax.tree_util.tree_leaves(a))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), a)


def tree_weighted_mean(trees: list[PyTree], weights) -> PyTree:
    """Weighted average of a list of pytrees (FedAvg aggregation)."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def avg(*leaves):
        return sum(wi * leaf for wi, leaf in zip(w, leaves))

    return jax.tree_util.tree_map(avg, *trees)
