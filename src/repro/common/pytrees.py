"""Pytree arithmetic used across the EchoPFL coordination layer.

All protocol-level operations (L1 clustering distance, Algorithm-1 merge,
broadcast decision rule) are defined on parameter *pytrees*. These helpers
keep that arithmetic in one place so the server, baselines, and tests agree
on semantics. Everything is jit-compatible.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_lerp(a: PyTree, b: PyTree, t) -> PyTree:
    """(1 - t) * a + t * b — the asynchronous mixing step (FedAsyn-style)."""
    return jax.tree_util.tree_map(lambda ai, bi: (1.0 - t) * ai + t * bi, a, b)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_l1(a: PyTree, b: PyTree | None = None) -> jax.Array:
    """Sum of absolute (differences of) leaves — Eq. 1's L1 distance."""
    if b is None:
        parts = [jnp.sum(jnp.abs(x)) for x in jax.tree_util.tree_leaves(a)]
    else:
        parts = [
            jnp.sum(jnp.abs(x - y))
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
        ]
    return jnp.sum(jnp.stack(parts)) if parts else jnp.zeros(())


def tree_l2(a: PyTree, b: PyTree | None = None) -> jax.Array:
    if b is None:
        parts = [jnp.sum(jnp.square(x)) for x in jax.tree_util.tree_leaves(a)]
    else:
        parts = [
            jnp.sum(jnp.square(x - y))
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
        ]
    return jnp.sqrt(jnp.sum(jnp.stack(parts))) if parts else jnp.zeros(())


def tree_flat_vector(a: PyTree, dtype=jnp.float32) -> jax.Array:
    """Flatten a parameter pytree into a single 1-D vector (stable leaf order)."""
    leaves = jax.tree_util.tree_leaves(a)
    if not leaves:
        return jnp.zeros((0,), dtype)
    return jnp.concatenate([jnp.ravel(x).astype(dtype) for x in leaves])


def tree_unflatten_vector(vec: jax.Array, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_flat_vector` against a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for leaf in leaves:
        n = math.prod(leaf.shape) if leaf.shape else 1
        out.append(jnp.reshape(vec[off : off + n], leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_num_params(a: PyTree) -> int:
    return sum(math.prod(x.shape) if x.shape else 1 for x in jax.tree_util.tree_leaves(a))


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), a)


def tree_weighted_mean(trees: list[PyTree], weights) -> PyTree:
    """Weighted average of a list of pytrees (FedAvg aggregation)."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def avg(*leaves):
        return sum(wi * leaf for wi, leaf in zip(w, leaves))

    return jax.tree_util.tree_map(avg, *trees)
