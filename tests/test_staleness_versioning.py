"""Staleness accounting (Sec. 5.1) + CI version control (Sec. 6)."""
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.staleness import StalenessTracker
from repro.core.versioning import ModelRepo, RWLock


# ------------------------------------------------------------------ staleness
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=50))
def test_tracker_stats_match_numpy(xs):
    t = StalenessTracker()
    for x in xs:
        t.record(x)
    assert t.q_max == max(xs)
    assert np.isclose(t.q_avg, np.mean(xs))
    if max(xs) == 0:
        assert t.convergence_proxy == 0.0
    else:
        assert np.isclose(t.convergence_proxy, np.sqrt(max(xs) * np.mean(xs)))


def test_tracker_rejects_negative():
    t = StalenessTracker()
    with pytest.raises(ValueError):
        t.record(-1)


def test_proxy_is_exactly_zero_without_staleness():
    """Regression: the 1e-12 floors used to leak into staleness-free runs,
    reporting sqrt(1e-12 * 1e-12) instead of 0.0."""
    t = StalenessTracker()
    assert t.convergence_proxy == 0.0  # no records at all
    for _ in range(5):
        t.record(0)
    assert t.convergence_proxy == 0.0  # records, all zero
    t.record(3)
    assert t.convergence_proxy > 0.0  # real staleness still reports


def test_broadcast_lowers_convergence_proxy():
    """The paper's O(sqrt(Qmax*Qavg)) argument: capping staleness (what a
    broadcast does) strictly improves the proxy."""
    with_bcast, without = StalenessTracker(), StalenessTracker()
    stale = [0, 1, 2, 40, 1, 0, 35, 2]
    for s in stale:
        without.record(s)
        with_bcast.record(min(s, 3))  # broadcast refreshes bases
    assert with_bcast.convergence_proxy < without.convergence_proxy


# ----------------------------------------------------------------- versioning
def test_branch_push_pull_roundtrip():
    repo = ModelRepo()
    b = repo.branch("cluster/0", {"w": 0.0})
    assert b.pull() == ({"w": 0.0}, 0)
    v = b.push("client1", lambda head: {"w": head["w"] + 1.0}, "inc")
    assert v == 1
    assert b.pull() == ({"w": 1.0}, 1)
    assert b.pull(have_version=1) is None   # already current
    assert b.pull(have_version=0) == ({"w": 1.0}, 1)


def test_branch_requires_model_on_create():
    repo = ModelRepo()
    with pytest.raises(KeyError):
        repo.branch("missing")


def test_concurrent_pushes_lose_nothing():
    """The RW-locked push is the paper's conflict-resolution: N threads each
    apply +1; the result must be exactly N (no lost updates)."""
    repo = ModelRepo()
    b = repo.branch("c", {"w": 0})
    n, per = 8, 50

    def worker():
        for _ in range(per):
            b.push("t", lambda head: {"w": head["w"] + 1})

    threads = [threading.Thread(target=worker) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    model, version = b.pull()
    assert model["w"] == n * per
    assert version == n * per


def test_concurrent_reads_during_writes():
    b = ModelRepo().branch("c", {"w": 0})
    stop = threading.event = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            model, v = b.pull()
            if model["w"] != v:  # each push keeps w == version
                errors.append((model["w"], v))

    rt = threading.Thread(target=reader)
    rt.start()
    for _ in range(200):
        b.push("w", lambda head: {"w": head["w"] + 1})
    stop.set()
    rt.join()
    assert not errors, f"torn reads: {errors[:3]}"


def test_merge_branches():
    repo = ModelRepo()
    repo.branch("a", {"w": 1.0})
    repo.branch("b", {"w": 3.0})
    merged = repo.merge_branches("a", "b", lambda dst, src: {"w": (dst["w"] + src["w"]) / 2})
    assert merged.pull()[0] == {"w": 2.0}
    assert repo.names() == ["a"]


def test_rwlock_writer_preference_no_starvation():
    lock = RWLock()
    order = []

    def writer():
        lock.acquire_write()
        order.append("w")
        lock.release_write()

    lock.acquire_read()
    t = threading.Thread(target=writer)
    t.start()
    import time

    time.sleep(0.05)
    assert order == []  # writer blocked by reader
    lock.release_read()
    t.join(timeout=2)
    assert order == ["w"]
