"""Flash-attention kernels vs the MODEL-layer oracle.

``tests/test_attention_grads.py`` checks the kernels against the kernel
package's own jnp oracle (``kernels.ref``). This file closes the other
half of the loop: the kernels must also agree with
``models.layers.attention_scores_reference`` — the (B, S, H, hd)-layout
reference that ``apply_attention`` is specified against — forward AND
under jax.grad. A drift between the two oracles (layout bridge, GQA
expansion order, mask sign conventions, softcap chain rule) would let
model-level tests and kernel-level tests both pass while the LM training
path silently computed something else.

Layout bridge: kernels take (B, H, S, hd); the layers reference takes
(B, S, H, hd). ``apply_attention`` crosses with swapaxes(1, 2) — so do we.
Runs in interpret mode, so it exercises the Pallas kernel logic on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_with_lse
from repro.kernels.flash_attention_bwd import flash_attention_bwd
from repro.models.layers import attention_scores_reference

CASES = [
    # B, H, KV, S, hd, causal, window, softcap
    (1, 4, 4, 64, 32, True, None, None),     # causal MHA
    (2, 4, 2, 64, 16, True, None, None),     # GQA 2:1 (tiny_lm's shape class)
    (1, 8, 2, 48, 32, True, None, None),     # GQA 4:1
    (1, 2, 2, 64, 32, False, None, None),    # bidirectional (encoder)
    (1, 4, 2, 64, 32, True, 16, None),       # sliding window
    (1, 4, 4, 64, 32, True, None, 30.0),     # logit softcap
    (1, 4, 2, 100, 16, True, None, None),    # ragged (non-pow2) seq
]


def _inputs(case, seed=0):
    B, H, KV, S, hd, causal, window, softcap = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    # layers layout: (B, S, heads, hd)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    kw = dict(causal=causal, scale=hd ** -0.5, window=window, softcap=softcap)
    return q, k, v, kw


def _flash_fwd(q, k, v, **kw):
    """Run the kernel on layers-layout inputs via the swapaxes bridge
    apply_attention uses, returning layers-layout output."""
    o, _ = flash_attention_with_lse(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        interpret=True, **kw,
    )
    return o.swapaxes(1, 2)


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_forward_matches_layers_reference(case):
    q, k, v, kw = _inputs(case)
    want = attention_scores_reference(q, k, v, **kw)
    got = _flash_fwd(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_grads_match_layers_reference(case):
    q, k, v, kw = _inputs(case, seed=1)

    def loss_ref(q, k, v):
        o = attention_scores_reference(q, k, v, **kw)
        return jnp.sum(o * jnp.sin(o))  # nontrivial cotangent

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)

    qk, kk, vk = (x.swapaxes(1, 2) for x in (q, k, v))
    o, lse = flash_attention_with_lse(qk, kk, vk, interpret=True, **kw)
    do = jax.grad(lambda o_: jnp.sum(o_ * jnp.sin(o_)))(o)
    got = flash_attention_bwd(qk, kk, vk, o, lse, do, interpret=True, **kw)

    for g, w, name in zip(got, want, ("dq", "dk", "dv")):
        np.testing.assert_allclose(
            np.asarray(g.swapaxes(1, 2)), np.asarray(w),
            atol=3e-4, rtol=3e-4, err_msg=name,
        )


def test_q_pos0_decode_offset_matches_reference():
    """Decode-style call: 4 new queries attending into a longer KV context
    at position offset — both oracles must place the causal mask the same
    way."""
    B, H, S_kv, S_q, hd = 1, 4, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S_q, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S_kv, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S_kv, H, hd), jnp.float32)
    pos0 = S_kv - S_q
    kw = dict(causal=True, scale=hd ** -0.5)
    want = attention_scores_reference(q, k, v, q_pos0=pos0, **kw)
    o, _ = flash_attention_with_lse(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        q_pos0=pos0, interpret=True, **kw,
    )
    np.testing.assert_allclose(np.asarray(o.swapaxes(1, 2)), np.asarray(want),
                               atol=3e-4, rtol=3e-4)


def test_chunked_reference_is_consistent():
    """chunk_q (memory-efficient path) of the layers oracle agrees with its
    own unchunked path AND the kernel — three-way agreement."""
    q, k, v, kw = _inputs((1, 4, 2, 64, 32, True, None, None), seed=2)
    full = attention_scores_reference(q, k, v, **kw)
    chunked = attention_scores_reference(q, k, v, chunk_q=16, **kw)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(_flash_fwd(q, k, v, **kw)),
                               np.asarray(full), atol=3e-4, rtol=3e-4)
