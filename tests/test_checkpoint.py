"""Fault-tolerant checkpointing: atomicity, manifests, async writer,
retention, and full EchoPFL-server state restore (elastic restart)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    restore_pytree,
    save_pytree,
)


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer0": {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32), "b": jnp.zeros(3)},
        "step": jnp.asarray(7, jnp.int32),
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save_pytree(str(tmp_path / "ckpt"), t, extra={"note": "hi"})
    got, extra = restore_pytree(str(tmp_path / "ckpt"), like=t)
    assert_tree_equal(t, got)
    assert extra == {"note": "hi"}


def test_restore_detects_corruption(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(d, tree())
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    manifest["checksum"] = "0" * 64
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with pytest.raises(IOError):
        restore_pytree(d, like=tree())


def test_restore_detects_structure_mismatch(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(d, tree())
    wrong = {"other": jnp.zeros(3)}
    with pytest.raises(ValueError):
        restore_pytree(d, like=wrong)


def test_overwrite_is_atomic_replacement(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(d, tree(0))
    save_pytree(d, tree(1))
    got, _ = restore_pytree(d, like=tree())
    assert_tree_equal(tree(1), got)
    assert not [n for n in os.listdir(tmp_path) if n.startswith("tmp.")]


def test_checkpointer_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(s))
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2
    step, got, _ = ck.restore_latest(like=tree())
    assert step == 4
    assert_tree_equal(tree(4), got)
    ck.close()


def test_async_writer_snapshot_isolation(tmp_path):
    """save_async snapshots immediately: mutating (donating) the arrays
    afterwards must not corrupt the checkpoint."""
    ck = Checkpointer(str(tmp_path), keep=3)
    t = {"w": np.ones(8, np.float32)}
    ck.save_async(5, t, extra={"k": 1})
    t["w"] *= -1  # simulate buffer donation
    ck.wait()
    step, got, extra = ck.restore_latest(like={"w": np.zeros(8, np.float32)})
    assert step == 5 and extra == {"k": 1}
    np.testing.assert_array_equal(got["w"], np.ones(8))
    ck.close()


def test_server_state_checkpoint_roundtrip(tmp_path):
    """Elastic restart: full EchoPFL server state (clusters, predictors,
    Top-K records, staleness) survives save -> new server -> load."""
    from repro.core.server import EchoPFLServer

    init = {"w": jnp.zeros(6)}
    srv = EchoPFLServer(init, num_initial_clusters=2, seed=0)
    for i, x in enumerate((0.0, 10.0, 0.5, 9.5, 0.2)):
        srv.handle_upload(i % 4, {"w": jnp.full(6, x)}, 0, 16, t=float(i))
    tree_s, meta = srv.state_dict()
    save_pytree(str(tmp_path / "srv"), tree_s, extra=meta)

    srv2 = EchoPFLServer(init, num_initial_clusters=2, seed=0)
    raw_meta = restore_pytree(str(tmp_path / "srv"), like=None)[1]
    template = srv2.state_template(raw_meta)
    tree_r, meta_r = restore_pytree(str(tmp_path / "srv"), like=template)
    srv2.load_state(tree_r, meta_r)

    assert srv2.clustering.assignment == srv.clustering.assignment
    assert srv2.staleness.snapshot() == srv.staleness.snapshot()
    assert set(srv2.predictors) == set(srv.predictors)
    for cid in srv.predictors:
        assert srv2.predictors[cid].records == srv.predictors[cid].records
    for cid, c in srv.clustering.clusters.items():
        assert_tree_equal(c.center, srv2.clustering.clusters[cid].center)
        assert srv2.clustering.clusters[cid].version == c.version
    # the restored server keeps serving uploads
    out = srv2.handle_upload(0, {"w": jnp.full(6, 0.1)}, 1, 16, t=9.0)
    assert out


def test_kill_during_swap_rolls_back_old_checkpoint(tmp_path, monkeypatch):
    """A crash at the worst possible instant — after the old checkpoint was
    renamed aside but while the staged dir fails to move into place — must
    leave the previous checkpoint restorable under its original name."""
    d = str(tmp_path / "ckpt")
    save_pytree(d, tree(0))

    real_replace = os.replace

    def exploding_replace(src, dst):
        if os.path.basename(src).startswith("tmp.") and not os.path.basename(
            src
        ).startswith("tmp.old."):
            raise OSError("simulated kill at rename")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", exploding_replace)
    with pytest.raises(OSError, match="simulated kill"):
        save_pytree(d, tree(1))
    monkeypatch.undo()

    got, _ = restore_pytree(d, like=tree())
    assert_tree_equal(tree(0), got)  # old checkpoint rolled back intact
    assert not [n for n in os.listdir(tmp_path) if n.startswith("tmp.")]


def test_kill_during_staging_leaves_no_visible_step(tmp_path, monkeypatch):
    """A crash while the payload is still being staged never creates the
    target directory at all — a fresh save sees no checkpoint, not a
    half-written one."""
    d = str(tmp_path / "ckpt")

    def exploding_savez(f, **kw):
        f.write(b"partial")
        raise OSError("simulated kill mid-write")

    monkeypatch.setattr(np, "savez", exploding_savez)
    with pytest.raises(OSError, match="mid-write"):
        save_pytree(d, tree(0))
    monkeypatch.undo()
    assert not os.path.exists(d)
    assert not [n for n in os.listdir(tmp_path) if n.startswith("tmp.")]
    save_pytree(d, tree(1))  # recovery: a clean save just works
    got, _ = restore_pytree(d, like=tree())
    assert_tree_equal(tree(1), got)


def test_latest_step_ignores_manifestless_dirs(tmp_path):
    """``latest_step`` only accepts step dirs whose manifest made it to
    disk — a dir with leaves but no manifest (the pre-atomic failure
    shape) is invisible to restart."""
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, tree(0))
    ck.close()
    fake = tmp_path / "step_0000000002"
    fake.mkdir()
    (fake / "leaves.npz").write_bytes(b"truncated garbage")
    assert latest_step(str(tmp_path)) == 1
    got, _ = restore_pytree(str(tmp_path / "step_0000000001"), like=tree())
    assert_tree_equal(tree(0), got)
