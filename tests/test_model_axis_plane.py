"""Model-axis (R×M mesh) plane compute: dim-sharded kernel parity and the
full-server trajectory harness.

PR 2 sharded the plane's *storage* over an optional ``model`` axis but
replicated kernel compute over it; these tests pin the true model-axis
compute path: per-shard partial L1 sums psum into full distances, the
assign blend runs elementwise per dim chunk (bitwise), and the chi2
kernels recruit the model axis for row-parallelism (per-row bitwise).

The in-process tests need an even device count >= 4 (the ci.sh
multi-device legs); the subprocess trajectory test always runs — it forces
an 8-device host in a child interpreter, builds a 4x2 ``(plane, model)``
mesh, and asserts the EchoPFL server's decisions are identical and its
centers bitwise-equal to the single-device run.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

even_multi_device = pytest.mark.skipif(
    len(jax.devices()) < 4 or len(jax.devices()) % 2,
    reason="needs an even device count >= 4 (ci.sh multi-device legs)",
)


@pytest.fixture(scope="module")
def mesh_rm():
    if len(jax.devices()) < 4 or len(jax.devices()) % 2:
        pytest.skip("needs an even device count >= 4")
    from repro.launch.mesh import make_plane_mesh

    return make_plane_mesh(len(jax.devices()) // 2, dim_shards=2)


def test_dim_shards_dispatch_rules():
    """The engagement rule is shared with the plane's storage rule: the
    model axis must exist, exceed one shard, and divide the flat dim."""
    if len(jax.devices()) < 4 or len(jax.devices()) % 2:
        pytest.skip("needs an even device count >= 4")
    from repro.launch.mesh import make_plane_mesh

    m = make_plane_mesh(len(jax.devices()) // 2, dim_shards=2)
    assert ops._dim_shards(m, "model", 300) == 2
    assert ops._dim_shards(m, "model", 301) == 1  # indivisible -> replicate
    assert ops._dim_shards(m, None, 300) == 1
    assert ops._dim_shards(None, "model", 300) == 1
    r_only = make_plane_mesh(len(jax.devices()))
    assert ops._dim_shards(r_only, "model", 300) == 1  # no model axis


def test_model_compute_knob(monkeypatch):
    monkeypatch.setenv("REPRO_PLANE_MODEL_COMPUTE", "off")
    assert not ops._model_compute_on()
    monkeypatch.setenv("REPRO_PLANE_MODEL_COMPUTE", "on")
    assert ops._model_compute_on()
    monkeypatch.delenv("REPRO_PLANE_MODEL_COMPUTE")
    assert ops._model_compute_on()  # default on


@even_multi_device
class TestModelAxisOps:
    def test_l1_pairwise_dim_sharded_matches_single_device(self, mesh_rm):
        xs = jax.random.normal(jax.random.PRNGKey(0), (11, 300))
        cs = jax.random.normal(jax.random.PRNGKey(1), (5, 300))
        got = np.asarray(ops.l1_distance_pairwise(xs, cs, mesh=mesh_rm))
        want = np.asarray(ops.l1_distance_pairwise(xs, cs))
        # partial chunk sums psum: last-ulp, never decision-flipping here
        np.testing.assert_allclose(got, want, rtol=1e-6)
        np.testing.assert_array_equal(got.argmin(axis=1), want.argmin(axis=1))
        np.testing.assert_allclose(
            got, np.asarray(ref.l1_distance_pairwise_ref(xs, cs)), rtol=1e-5
        )

    def test_l1_pairwise_indivisible_dim_falls_back_bitwise(self, mesh_rm):
        # 301 is not divisible by the 2-way model axis: the dispatch must
        # replicate over it (the PR-2 path), whose per-row sums are bitwise
        xs = jax.random.normal(jax.random.PRNGKey(2), (9, 301))
        cs = jax.random.normal(jax.random.PRNGKey(3), (4, 301))
        got = np.asarray(ops.l1_distance_pairwise(xs, cs, mesh=mesh_rm))
        np.testing.assert_array_equal(got, np.asarray(ops.l1_distance_pairwise(xs, cs)))

    def test_l1_pairwise_knob_off_restores_replicated_compute(self, mesh_rm, monkeypatch):
        monkeypatch.setenv("REPRO_PLANE_MODEL_COMPUTE", "off")
        xs = jax.random.normal(jax.random.PRNGKey(4), (11, 300))
        cs = jax.random.normal(jax.random.PRNGKey(5), (5, 300))
        got = np.asarray(ops.l1_distance_pairwise(xs, cs, mesh=mesh_rm))
        np.testing.assert_array_equal(got, np.asarray(ops.l1_distance_pairwise(xs, cs)))

    @pytest.mark.parametrize("c", [1, 3, 8, 11])
    def test_assign_and_lerp_blend_bitwise_dists_last_ulp(self, mesh_rm, c):
        u = jax.random.normal(jax.random.PRNGKey(c), (300,))
        cs = jax.random.normal(jax.random.PRNGKey(c + 100), (c, 300))
        d, i, b = ops.assign_and_lerp(u, cs, 0.25, mesh=mesh_rm)
        ds, is_, bs = ops.assign_and_lerp(u, cs, 0.25)
        assert int(i) == int(is_)
        # the blend is elementwise per dim chunk: bitwise, not just close
        np.testing.assert_array_equal(np.asarray(b), np.asarray(bs))
        np.testing.assert_allclose(np.asarray(d), np.asarray(ds), rtol=1e-6)

    def test_assign_and_lerp_padded_rows_never_win(self, mesh_rm):
        u = jnp.full((256,), 1e-3)
        cs = jnp.stack([jnp.full((256,), 50.0), jnp.full((256,), -40.0), jnp.full((256,), 30.0)])
        d, i, b = ops.assign_and_lerp(u, cs, 0.5, mesh=mesh_rm)
        assert int(i) == 2  # 30.0 is nearest; no padding row may win
        assert np.all(np.isfinite(np.asarray(d)))

    def test_chi2_rows_spread_over_both_axes_bitwise(self, mesh_rm):
        for m in (3, 11, 16):
            f_pred = jax.random.uniform(jax.random.PRNGKey(m), (m, 6)) * 100
            f_true = jax.random.uniform(jax.random.PRNGKey(m + 1), (m, 6)) * 100 + 1.0
            s_soft = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(m + 2), (m, 6)), axis=-1)
            got = np.asarray(ops.chi2_feedback(f_pred, f_true, s_soft, mesh=mesh_rm))
            want = np.asarray(ops.chi2_feedback(f_pred, f_true, s_soft))
            assert got.shape == (m,)
            np.testing.assert_array_equal(got, want)

    def test_chi2_all_g_bitwise_seg_psums_both_axes(self, mesh_rm):
        sizes = [2, 1, 9, 4]
        m, s = sum(sizes), len(sizes)
        f_pred = jax.random.uniform(jax.random.PRNGKey(7), (m, 6)) * 100
        f_true = jax.random.uniform(jax.random.PRNGKey(8), (m, 6)) * 100 + 1.0
        s_soft = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(9), (m, 6)), axis=-1)
        seg_ids = jnp.asarray(np.repeat(np.arange(s), sizes), np.int32)
        g, seg = ops.chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments=s, mesh=mesh_rm)
        g1, seg1 = ops.chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments=s)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g1))
        np.testing.assert_allclose(np.asarray(seg), np.asarray(seg1), rtol=1e-5, atol=1e-6)

    def test_plane_rows_feed_dim_sharded_pairwise_without_gathering(self, mesh_rm):
        """End to end: rows taken off a dim-sharded plane pass straight into
        the dim-sharded pairwise launch (dispatch passes both operand
        layouts through) and score within fp tolerance."""
        from repro.core.plane import ParameterPlane

        template = {"w": jnp.zeros((300,), jnp.float32)}
        plane = ParameterPlane(template, capacity=16, mesh=mesh_rm)
        assert plane._sharding.spec[1] == "model"  # storage dim-sharded
        rows = [
            plane.alloc(jnp.asarray(np.random.default_rng(i).standard_normal(plane.dim), jnp.float32))
            for i in range(8)
        ]
        centers = jnp.asarray(
            np.random.default_rng(99).standard_normal((3, plane.dim)), jnp.float32
        )
        U_shard = plane.rows(tuple(rows), on_mesh="shard")
        got = np.asarray(ops.l1_distance_pairwise(U_shard, centers, mesh=mesh_rm))
        want = np.asarray(ops.l1_distance_pairwise(plane.rows(tuple(rows)), centers))
        np.testing.assert_allclose(got, want, rtol=1e-6)


# ------------------------------------------------ forced-8-device R×M parity
_RM_PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.pop("REPRO_PLANE_MESH", None)
    os.environ.pop("REPRO_PLANE_MODEL_COMPUTE", None)  # default: compute shards
    os.environ["REPRO_PLANE_MESH_MIN_ROWS"] = "0"  # force sharded compute
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.server import EchoPFLServer
    from repro.kernels import ops
    from repro.launch.mesh import make_plane_mesh

    assert len(jax.devices()) == 8

    def vec(x):
        return {"w": jnp.full((24,), float(x))}  # 24 % 2 == 0: dim shards

    def feedback_fn(client_id, center):
        err = 80.0 if client_id in ("c4", "c5") else 1.0
        f_pred = np.asarray([50.0 + err, 50.0 - err, 1.0])
        f_true = np.asarray([50.0, 50.0, 1.0])
        s_soft = np.asarray([0.9, 0.08, 0.02])
        return f_pred, f_true, s_soft

    def run(mesh):
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=1, refine_every=8,
                            feedback_fn=feedback_fn, local_train_fn=lambda p: p,
                            plane_backend="plane", plane_mesh=mesh, seed=0)
        for i in range(40):
            srv.handle_upload(f"c{i % 6}", vec(40.0 * (i % 2) + 0.01 * i), 0, 8,
                              t=float(i))
        return srv

    mesh = make_plane_mesh(4, dim_shards=2)
    assert ops._dim_shards(mesh, "model", 24) == 2  # model compute engages
    single = run(False)  # explicit unsharded, immune to inherited env knobs
    sharded = run(mesh)
    assert single.clustering.plane.mesh is None
    assert sharded.clustering.plane.mesh is not None
    # storage sharded over BOTH axes (rows over plane, dim over model)
    spec = sharded.clustering.plane._sharding.spec
    assert spec[0] == "plane" and spec[1] == "model", spec

    # trajectory identity: every protocol decision matches the 1-device run
    assert sharded.clustering.assignment == single.clustering.assignment
    assert sharded.events == single.events
    ss, sg = sharded.stats(), single.stats()
    for key in ("clusters", "merges", "expansions", "staleness", "broadcasts",
                "rnn_broadcasts", "decisions", "plane_rows"):
        assert ss[key] == sg[key], (key, ss[key], sg[key])
    assert ss["expansions"] > 0  # scenario must exercise refinement
    # centers: decisions identical + elementwise blends -> bitwise equality
    for cid, c in single.clustering.clusters.items():
        a = sharded.clustering.clusters[cid]
        for x, y in zip(jax.tree_util.tree_leaves(a.center),
                        jax.tree_util.tree_leaves(c.center)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print("RM-PARITY-OK")
    """
)


def test_model_axis_server_trajectory_parity_on_forced_8_device_host():
    """Acceptance: an R×M mesh (4 row shards x 2 model shards) whose model
    axis shards both storage AND kernel compute reproduces the
    single-device server trajectory on the same seed — assignments, merges,
    expansions, and broadcast decisions identical, centers bitwise-equal
    (the blend is elementwise per dim chunk). Runs in a subprocess because
    the device count is fixed at jax init."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", _RM_PARITY_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "RM-PARITY-OK" in proc.stdout
