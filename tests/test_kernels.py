"""Per-kernel allclose sweeps: every Pallas kernel (interpret=True on CPU)
against its pure-jnp oracle in ref.py, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chi2_feedback import chi2_feedback
from repro.kernels.flash_attention import flash_attention
from repro.kernels.l1_distance import l1_distance
from repro.kernels.merge_attention import merge_attention

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------- flash attn
FLASH_CASES = [
    # B, H, KV, Sq, Sk, hd, causal, window, softcap
    (1, 4, 2, 128, 128, 64, True, None, None),
    (2, 4, 4, 64, 64, 32, True, None, 50.0),
    (1, 2, 1, 100, 100, 80, True, 32, None),      # GQA 2:1, ragged seq, sliding window
    (1, 2, 2, 64, 192, 128, False, None, None),   # cross/backward-style, non-causal
    (2, 8, 2, 1, 256, 64, True, None, None),      # decode: 1 query vs long KV
    (1, 4, 4, 256, 256, 16, True, None, None),    # tiny head dim
    (1, 16, 2, 32, 32, 64, True, 8, 30.0),        # window + softcap together
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c) for c in FLASH_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, H, KV, Sq, Sk, hd, causal, window, softcap = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, Sq, hd), dtype)
    k = jax.random.normal(kk, (B, KV, Sk, hd), dtype)
    v = jax.random.normal(kv, (B, KV, Sk, hd), dtype)
    q_pos0 = Sk - Sq if causal and Sk > Sq else 0
    got = flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, q_pos0=q_pos0, interpret=True
    )
    want = ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap, q_pos0=q_pos0
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=ATOL[dtype],
    )


def test_flash_attention_rejects_bad_gqa():
    q = jnp.zeros((1, 3, 8, 16))
    k = v = jnp.zeros((1, 2, 8, 16))
    with pytest.raises(Exception):
        flash_attention(q, k, v, interpret=True)


# --------------------------------------------------------------- l1 distance
@pytest.mark.parametrize("n", [1, 100, 1000, 65536, 70000])
@pytest.mark.parametrize("c", [1, 2, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l1_distance_matches_ref(n, c, dtype):
    key = jax.random.PRNGKey(n * 7 + c)
    u = jax.random.normal(key, (n,), dtype)
    centers = jax.random.normal(jax.random.PRNGKey(n + c), (c, n), dtype)
    got = np.asarray(l1_distance(u, centers, interpret=True))
    want = np.asarray(ref.l1_distance_ref(u, centers))
    np.testing.assert_allclose(got, want, rtol=3e-3 if dtype == jnp.bfloat16 else 1e-5)


def test_l1_distance_zero_is_zero():
    u = jnp.ones((4096,))
    centers = jnp.stack([u, -u])
    d = np.asarray(l1_distance(u, centers, interpret=True))
    assert d[0] == 0.0
    assert np.isclose(d[1], 2 * 4096)


# ----------------------------------------------------------- merge attention
@pytest.mark.parametrize("n", [100, 4096, 70000])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_merge_attention_matches_ref(n, dtype):
    key = jax.random.PRNGKey(n)
    k1, k2, k3 = jax.random.split(key, 3)
    vm = jax.random.normal(k1, (n,), dtype)
    va = jax.random.normal(k2, (n,), dtype)
    vt = jax.random.normal(k3, (n,), dtype)
    got = np.asarray(merge_attention(vm, va, vt, interpret=True))
    want, alpha = ref.merge_attention_ref(vm, va, vt)
    np.testing.assert_allclose(got, np.asarray(want), atol=1e-5, rtol=1e-5)
    a = np.asarray(alpha)
    assert (a >= 0).all() and (a <= 1 + 1e-6).all()


def test_merge_attention_algorithm1_semantics():
    """Where assumed & posterior directions agree, alpha>0 pulls toward aux;
    where they disagree, alpha=0 keeps main (Algorithm 1's attention map)."""
    vm = jnp.zeros((4,))
    va = jnp.asarray([1.0, 1.0, -1.0, 2.0])   # assumed directions
    vt = jnp.asarray([1.0, -1.0, 1.0, 2.0])   # posterior: agree, disagree, disagree, agree(max)
    merged, alpha = ref.merge_attention_ref(vm, va, vt)
    a = np.asarray(alpha)
    assert a[1] == 0.0 and a[2] == 0.0          # sign disagreement -> keep main
    assert np.isclose(a[3], 1.0)                # strongest agreement -> full aux
    m = np.asarray(merged)
    assert m[1] == 0.0 and m[2] == 0.0
    assert np.isclose(m[3], 2.0)


# ------------------------------------------------------------- chi2 feedback
@pytest.mark.parametrize("m,j", [(1, 10), (7, 6), (300, 9), (64, 2), (5, 200)])
def test_chi2_feedback_matches_ref(m, j):
    key = jax.random.PRNGKey(m * 31 + j)
    f_pred = jax.random.uniform(key, (m, j)) * 100
    f_true = jax.random.uniform(jax.random.PRNGKey(j), (m, j)) * 100 + 1.0
    s_soft = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(m), (m, j)), axis=-1)
    got = np.asarray(chi2_feedback(f_pred, f_true, s_soft, interpret=True))
    want = np.asarray(ref.chi2_feedback_ref(f_pred, f_true, s_soft))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_chi2_feedback_perfect_fit_is_zero():
    f = jnp.asarray([[10.0, 20.0, 30.0]])
    s = jnp.asarray([[0.2, 0.3, 0.5]])
    g = np.asarray(chi2_feedback(f, f, s, interpret=True))
    assert np.allclose(g, 0.0)


def test_chi2_feedback_uniform_soft_labels_damp():
    """Var(S) de-confounds training stage (Eq. 3): an untrained model
    (uniform soft labels) produces near-zero feedback even when the
    predicted histogram mismatches."""
    f_pred = jnp.asarray([[100.0, 0.0, 0.0]])
    f_true = jnp.asarray([[1.0, 50.0, 49.0]])
    s_uniform = jnp.full((1, 3), 1 / 3)
    s_sharp = jnp.asarray([[0.98, 0.01, 0.01]])
    g_u = float(chi2_feedback(f_pred, f_true, s_uniform, interpret=True)[0])
    g_s = float(chi2_feedback(f_pred, f_true, s_sharp, interpret=True)[0])
    assert g_u < 1e-6
    assert g_s > g_u
