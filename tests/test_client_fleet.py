"""The vectorized client fleet (REPRO_CLIENT=fleet): loop-vs-fleet parity
of both simulator loops, masked-padding correctness for ragged client
datasets, head-only/heterogeneous-epoch masking equivalence, and the fleet
engine's plane-backed state handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tasks import MLPTaskConfig
from repro.core.client import SimClient
from repro.data.synthetic import ClientDataset
from repro.fl.experiment import build_clients, build_strategy, run_experiment
from repro.fl.fleet import ClientFleet
from repro.fl.simulator import Simulator
from repro.models import mlp

CFG = MLPTaskConfig("tiny", input_dim=12, hidden=(10,), num_classes=4)


def _ragged_clients(rng, sizes=(7, 12, 5, 12)):
    """SimClients with deliberately unequal train/test set sizes."""
    clients = []
    for i, n in enumerate(sizes):
        x = rng.normal(size=(n, CFG.input_dim)).astype(np.float32)
        y = rng.integers(0, CFG.num_classes, size=n).astype(np.int32)
        nt = max(2, n // 3)
        xt = rng.normal(size=(nt, CFG.input_dim)).astype(np.float32)
        yt = rng.integers(0, CFG.num_classes, size=nt).astype(np.int32)
        data = ClientDataset(x_train=x, y_train=y, x_test=xt, y_test=yt, latent_cluster=0)
        clients.append(
            SimClient(
                client_id=i, data=data, num_classes=CFG.num_classes,
                device_class="D1", round_time_fn=lambda: 1.0,
                local_epochs=3 + i % 3, lr=0.05 * (1 + i),
            )
        )
    return clients


@pytest.fixture
def params(rng):
    return mlp.init_mlp(CFG, jax.random.PRNGKey(11))


# -------------------------------------------------- masked batched variants
class TestMaskedBatchedVariants:
    def test_ragged_training_matches_per_client_path(self, rng, params):
        """fleet_local_train on zero-padded rows with validity masks must
        reproduce each client's unpadded local_train — including per-row
        lr and heterogeneous epoch budgets."""
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        trained, _ = fleet.train_cohort([c.client_id for c in clients], [params] * len(clients))
        for c, got in zip(clients, trained):
            want, _ = mlp.local_train(
                params, jnp.asarray(c.data.x_train), jnp.asarray(c.data.y_train),
                epochs=c.local_epochs, lr=c.lr,
            )
            for a, b in zip(jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(got)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    def test_head_only_masking_equivalence(self, rng, params):
        """A head_only row in the batch must match _sgd_epoch(head_only=True):
        body layers frozen bit-exactly, head layer trained."""
        clients = _ragged_clients(rng)
        clients[1].partial_finetune = True
        fleet = ClientFleet(clients, params)
        trained, _ = fleet.train_cohort([c.client_id for c in clients], [params] * len(clients))
        c = clients[1]
        want, _ = mlp.local_train(
            params, jnp.asarray(c.data.x_train), jnp.asarray(c.data.y_train),
            epochs=c.local_epochs, lr=c.lr, head_only=True,
        )
        got = trained[1]
        for a, b in zip(jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        # body layers untouched (exact zero gradient selection)
        for a, b in zip(jax.tree_util.tree_leaves(params[:-1]), jax.tree_util.tree_leaves(got[:-1])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_head_only_body_stays_frozen_under_nonfinite_grads(self, rng, params):
        """Gradient masking is a select, not a multiply: even when training
        diverges (inf/nan gradients), frozen body params must stay bit-equal
        — g * 0.0 would leak NaN."""
        clients = _ragged_clients(rng)
        c = clients[1]
        c.partial_finetune = True
        c.lr = 1e30  # diverges within an epoch or two
        fleet = ClientFleet(clients, params)
        trained, _ = fleet.train_cohort([c.client_id], [params])
        for a, b in zip(jax.tree_util.tree_leaves(params[:-1]),
                        jax.tree_util.tree_leaves(trained[0][:-1])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fleet_evaluate_masks_padding(self, rng, params):
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        accs = fleet.evaluate_fleet([params] * len(clients))
        for c, got in zip(clients, accs):
            want = float(mlp.evaluate(params, jnp.asarray(c.data.x_test), jnp.asarray(c.data.y_test)))
            np.testing.assert_allclose(got, want, atol=1e-6)

    def test_fleet_feedback_matches_per_client_probe(self, rng, params):
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        pairs = [(c.client_id, params) for c in clients] + [(clients[0].client_id, params)]
        f_pred, f_true, s_soft = fleet.feedback_many(pairs)
        assert f_pred.shape == (len(pairs), CFG.num_classes)
        for k, (cid, center) in enumerate(pairs):
            c = clients[cid]
            fp, ft, ss = c.feedback_inputs(center)
            np.testing.assert_array_equal(f_pred[k], fp)  # integer histograms: exact
            np.testing.assert_array_equal(f_true[k], ft)
            np.testing.assert_allclose(s_soft[k], ss, rtol=1e-6, atol=1e-7)

    def test_zero_epoch_rows_are_noops(self, rng, params):
        clients = _ragged_clients(rng)
        clients[2].local_epochs = 0
        fleet = ClientFleet(clients, params)
        trained, losses = fleet.train_cohort([c.client_id for c in clients], [params] * len(clients))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(trained[2])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert losses[2] == 0.0  # matches local_train's epochs=0 loss


# --------------------------------------------------------- fleet engine state
class TestFleetEngine:
    def test_train_client_row_sliced_path_matches_cohort(self, rng, params):
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        for c in clients:
            fleet.set_model(c.client_id, params)
        tree, loss = fleet.train_client(clients[0].client_id)
        want, want_loss = mlp.local_train(
            params, jnp.asarray(clients[0].data.x_train), jnp.asarray(clients[0].data.y_train),
            epochs=clients[0].local_epochs, lr=clients[0].lr,
        )
        for a, b in zip(jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(tree)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-5)
        # the model row advanced: training again continues from the new row
        np.testing.assert_allclose(
            np.asarray(fleet.model_vec(clients[0].client_id)),
            np.asarray(fleet.spec.flatten(tree)), rtol=1e-6,
        )

    def test_train_cohort_none_params_fall_back_to_model_row(self, rng, params):
        """model_for -> None means 'train from the client's own model', the
        same contract SimClient.local_train(None) honors."""
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        c = clients[0]
        start, _ = mlp.local_train(
            params, jnp.asarray(c.data.x_train), jnp.asarray(c.data.y_train),
            epochs=c.local_epochs, lr=c.lr,
        )
        fleet.set_model(c.client_id, start)
        trained, _ = fleet.train_cohort([c.client_id], [None])
        want, _ = mlp.local_train(
            start, jnp.asarray(c.data.x_train), jnp.asarray(c.data.y_train),
            epochs=c.local_epochs, lr=c.lr,
        )
        for a, b in zip(jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(trained[0])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    def test_train_from_unset_model_raises(self, rng, params):
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        with pytest.raises(ValueError):
            fleet.train_client(clients[0].client_id)
        with pytest.raises(ValueError):
            fleet.train_cohort([clients[0].client_id], [None])
        with pytest.raises(ValueError):
            fleet.train_rows([clients[0].client_id])

    def test_train_rows_matches_sequential_train_client(self, rng, params):
        """The coalesced async path's batched row-sliced launch: N clients
        training from their own model rows in one launch must equal N
        train_client calls — results, row write-back, version bumps."""
        clients = _ragged_clients(rng)
        ids = [c.client_id for c in clients]
        batched = ClientFleet(clients, params)
        seq = ClientFleet(clients, params)
        for f in (batched, seq):
            for c in clients:
                f.set_model(c.client_id, params)
        trees_b, losses_b = batched.train_rows(ids)
        for cid, tree_b, loss_b in zip(ids, trees_b, losses_b):
            tree_s, loss_s = seq.train_client(cid)
            for a, b in zip(jax.tree_util.tree_leaves(tree_s), jax.tree_util.tree_leaves(tree_b)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(float(loss_b), float(loss_s), rtol=1e-5)
            np.testing.assert_allclose(
                np.asarray(batched.model_vec(cid)), np.asarray(seq.model_vec(cid)),
                rtol=1e-6, atol=1e-7,
            )
        # rows advanced: a second batch continues from the trained rows
        trees_b2, _ = batched.train_rows(ids[:2])
        tree_s2, _ = seq.train_client(ids[0])
        for a, b in zip(jax.tree_util.tree_leaves(tree_s2), jax.tree_util.tree_leaves(trees_b2[0])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    def test_set_models_batches_with_last_write_wins(self, rng, params):
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        other = jax.tree_util.tree_map(lambda x: x + 1.0, params)
        third = jax.tree_util.tree_map(lambda x: x * 0.5, params)
        # duplicate client 0: the LAST write must win, like sequential sets
        fleet.set_models(
            [clients[0].client_id, clients[1].client_id, clients[0].client_id],
            [params, other, third],
        )
        np.testing.assert_allclose(
            np.asarray(fleet.model_vec(clients[0].client_id)),
            np.asarray(fleet.spec.flatten(third)), rtol=1e-7,
        )
        np.testing.assert_allclose(
            np.asarray(fleet.model_vec(clients[1].client_id)),
            np.asarray(fleet.spec.flatten(other)), rtol=1e-7,
        )

    def test_dataset_replacement_is_picked_up(self, rng, params):
        """Distribution drift (Fig. 18): replacing a SimClient's dataset
        mid-run must be reflected by the next fleet launch, like the loop
        backend's live reads."""
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        fleet.evaluate_fleet([params] * len(clients))
        c = clients[0]
        n = len(c.data.y_test) + 3  # also changes the padded width
        rng2 = np.random.default_rng(99)
        c.data = ClientDataset(
            x_train=c.data.x_train, y_train=c.data.y_train,
            x_test=rng2.normal(size=(n, CFG.input_dim)).astype(np.float32),
            y_test=rng2.integers(0, CFG.num_classes, size=n).astype(np.int32),
            latent_cluster=0,
        )
        accs = fleet.evaluate_fleet([params] * len(clients))
        want = float(mlp.evaluate(params, jnp.asarray(c.data.x_test), jnp.asarray(c.data.y_test)))
        np.testing.assert_allclose(accs[0], want, atol=1e-6)

    def test_eval_rows_identity_cached(self, rng, params):
        """Re-evaluating with the same center object must not rewrite eval
        rows (the per-tick gather is the plane's patched cached view)."""
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        fleet.evaluate_fleet([params] * len(clients))
        staged_before = len(fleet.plane._dirty) + len(fleet.plane._bulk)
        fleet.evaluate_fleet([params] * len(clients))
        assert len(fleet.plane._dirty) + len(fleet.plane._bulk) == staged_before == 0

    def test_unset_model_and_none_params_evaluates_to_zero(self, rng, params):
        clients = _ragged_clients(rng)
        fleet = ClientFleet(clients, params)
        fleet.set_model(clients[0].client_id, params)
        accs = fleet.evaluate_fleet([None] * len(clients))
        assert accs[1] == 0.0 and accs[2] == 0.0  # no model ever set
        want = float(mlp.evaluate(params, jnp.asarray(clients[0].data.x_test),
                                  jnp.asarray(clients[0].data.y_test)))
        np.testing.assert_allclose(accs[0], want, atol=1e-6)
        # a second tick with an unchanged model row stages no copies (the
        # model-row mirror is version-tagged), and a model write re-stages
        fleet.evaluate_fleet([None] * len(clients))
        assert not fleet.plane._dirty and not fleet.plane._bulk
        fleet.set_model(clients[0].client_id, params)
        fleet.evaluate_fleet([None] * len(clients))
        accs2 = fleet.evaluate_fleet([None] * len(clients))
        np.testing.assert_allclose(accs2[0], want, atol=1e-6)


# -------------------------------------------------------------- fleet mesh
class TestFleetMesh:
    def test_env_knob_parsing(self, monkeypatch):
        from repro.launch.mesh import fleet_mesh_from_env

        monkeypatch.setenv("REPRO_FLEET_MESH", "off")
        assert fleet_mesh_from_env() is None
        monkeypatch.delenv("REPRO_FLEET_MESH")
        assert fleet_mesh_from_env() is None
        monkeypatch.setenv("REPRO_FLEET_MESH", "1")
        m = fleet_mesh_from_env()
        assert m is not None and m.shape["plane"] == 1

    def test_meshed_fleet_matches_single_device(self, rng, params):
        """With a fleet mesh, the client-model plane and the (clients, n,
        dim) data tensors shard over the 'plane' axis; every launch's
        per-client arithmetic is unchanged, so training, eval, and feedback
        match the single-device fleet."""
        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices (ci.sh multi-device leg)")
        from repro.launch.mesh import make_plane_mesh

        clients = _ragged_clients(rng)  # 4 clients: 2 row shards divide them
        ids = [c.client_id for c in clients]
        single = ClientFleet(clients, params, mesh=False)
        meshed = ClientFleet(clients, params, mesh=make_plane_mesh(2))
        assert meshed.x_train.sharding.spec[0] == "plane"
        # a fleet that does not divide the row shards falls back unsharded
        if len(jax.devices()) >= 8:
            assert ClientFleet(clients, params, mesh=make_plane_mesh(8)).mesh is None
        ta, la = single.train_cohort(ids, [params] * len(ids))
        tb, lb = meshed.train_cohort(ids, [params] * len(ids))
        for a, b in zip(ta, tb):
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)
        for f in (single, meshed):
            for c in clients:
                f.set_model(c.client_id, params)
        np.testing.assert_allclose(
            single.evaluate_fleet([None] * len(ids)), meshed.evaluate_fleet([None] * len(ids)),
            atol=1e-6,
        )
        pairs = [(cid, params) for cid in ids]
        fa = single.feedback_many(pairs)
        fb = meshed.feedback_many(pairs)
        for x, y in zip(fa, fb):
            np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)
        ra, _ = single.train_rows(ids[:3])
        rb, _ = meshed.train_rows(ids[:3])
        for a, b in zip(ra, rb):
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------ simulator-level parity
def _match_reports(r1, r2, atol=5e-6):
    # virtual-time trajectory and byte accounting must be *exact*
    assert (r1.up_bytes, r1.down_bytes, r1.up_events, r1.down_events) == (
        r2.up_bytes, r2.down_bytes, r2.up_events, r2.down_events
    )
    assert [t for t, _ in r1.curve] == [t for t, _ in r2.curve]
    np.testing.assert_allclose(
        [a for _, a in r1.curve], [a for _, a in r2.curve], atol=atol
    )
    assert set(r1.per_client_acc) == set(r2.per_client_acc)
    for cid in r1.per_client_acc:
        np.testing.assert_allclose(r1.per_client_acc[cid], r2.per_client_acc[cid], atol=atol)
    assert r1.duration == r2.duration


class TestLoopFleetParity:
    def test_run_sync_parity(self):
        reports = {
            backend: run_experiment(
                "har", "fedavg", num_clients=6, seed=3, rounds=3,
                client_backend=backend, samples_per_client=48,
            )[3]
            for backend in ("loop", "fleet")
        }
        _match_reports(reports["loop"], reports["fleet"])
        assert reports["loop"].extra["rounds"] == reports["fleet"].extra["rounds"] == 3

    def test_run_async_parity_echopfl(self):
        """The event-driven trajectory — upload ordering, cluster decisions,
        broadcasts, refinement — must be unchanged when single-client
        training routes through the fleet's row-sliced path and eval ticks
        and feedback probes batch."""
        reports = {}
        extras = {}
        for backend in ("loop", "fleet"):
            r = run_experiment(
                "har", "echopfl", num_clients=6, seed=3, max_time=420,
                client_backend=backend, samples_per_client=48,
            )[3]
            reports[backend] = r
            extras[backend] = r.extra
        _match_reports(reports["loop"], reports["fleet"])
        for key in ("uploads", "clusters", "merges", "expansions", "broadcasts"):
            assert extras["loop"][key] == extras["fleet"][key], key

    def test_stale_fleet_hook_replaced_or_cleared_on_strategy_reuse(self):
        """A strategy reused across simulators must never keep probing a
        previous simulator's dead fleet: a new fleet rebinds the hook, a
        loop-backend run clears it (falling back to feedback_fn)."""
        task, clients, init = build_clients("har", 4, seed=0, samples_per_client=16)
        strat = build_strategy("echopfl", init, clients, seed=0)
        sim_a = Simulator(clients, strat, client_backend="fleet", seed=0)
        sim_a._ensure_fleet(init)
        hook_a = strat.feedback_batch_fn
        assert getattr(hook_a, "_fleet_hook", False)
        sim_b = Simulator(clients, strat, client_backend="fleet", seed=0)
        sim_b._ensure_fleet(init)
        assert strat.feedback_batch_fn is not hook_a  # rebound to B's fleet
        sim_c = Simulator(clients, strat, client_backend="loop", seed=0)
        sim_c._ensure_fleet(init)
        assert strat.feedback_batch_fn is None
        # re-running an existing fleet simulator reclaims the hook for its
        # OWN fleet (after another simulator cleared or rebound it)
        sim_a._ensure_fleet(init)
        assert strat.feedback_batch_fn._fleet is sim_a._fleet
        sim_b._ensure_fleet(init)
        assert strat.feedback_batch_fn._fleet is sim_b._fleet

    def test_invalid_backend_rejected(self):
        task, clients, init = build_clients("har", 2, seed=0, samples_per_client=16)
        strat = build_strategy("fedavg", init, clients, seed=0)
        with pytest.raises(ValueError):
            Simulator(clients, strat, client_backend="warp")
