"""Per-arch reduced-config smoke tests + decode/forward consistency.

Every assigned architecture instantiates a small same-family config and runs
one train step (finite loss, right shapes) and, for decoder archs, verifies
that incremental decode through the fixed-size cache reproduces the full
forward pass logits token-for-token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY
from repro.configs.base import reduced_config
from repro.models import init_cache, init_params, make_serve_step, make_train_step
from repro.models.model import forward
from repro.models.steps import TrainState, make_eval_step, make_optimizer, make_prefill_step

ARCHS = sorted(ARCH_REGISTRY)


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embeds_input:
        return {
            "embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        }
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(ARCH_REGISTRY[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = make_optimizer(cfg)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(state.params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_over_steps(arch):
    cfg = reduced_config(ARCH_REGISTRY[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=4, S=16)
    opt = make_optimizer(cfg)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", [a for a in ARCHS if not ARCH_REGISTRY[a].is_encoder])
def test_decode_matches_full_forward(arch):
    """Prefill + token-by-token decode == one full forward (cache coherence).

    MoE archs run in dropless mode: capacity-factor dispatch intentionally
    depends on the token-group shape, so only dropless routing can be
    bit-consistent between full-sequence and single-token execution."""
    import dataclasses

    cfg = reduced_config(ARCH_REGISTRY[arch])
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe_dropless=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 12
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    full_logits, _, _ = forward(cfg, params, {"tokens": toks})

    ctx = 4
    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))
    last, cache = prefill(params, {"tokens": toks[:, :ctx]})
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full_logits[:, ctx - 1]), atol=2e-3, rtol=2e-3
    )
    # pad prefill cache buffers out to full length margin
    grown = init_cache(cfg, B, ctx_len=ctx, margin=S - ctx)
    def graft(dst, src):
        if dst.ndim >= 2 and dst.shape[:1] == src.shape[:1] and dst.dtype == src.dtype:
            pass
        return dst
    # write prefill buffers into the fixed-size cache
    def copy_into(fixed, pre):
        def one(f, p):
            if f.shape == p.shape:
                return p
            # time axis is the one that differs; left-align
            axis = next(i for i, (a, b) in enumerate(zip(f.shape, p.shape)) if a != b)
            pad = [(0, 0)] * f.ndim
            pad[axis] = (0, f.shape[axis] - p.shape[axis])
            return jnp.pad(p, pad)
        return jax.tree_util.tree_map(one, fixed, pre)

    cache = copy_into(grown, cache)
    for t in range(ctx, S):
        logits, cache = serve(params, cache, {"tokens": toks[:, t : t + 1]})
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(full_logits[:, t]),
            atol=5e-3, rtol=5e-3,
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_eval_step(arch):
    cfg = reduced_config(ARCH_REGISTRY[arch])
    params = init_params(cfg, jax.random.PRNGKey(0))
    out = jax.jit(make_eval_step(cfg))(params, _batch(cfg))
    assert 0.0 <= float(out["accuracy"]) <= 1.0
    assert np.isfinite(float(out["ce"]))


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation is semantics-preserving: 4 microbatches give the
    same step as one full batch (the §Perf memory lever must be exact)."""
    import dataclasses

    cfg = reduced_config(ARCH_REGISTRY["llama3.2-1b"])
    cfg1 = dataclasses.replace(cfg, train=dataclasses.replace(cfg.train, microbatches=1))
    cfg4 = dataclasses.replace(cfg, train=dataclasses.replace(cfg.train, microbatches=4))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=8, S=16)
    opt = make_optimizer(cfg)
    s0 = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    s1, m1 = jax.jit(make_train_step(cfg1))(s0, batch)
    s4, m4 = jax.jit(make_train_step(cfg4))(s0, batch)
    assert np.isclose(float(m1["ce"]), float(m4["ce"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params), jax.tree_util.tree_leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=1e-4)


def test_moe_routes_to_topk_experts():
    cfg = reduced_config(ARCH_REGISTRY["granite-moe-3b-a800m"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    logits, aux, _ = forward(cfg, params, _batch(cfg))
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) >= 0.0  # load-balance loss is defined and finite
