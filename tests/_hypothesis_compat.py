"""Hypothesis with a fixed-example fallback.

The property tests prefer real `hypothesis` when it is installed (see
requirements-dev.txt). In hermetic environments without it, this module
provides a deterministic stand-in: each `@given(...)` test runs against a
fixed number of seeded pseudo-random examples instead of a shrinking
search. The strategy surface is only what the suite actually uses —
integers / floats / lists / tuples / composite / .map — all drawing from
`numpy.random.default_rng` with a seed derived from the test name, so
failures reproduce exactly across runs.
"""
from __future__ import annotations

try:  # real hypothesis when available
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fixed-example shim
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False
    _EXAMPLES = 10  # fixed examples per @given test

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example(self, rng) -> object:
            return self._draw_fn(rng)

        def map(self, fn) -> "_Strategy":
            return _Strategy(lambda rng: fn(self._draw_fn(rng)))

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1) -> _Strategy:
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw) -> _Strategy:
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements: _Strategy, min_size=0, max_size=10) -> _Strategy:
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*parts: _Strategy) -> _Strategy:
            return _Strategy(lambda rng: tuple(p.example(rng) for p in parts))

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def draw_value(rng):
                    draw = lambda strategy: strategy.example(rng)
                    return fn(draw, *args, **kwargs)

                return _Strategy(draw_value)

            return build

    st = _St()

    def given(*strategies):
        def decorator(fn):
            def wrapper(*args, **kwargs):
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(_EXAMPLES):
                    rng = np.random.default_rng((base + i * 7919) % 2**32)
                    values = [s.example(rng) for s in strategies]
                    fn(*args, *values, **kwargs)

            # NOT functools.wraps: copying __wrapped__ would let pytest see
            # the original signature and demand the @given args as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorator

    class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
