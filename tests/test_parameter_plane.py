"""The device-resident parameter plane: adapters, allocation, and parity of
the plane-backed clustering path against the original pytree path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.pytrees import flatten_spec, tree_flat_vector, tree_num_params
from repro.core.clustering import DynamicClustering
from repro.core.plane import ParameterPlane


def leaves_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------ adapters
class TestAdapters:
    def test_roundtrip_to_from_pytree(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=4)
        row = plane.alloc(tiny_params)
        back = plane.to_pytree(row)
        assert jax.tree_util.tree_structure(back) == jax.tree_util.tree_structure(tiny_params)
        leaves_equal(back, tiny_params)

    def test_from_pytree_matches_tree_flat_vector(self, tiny_params):
        plane = ParameterPlane(tiny_params)
        np.testing.assert_array_equal(
            np.asarray(plane.from_pytree(tiny_params)),
            np.asarray(tree_flat_vector(tiny_params)),
        )

    def test_dim_is_param_count(self, tiny_params):
        plane = ParameterPlane(tiny_params)
        assert plane.dim == tree_num_params(tiny_params)

    def test_flatten_spec_is_memoized(self, tiny_params):
        assert flatten_spec(tiny_params) is flatten_spec(tiny_params)


# ---------------------------------------------------------------- allocation
class TestAllocation:
    def test_free_then_realloc_reuses_row_zeroed(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=2)
        row = plane.alloc(tiny_params)
        plane.flush()  # old bytes land in the buffer
        plane.free(row)
        again = plane.alloc()
        assert again == row  # LIFO free list reuses the row
        np.testing.assert_array_equal(np.asarray(plane.row(again)), 0.0)

    def test_grow_preserves_rows(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=1)
        r0 = plane.alloc(tiny_params)
        r1 = plane.alloc()  # forces a grow
        assert plane.capacity == 2
        assert r0 != r1
        leaves_equal(plane.to_pytree(r0), tiny_params)

    def test_double_free_rejected(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=2)
        row = plane.alloc()
        plane.free(row)
        with pytest.raises(KeyError):
            plane.free(row)

    def test_staged_write_visible_before_flush(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=2)
        row = plane.alloc()
        vec = jnp.arange(plane.dim, dtype=jnp.float32)
        plane.write(row, vec)
        np.testing.assert_array_equal(np.asarray(plane.row(row)), np.asarray(vec))
        got = plane.rows([row])  # flushes
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(vec))
        assert not plane._dirty

    def test_lerp_row(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=2)
        row = plane.alloc()  # zeros
        plane.lerp_row(row, jnp.full((plane.dim,), 4.0), 0.25)
        np.testing.assert_allclose(np.asarray(plane.row(row)), 1.0)

    def test_recycled_row_never_exposes_previous_tenant(self, tiny_params):
        """free -> alloc must hand out a zeroed row even though the freed
        tenant's bytes are still physically in the buffer — through every
        read path: row(), a fresh rows() gather, and a flushed matrix()."""
        plane = ParameterPlane(tiny_params, capacity=4)
        row = plane.alloc(jnp.full((plane.dim,), 7.7))
        other = plane.alloc(jnp.full((plane.dim,), 1.0))
        plane.rows((row, other))  # flush: tenant bytes land in the buffer
        plane.free(row)
        again = plane.alloc()
        assert again == row  # LIFO free list recycles the same physical row
        np.testing.assert_array_equal(np.asarray(plane.row(again)), 0.0)
        np.testing.assert_array_equal(np.asarray(plane.rows((again, other))[0]), 0.0)
        np.testing.assert_array_equal(np.asarray(plane.matrix()[again]), 0.0)
        np.testing.assert_array_equal(np.asarray(plane.row(other)), 1.0)

    def test_grow_preserves_staged_dirty_rows(self, tiny_params):
        """_grow with a write still staged must not lose it: the dirty map
        is host-side bookkeeping and survives the buffer doubling."""
        plane = ParameterPlane(tiny_params, capacity=1)
        r0 = plane.alloc()
        vec = jnp.arange(plane.dim, dtype=jnp.float32)
        plane.write(r0, vec)  # staged, deliberately not flushed
        r1 = plane.alloc()  # forces _grow while r0 is dirty
        assert plane.capacity == 2
        got = plane.rows((r0, r1))
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(vec))
        np.testing.assert_array_equal(np.asarray(got[1]), 0.0)


# ----------------------------------------------------------------- view cache
class TestViewCache:
    def test_rows_cache_is_true_lru_hot_set_survives_cold_reads(self, tiny_params):
        """Regression: eviction used to pick the oldest-*inserted* key, which
        is typically the hot per-upload center set — a burst of cold
        one-off reads would evict it every refinement. Hits must refresh
        recency so the hot set outlives interleaved cold reads."""
        plane = ParameterPlane(tiny_params, capacity=16)
        rows = [plane.alloc(jnp.full((plane.dim,), float(i))) for i in range(12)]
        hot = tuple(rows[:3])
        plane.rows(hot)  # inserted first (oldest by insertion order)
        for i in range(3, 12):  # far more cold sets than the cache holds
            plane.rows((rows[i],))
            assert (hot, "local") in plane._views, f"hot set evicted by cold read {i}"
            plane.rows(hot)  # hit: must move the hot set to MRU
        # the cached hot view still patches correctly after all that churn
        plane.write(rows[0], jnp.full((plane.dim,), 99.0))
        np.testing.assert_array_equal(np.asarray(plane.rows(hot)[0]), 99.0)

    def test_cold_reads_still_evict_each_other(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=16)
        rows = [plane.alloc() for _ in range(10)]
        for i in range(10):
            plane.rows((rows[i],))
        assert len(plane._views) <= 4
        assert ((rows[9],), "local") in plane._views  # most recent survives


# -------------------------------------------------------------------- parity
# --------------------------------------------------------------- bulk writes
class TestBulkWrites:
    def test_write_rows_staged_read_and_flush(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=8)
        rows = [plane.alloc() for _ in range(4)]
        mat = jnp.arange(4 * plane.dim, dtype=jnp.float32).reshape(4, plane.dim)
        plane.write_rows(rows, mat)
        # single-row read serves the staged matrix slice, pre-flush
        np.testing.assert_array_equal(np.asarray(plane.row(rows[2])), np.asarray(mat[2]))
        # batched read flushes the staged matrix in one scatter
        np.testing.assert_array_equal(np.asarray(plane.rows(tuple(rows))), np.asarray(mat))
        assert not plane._bulk and not plane._dirty

    def test_later_writes_win_regardless_of_staging_kind(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=8)
        rows = [plane.alloc() for _ in range(3)]
        mat = jnp.ones((3, plane.dim), jnp.float32)
        # per-row write then bulk: bulk wins
        plane.write(rows[0], jnp.full((plane.dim,), 7.0))
        plane.write_rows(rows, mat)
        # bulk then per-row: per-row wins
        plane.write(rows[1], jnp.full((plane.dim,), 9.0))
        # bulk then later bulk: the later matrix wins
        plane.write_rows([rows[2]], jnp.full((1, plane.dim), 5.0))
        got = np.asarray(plane.rows(tuple(rows)))
        np.testing.assert_array_equal(got[0], np.ones(plane.dim))
        np.testing.assert_array_equal(got[1], np.full(plane.dim, 9.0))
        np.testing.assert_array_equal(got[2], np.full(plane.dim, 5.0))

    def test_write_rows_patches_cached_views(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=8)
        rows = [plane.alloc() for _ in range(3)]
        ids = tuple(rows)
        before = np.asarray(plane.rows(ids))
        np.testing.assert_array_equal(before, np.zeros((3, plane.dim)))
        mat = jnp.full((2, plane.dim), 3.0)
        plane.write_rows(rows[:2], mat)
        after = np.asarray(plane.rows(ids))  # cached view, patched in place
        np.testing.assert_array_equal(after[:2], np.asarray(mat))
        np.testing.assert_array_equal(after[2], np.zeros(plane.dim))

    def test_write_rows_validates(self, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=4)
        row = plane.alloc()
        with pytest.raises(KeyError):
            plane.write_rows([row, row + 1], jnp.zeros((2, plane.dim)))
        with pytest.raises(ValueError):
            plane.write_rows([row], jnp.zeros((2, plane.dim)))

    def test_bulk_staging_stays_bounded_on_cached_view_reads(self, tiny_params):
        """Regression: cached-view reads patch in place without flushing, so
        a per-tick write_rows producer (the fleet eval refresh) must not
        grow _bulk by one matrix per tick — it is capped at one live
        matrix, and values stay correct across the internal flushes."""
        plane = ParameterPlane(tiny_params, capacity=8)
        rows = [plane.alloc() for _ in range(3)]
        ids = tuple(rows)
        plane.rows(ids)  # establish the cached view
        for tick in range(5):
            mat = jnp.full((3, plane.dim), float(tick + 1))
            plane.write_rows(rows, mat)
            assert len(plane._bulk) <= 1
            np.testing.assert_array_equal(np.asarray(plane.rows(ids)), np.asarray(mat))
        np.testing.assert_array_equal(
            np.asarray(plane.row(rows[1])), np.full(plane.dim, 5.0)
        )

    def test_write_rows_rejects_duplicate_ids(self, tiny_params):
        """Duplicate ids in one scatter resolve in unspecified order, so the
        staged read and the flushed buffer could disagree — rejected."""
        plane = ParameterPlane(tiny_params, capacity=4)
        row = plane.alloc()
        with pytest.raises(ValueError):
            plane.write_rows([row, row], jnp.zeros((2, plane.dim)))


def _tree(x, shift=0.0):
    return {
        "a": {"w": jnp.full((6, 4), float(x), jnp.float32)},
        "b": jnp.asarray([float(x) - shift, float(x) + shift], jnp.float32),
    }


def _run_scenario(backend: str):
    """Seeded 3-cluster stream: seeding, nearest-joins, hysteresis switches,
    and aggregation — identical upload sequence for both backends."""
    cl = DynamicClustering(3, mix_rate=0.25, backend=backend)
    rng = np.random.default_rng(42)
    anchors = {0: 0.0, 1: 30.0, 2: 90.0}
    events = []
    for step in range(40):
        client = int(rng.integers(0, 9))
        anchor = anchors[client % 3] + float(rng.normal() * 2.0)
        update = _tree(anchor, shift=0.5)
        cid, created = cl.assign(f"c{client}", update)
        cl.aggregate(cid, update)
        events.append((f"c{client}", cid, created))
    return cl, events


class TestBackendParity:
    def test_assign_aggregate_parity(self):
        plane_cl, plane_events = _run_scenario("plane")
        tree_cl, tree_events = _run_scenario("pytree")
        assert plane_events == tree_events  # identical assignment decisions
        assert plane_cl.assignment == tree_cl.assignment
        for cid in tree_cl.clusters:
            a = np.asarray(plane_cl.plane.row(plane_cl.clusters[cid]._row))
            b = np.asarray(tree_flat_vector(tree_cl.clusters[cid].center))
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
            assert plane_cl.clusters[cid].version == tree_cl.clusters[cid].version

    def test_nearest_pair_parity(self):
        plane_cl, _ = _run_scenario("plane")
        tree_cl, _ = _run_scenario("pytree")
        assert plane_cl.nearest_pair(close_frac=None) == tree_cl.nearest_pair(close_frac=None)
        assert plane_cl.nearest_pair() == tree_cl.nearest_pair()

    def test_center_property_materializes_equal_trees(self):
        plane_cl, _ = _run_scenario("plane")
        tree_cl, _ = _run_scenario("pytree")
        for cid in tree_cl.clusters:
            a, b = plane_cl.clusters[cid].center, tree_cl.clusters[cid].center
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-6)

    def test_merge_parity(self):
        results = {}
        for backend in ("plane", "pytree"):
            cl, _ = _run_scenario(backend)
            pair = cl.nearest_pair(close_frac=None)
            merged = cl.merge_pair(pair[0], pair[1], lambda p: p)
            vec = (
                np.asarray(cl.plane.row(cl.clusters[merged]._row))
                if backend == "plane"
                else np.asarray(tree_flat_vector(cl.clusters[merged].center))
            )
            results[backend] = (merged, vec, sorted(cl.clusters))
        assert results["plane"][0] == results["pytree"][0]
        assert results["plane"][2] == results["pytree"][2]
        np.testing.assert_allclose(results["plane"][1], results["pytree"][1], rtol=1e-6, atol=1e-6)

    def test_plane_rows_freed_on_merge_and_drop(self):
        cl, _ = _run_scenario("plane")
        before = cl.plane.num_allocated
        pair = cl.nearest_pair(close_frac=None)
        cl.merge_pair(pair[0], pair[1], lambda p: p)
        assert cl.plane.num_allocated == before - 2  # center + anchor rows returned
