"""Poison-resilient ingest (REPRO_GUARD + REPRO_FAULT_POISON_*): the
deterministic value-level poison schedule, the batched ingest guard's
accept/reject discipline, quarantine/eviction escalation, center rollback
from the snapshot ring, and the bitwise contracts that make the whole
defense free when it isn't needed.

Contracts under test:
  * poison draws ride the (seed, kind, client, counter) SeedSequence
    scheme, so loop/fleet backends and per-event/coalesced loops corrupt
    the identical uploads;
  * guard-off constructs nothing and a guard-on CLEAN run is all-accept —
    both bitwise-identical to the pre-guard trajectory (the stats ride
    the existing fused launches, so nothing perturbs arithmetic);
  * guard-off under poison is the negative control: non-finite values
    reach cluster centers (what the defense exists to stop);
  * rejected uploads still bill bytes, never reach the strategy, and
    escalate per-client strikes to quarantine then eviction;
  * a poisoned blend that slips past the upload stats is caught by the
    synced center norm and rolled back to a snapshot-ring entry.
"""
import math

import numpy as np
import pytest

from repro.fl.experiment import build_clients, build_strategy
from repro.fl.faults import (
    FaultConfig,
    FaultInjector,
    FaultPlan,
    apply_poison,
    default_fault_config,
)
from repro.fl.guard import GuardConfig, IngestGuard, guard_enabled, resolve_guard
from repro.fl.network import NetworkModel
from repro.fl.simulator import Simulator


def _run(*, backend="fleet", window=0.0, seed=3, fault_cfg=None, guard=None,
         max_time=600.0, num_clients=6, uplink=None, strategy="echopfl"):
    task, clients, init = build_clients("har", num_clients, seed=seed, samples_per_client=48)
    strat = build_strategy(strategy, init, clients, seed=seed)
    # explicit "off" beats any ambient REPRO_FAULTS/REPRO_GUARD: the CI
    # poison-chaos legs set chaotic env defaults, and the clean control
    # arms here must stay genuinely clean under them
    faults = FaultPlan(config=fault_cfg) if fault_cfg is not None else "off"
    sim = Simulator(
        clients, strat, network=NetworkModel(), seed=seed, client_backend=backend,
        coalesce_window=window, uplink=uplink, faults=faults,
        guard=guard if guard is not None else "off",
    )
    return sim.run_async(max_time=max_time), sim, init


def _assert_bitwise(a, b):
    assert a.curve == b.curve
    assert a.per_client_acc == b.per_client_acc
    assert (a.up_bytes, a.down_bytes, a.up_events, a.down_events) == (
        b.up_bytes, b.down_bytes, b.up_events, b.down_events
    )
    assert a.duration == b.duration
    assert a.extra.get("staleness") == b.extra.get("staleness")
    assert a.extra.get("uploads") == b.extra.get("uploads")


_POISON = dict(seed=7, poison_nan_rate=0.08, poison_scale_rate=0.06, poison_sign_rate=0.06)


# ------------------------------------------------------------ knob parsing
class TestKnobs:
    def test_resolve_guard_specs(self, monkeypatch):
        monkeypatch.delenv("REPRO_GUARD", raising=False)
        assert resolve_guard(None) is None
        assert resolve_guard("off") is None
        assert isinstance(resolve_guard("on"), GuardConfig)
        monkeypatch.setenv("REPRO_GUARD", "on")
        assert guard_enabled()
        assert isinstance(resolve_guard(None), GuardConfig)
        assert resolve_guard("off") is None  # explicit off beats the env
        cfg = GuardConfig(grace=2, k=4.0)
        assert resolve_guard(cfg) is cfg
        with pytest.raises(ValueError):
            resolve_guard("sometimes")

    def test_guard_config_validation(self):
        with pytest.raises(ValueError):
            GuardConfig(grace=-1)
        with pytest.raises(ValueError):
            GuardConfig(k=0.0)
        with pytest.raises(ValueError):
            GuardConfig(quarantine_strikes=5, evict_strikes=3)
        with pytest.raises(ValueError):
            GuardConfig(snapshot_ring=-2)

    def test_poison_env_knobs(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULT_POISON_NAN", "0.2")
        monkeypatch.setenv("REPRO_FAULT_POISON_SCALE", "0.1")
        monkeypatch.setenv("REPRO_FAULT_POISON_SIGN", "0.05")
        monkeypatch.setenv("REPRO_FAULT_POISON_FACTOR", "500")
        cfg = default_fault_config()
        assert (cfg.poison_nan_rate, cfg.poison_scale_rate, cfg.poison_sign_rate) == (
            0.2, 0.1, 0.05
        )
        assert cfg.poison_scale_factor == 500.0

    def test_fault_config_validation(self):
        """Satellite: out-of-range probabilities and negative durations
        fail fast with a clear error instead of corrupting the schedule."""
        for bad in (
            dict(crash_rate=1.5), dict(death_rate=-0.1), dict(loss_rate=2.0),
            dict(dup_rate=-1e-9), dict(reorder_rate=7.0),
            dict(poison_nan_rate=1.2), dict(poison_scale_rate=-0.5),
            dict(poison_sign_rate=math.inf), dict(poison_nan_frac=1.01),
            dict(poison_nan_rate=0.5, poison_scale_rate=0.4, poison_sign_rate=0.2),
            dict(crash_downtime=-5.0), dict(backoff_base=-1.0),
            dict(backoff_cap=-0.5), dict(reorder_max_delay=-2.0),
            dict(dup_max_delay=-1.0), dict(poison_scale_factor=0.0),
        ):
            with pytest.raises(ValueError):
                FaultConfig(**bad)
        # the boundary values themselves are legal
        FaultConfig(crash_rate=1.0, loss_rate=0.0, poison_nan_rate=1.0)


# ------------------------------------------------------- poison determinism
class TestPoisonSchedule:
    def test_draws_are_order_independent(self):
        cfg = FaultConfig(**_POISON)
        a = FaultInjector(FaultPlan(config=cfg))
        b = FaultInjector(FaultPlan(config=cfg))
        seq_a = [a.poison(0), a.poison(0), a.poison(1), a.poison(2)]
        b_p2 = b.poison(2)
        b_p1 = b.poison(1)
        b_p0a, b_p0b = b.poison(0), b.poison(0)
        assert seq_a == [b_p0a, b_p0b, b_p1, b_p2]

    def test_zero_rates_never_draw(self):
        inj = FaultInjector(FaultPlan(config=FaultConfig(seed=7)))
        assert inj.poison(0) is None
        # no counter advanced: a later poison-enabled injector's first draw
        # for this client is its counter-0 draw
        assert not any(k[0] == 5 for k in inj._counters)  # _K_POISON

    def test_apply_poison_semantics(self):
        import jax.numpy as jnp

        cfg = FaultConfig(seed=0, poison_nan_rate=0.5, poison_nan_frac=0.1,
                          poison_scale_factor=100.0)
        tree = {"w": jnp.arange(40, dtype=jnp.float32), "b": jnp.ones((10,), jnp.float32)}
        flat = np.concatenate([np.asarray(v).ravel() for v in
                               [tree["b"], tree["w"]]])  # alphabetical leaf order

        signed = apply_poison(tree, "sign", 0.3, cfg)
        np.testing.assert_array_equal(np.asarray(signed["w"]), -np.arange(40, dtype=np.float32))

        scaled = apply_poison(tree, "scale", 0.3, cfg)
        np.testing.assert_array_equal(np.asarray(scaled["b"]), np.full((10,), 100.0, np.float32))

        nanned = apply_poison(tree, "nan", 0.3, cfg)
        nan_flat = np.concatenate([np.asarray(nanned["b"]).ravel(),
                                   np.asarray(nanned["w"]).ravel()])
        n_nan = int(np.isnan(nan_flat).sum())
        assert n_nan == max(1, round(0.1 * flat.size))
        # the input tree was never mutated (fresh host copies)
        assert not np.isnan(np.asarray(tree["w"])).any()

    def test_schedule_identical_loop_vs_fleet(self):
        cfg = FaultConfig(**_POISON)
        rf, _, _ = _run(fault_cfg=cfg, guard="on", backend="fleet")
        rl, _, _ = _run(fault_cfg=cfg, guard="on", backend="loop")
        pf = {k: v for k, v in rf.extra["faults"].items() if k.startswith("poison")}
        pl = {k: v for k, v in rl.extra["faults"].items() if k.startswith("poison")}
        assert pf == pl and sum(pf.values()) > 0
        assert rf.extra["guard"] == rl.extra["guard"]


# -------------------------------------------------- bitwise identity (clean)
class TestCleanIdentity:
    @pytest.mark.parametrize("window", [0.0, 30.0])
    def test_guard_on_clean_run_is_bitwise_identical(self, window):
        """A clean run under the guard is all-accept: the added stats ride
        existing launches and decisions never alter the trajectory, so the
        curve/bytes/staleness ledger matches guard-off exactly."""
        r_off, _, _ = _run(window=window)
        r_on, _, _ = _run(window=window, guard="on")
        _assert_bitwise(r_off, r_on)
        g = r_on.extra["guard"]
        assert g["accepted"] > 0
        assert g["rejected_nonfinite"] == g["rejected_norm"] == g["rejected_dist"] == 0
        assert g["rollbacks"] == 0 and g["evicted_clients"] == 0
        assert "guard" not in r_off.extra  # guard-off constructs nothing

    def test_guard_off_sim_has_no_guard_machinery(self, monkeypatch):
        monkeypatch.delenv("REPRO_GUARD", raising=False)
        task, clients, init = build_clients("har", 2, seed=0, samples_per_client=48)
        strat = build_strategy("echopfl", init, clients, seed=0)
        sim = Simulator(clients, strat, seed=0)
        assert sim._guard is None
        assert strat.guard is None
        assert strat.clustering.snapshot_ring == 0


# --------------------------------------------------------- negative control
class TestNegativeControl:
    def test_unguarded_poison_reaches_centers(self):
        """Without the guard, NaN uploads blend straight into cluster
        centers and propagate — the failure mode the defense targets."""
        rep, sim, _ = _run(fault_cfg=FaultConfig(**_POISON), max_time=1200.0, num_clients=8)
        f = rep.extra["faults"]
        assert f["poison_nan"] > 0
        cl = sim.strategy.clustering
        centers = [np.asarray(c.center_vec) if cl.plane is not None
                   else np.concatenate([np.ravel(x) for x in
                                        __import__("jax").tree_util.tree_leaves(c.center)])
                   for c in cl.clusters.values()]
        assert any(not np.isfinite(v).all() for v in centers), (
            "negative control lost: poison never corrupted a center"
        )
        clean, _, _ = _run(max_time=1200.0, num_clients=8)
        assert rep.final_acc < clean.final_acc - 0.1


# ----------------------------------------------------------------- defense
class TestGuardDefense:
    def test_guard_on_survives_poison(self):
        rep, sim, _ = _run(fault_cfg=FaultConfig(**_POISON), guard="on",
                           max_time=1200.0, num_clients=8)
        g = rep.extra["guard"]
        assert math.isfinite(rep.final_acc)
        assert g["rejected_nonfinite"] > 0  # NaN uploads quarantined at ingest
        assert g["accepted"] > 0
        cl = sim.strategy.clustering
        for c in cl.clusters.values():
            vec = (np.asarray(c.center_vec) if cl.plane is not None else
                   np.concatenate([np.ravel(x) for x in
                                   __import__("jax").tree_util.tree_leaves(c.center)]))
            assert np.isfinite(vec).all(), "guarded run leaked a corrupt center"
        # the defense keeps the run near the clean trajectory while the
        # unguarded run collapses
        bad, _, _ = _run(fault_cfg=FaultConfig(**_POISON), max_time=1200.0, num_clients=8)
        clean, _, _ = _run(max_time=1200.0, num_clients=8)
        assert rep.final_acc > bad.final_acc
        assert rep.final_acc > clean.final_acc - 0.1

    @pytest.mark.parametrize("backend", ["fleet", "loop"])
    def test_degenerate_window_bitwise_under_poison(self, backend):
        """One event per window: the coalesced loop's collection-time guard
        verdicts land in the per-event loop's pop order, so poisoned +
        guarded runs stay bitwise identical across the two async paths."""
        cfg = FaultConfig(**_POISON)
        r0, _, _ = _run(fault_cfg=cfg, guard="on", backend=backend)
        r1, _, _ = _run(fault_cfg=cfg, guard="on", backend=backend, window=1e-9)
        _assert_bitwise(r0, r1)
        assert r0.extra["guard"] == r1.extra["guard"]
        assert r0.extra["faults"] == r1.extra["faults"]

    def test_rejected_uploads_still_bill_bytes(self):
        """Quarantine is a server-side decision: the poisoned payload
        crossed the wire first, so up_bytes counts it like any upload."""
        rep, _, init = _run(fault_cfg=FaultConfig(**_POISON), guard="on")
        g = rep.extra["guard"]
        rejected = (g["rejected_nonfinite"] + g["rejected_norm"] +
                    g["rejected_dist"] + g["rejected_quarantined"])
        assert rejected > 0
        from repro.fl.simulator import model_bytes
        # every up_event billed a full payload; accepted ingests < deliveries
        assert rep.up_events >= g["accepted"] + rejected
        assert rep.extra["uploads"] == g["accepted"]


# ------------------------------------------------------ escalation (unit)
class TestEscalation:
    def test_strikes_quarantine_then_evict(self):
        g = IngestGuard(GuardConfig(grace=1, window=8, k=1.0, rel_floor=1e-3,
                                    quarantine_strikes=2, evict_strikes=4))
        # build a tight clean history for cluster 0
        for _ in range(8):
            assert g.check_upload("good", 0, True, 1.0, 1.0) == "accept"
        # a wildly out-of-band norm strikes the offender
        assert g.check_upload("bad", 0, True, 1e6, 1.0) == "norm"
        assert "bad" not in g.quarantined
        assert g.check_upload("bad", 0, True, 1e6, 1.0) == "norm"
        assert "bad" in g.quarantined  # second strike hit the threshold
        # quarantined clients are auto-rejected even with clean stats...
        assert g.check_upload("bad", 0, True, 1.0, 1.0) == "quarantined"
        # ...and keep striking until eviction fires exactly once
        assert not g.should_evict("bad")
        assert g.check_upload("bad", 0, True, 1.0, 1.0) == "quarantined"
        assert g.should_evict("bad")
        assert "bad" in g.evicted
        assert not g.should_evict("bad")  # second consult: already evicted
        led = g.ledger_snapshot()
        assert led["quarantined_clients"] == 1 and led["evicted_clients"] == 1
        assert led["rejected_quarantined"] == 2

    def test_nonfinite_always_rejected_even_in_grace(self):
        g = IngestGuard(GuardConfig(grace=100))
        assert g.check_upload("c", 0, False, math.inf, math.inf) == "nonfinite"
        assert g.ledger["rejected_nonfinite"] == 1

    def test_upload_stats_flags_nonfinite(self):
        import jax.numpy as jnp

        g = IngestGuard(GuardConfig())
        clean = {"w": jnp.ones((4,), jnp.float32)}
        finite, l2, dist = g.upload_stats(clean, None)
        assert finite and np.isclose(l2, 2.0) and dist == 0.0
        bad = {"w": jnp.array([1.0, np.nan, 1.0, 1.0], jnp.float32)}
        finite, l2, dist = g.upload_stats(bad, clean)
        assert not finite and math.isinf(l2)


# ----------------------------------------------------- center ring (unit)
class TestSnapshotRing:
    def test_rollback_restores_last_finite_snapshot(self):
        from repro.core.server import EchoPFLServer

        import jax

        task, clients, init = build_clients("har", 4, seed=0, samples_per_client=48)
        srv = EchoPFLServer(init, num_initial_clusters=2, refine_every=1000)
        srv.attach_guard(IngestGuard(GuardConfig(snapshot_ring=2)))
        for i, c in enumerate(clients):
            up = jax.tree_util.tree_map(lambda x, i=i: x + i * 0.01, init)
            srv.handle_upload(c.client_id, up, 0, 48, float(i))
        cl = next(iter(srv.clustering.clusters.values()))
        if cl._snap_count == 0:  # broadcast is on-demand: force one push
            cl.snapshot_broadcast()
        assert cl._snap_count > 0  # broadcasts push ring entries
        if srv.clustering.plane is not None:
            before = np.asarray(cl.center_vec).copy()
            # corrupt the live center, then roll back
            srv.clustering.plane.write(
                cl._row, np.full_like(before, np.nan)
            )
            cl._center_cache = None
            assert not np.isfinite(np.asarray(cl.center_vec)).all()
            assert cl.rollback()
            assert np.isfinite(np.asarray(cl.center_vec)).all()

    def test_ring_rows_freed_with_cluster(self):
        from repro.core.server import EchoPFLServer

        import jax

        task, clients, init = build_clients("har", 4, seed=0, samples_per_client=48)
        srv = EchoPFLServer(init, num_initial_clusters=2, refine_every=1000)
        srv.attach_guard(IngestGuard(GuardConfig(snapshot_ring=3)))
        for i, c in enumerate(clients):
            up = jax.tree_util.tree_map(lambda x, i=i: x + (i % 2) * 0.5, init)
            srv.handle_upload(c.client_id, up, 0, 48, float(i))
        plane = srv.clustering.plane
        if plane is None:
            pytest.skip("pytree backend has no plane rows")
        before = plane.num_allocated
        victim = next(cid for cid in sorted(srv.clustering.clusters)
                      if srv.clustering.clusters[cid].members)
        members = sorted(srv.clustering.clusters[victim].members)
        n_snap = len(srv.clustering.clusters[victim]._snap_rows or ())
        assert n_snap == 3
        srv.evict_clients(members)
        # center + bcast + ring rows + one upload row per member all freed
        assert plane.num_allocated == before - 2 - n_snap - len(members)


# -------------------------------------------------- codec row reclamation
class TestCodecRelease:
    def test_death_releases_uplink_codec_rows(self):
        """Satellite: evicting a dead client frees its uplink-codec rows
        (delta anchor + EF residual under top-k), not just its cluster
        rows — the codec plane's free-list shrinks by 2 per death."""
        cfg = FaultConfig(seed=3, crash_rate=0.25, death_rate=0.8,
                          loss_rate=0.0, dup_rate=0.0, reorder_rate=0.0)
        rep, sim, _ = _run(fault_cfg=cfg, uplink="topk", num_clients=8, max_time=1500.0)
        f = rep.extra["faults"]
        assert f["deaths"] > 0
        codec = sim._codec
        n = len(codec.index)
        # top-k codec allocates 2 rows per client; each dead client's pair
        # was returned to the free-list
        assert codec.plane.num_allocated == 2 * n - 2 * len(sim._dead)
        for cid in sim._dead:
            assert codec._released[codec.index[cid]]
        # a released client's encode is a hard error, not silent garbage
        dead = next(iter(sim._dead))
        import jax.numpy as jnp
        with pytest.raises(ValueError):
            codec.encode(dead, sim.clients[dead].model)

    def test_release_survives_state_roundtrip(self):
        from repro.fl.uplink import UplinkCodec, resolve_uplink

        import jax.numpy as jnp

        template = {"w": jnp.zeros((32,), jnp.float32)}
        codec = UplinkCodec(template, [0, 1, 2], resolve_uplink("topk"))
        codec.seed({i: template for i in range(3)})
        before = codec.plane.num_allocated
        codec.release_client(1)
        assert codec.plane.num_allocated == before - 2
        codec.release_client(1)  # idempotent
        assert codec.plane.num_allocated == before - 2
        tree, meta = codec.state_dict()
        codec2 = UplinkCodec(template, [0, 1, 2], resolve_uplink("topk"))
        codec2.load_state(tree, meta, client_id_type=int)
        # the restored codec never re-seeds the released client's rows
        assert not codec2._seeded[codec2.index[1]]


# ------------------------------------------------------------ fedavg port
class TestFedAvgFlatAggregation:
    def test_matches_tree_weighted_mean(self):
        import jax
        import jax.numpy as jnp

        from repro.baselines.fedavg import FedAvg
        from repro.common.pytrees import tree_weighted_mean

        rng = np.random.default_rng(0)
        init = {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
                "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
        sizes = {0: 10, 1: 30, 2: 60}
        srv = FedAvg(init, sizes)
        ups = {
            cid: jax.tree_util.tree_map(
                lambda x, c=cid: x + np.float32(0.1 * (c + 1)), init)
            for cid in sizes
        }
        dls = srv.finish_round("global", ups, 0.0)
        assert srv.version == 1 and len(dls) == 3
        ref = tree_weighted_mean(list(ups.values()), [sizes[c] for c in ups])
        got = srv.global_model
        for a, b in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        # version-cached view: repeat reads share one object identity
        assert srv.global_model is srv.model_for(0)

    def test_loop_vs_fleet_sync_parity(self):
        def run(backend):
            task, clients, init = build_clients("har", 6, seed=3, samples_per_client=48)
            strat = build_strategy("fedavg", init, clients, seed=3)
            sim = Simulator(clients, strat, network=NetworkModel(), seed=3,
                            client_backend=backend)
            return sim.run_sync(rounds=4)

        rf, rl = run("fleet"), run("loop")
        assert rf.curve == rl.curve
        assert rf.per_client_acc == rl.per_client_acc
        assert (rf.up_bytes, rf.down_bytes) == (rl.up_bytes, rl.down_bytes)
