"""The row-sharded parameter plane: sharded kernel parity, sharding
preservation through flush/grow/recycling, and trajectory identity of the
sharded server against the single-device plane.

The in-process tests need >= 2 local devices and run under the ci.sh
multi-device leg (XLA_FLAGS=--xla_force_host_platform_device_count=8,
REPRO_PLANE_MESH=auto); on the default 1-device tier-1 run they skip. The
subprocess parity test always runs: it forces an 8-device host platform in
a child interpreter and asserts the full EchoPFL server trajectory
(assignments, merges, expansions, broadcast decisions) is identical
sharded vs. single-device, with centers matching to fp tolerance.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plane import ParameterPlane
from repro.kernels import ops, ref

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >= 2 devices (ci.sh multi-device leg)"
)


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    from repro.launch.mesh import make_plane_mesh

    return make_plane_mesh()


def test_plane_mesh_env_parsing(monkeypatch):
    from repro.launch.mesh import plane_mesh_from_env

    monkeypatch.setenv("REPRO_PLANE_MESH", "off")
    assert plane_mesh_from_env() is None
    monkeypatch.setenv("REPRO_PLANE_MESH", "1")
    m = plane_mesh_from_env()
    assert m is not None and m.shape["plane"] == 1  # "1" = one shard, not auto
    monkeypatch.setenv("REPRO_PLANE_MESH", "auto")
    m = plane_mesh_from_env()
    assert (m is None) == (len(jax.devices()) == 1)


def test_explicit_unsharded_overrides_env(monkeypatch):
    from repro.core.clustering import DynamicClustering

    monkeypatch.setenv("REPRO_PLANE_MESH", "1")
    cl = DynamicClustering(2, backend="plane", mesh=False)
    assert cl.mesh is None


# -------------------------------------------------------------- sharded ops
@multi_device
class TestShardedOps:
    def test_l1_pairwise_bitwise_vs_single_device(self, mesh):
        xs = jax.random.normal(jax.random.PRNGKey(0), (11, 300))
        cs = jax.random.normal(jax.random.PRNGKey(1), (5, 300))
        got = np.asarray(ops.l1_distance_pairwise(xs, cs, mesh=mesh))
        want = np.asarray(ops.l1_distance_pairwise(xs, cs))
        np.testing.assert_array_equal(got, want)  # per-row sums: bitwise
        np.testing.assert_allclose(got, np.asarray(ref.l1_distance_pairwise_ref(xs, cs)), rtol=1e-5)

    def test_l1_pairwise_fewer_rows_than_shards(self, mesh):
        xs = jax.random.normal(jax.random.PRNGKey(2), (1, 200))
        cs = jax.random.normal(jax.random.PRNGKey(3), (3, 200))
        got = np.asarray(ops.l1_distance_pairwise(xs, cs, mesh=mesh))
        np.testing.assert_array_equal(got, np.asarray(ops.l1_distance_pairwise(xs, cs)))

    @pytest.mark.parametrize("c", [1, 3, 8, 11])
    def test_assign_and_lerp_bitwise_vs_single_device(self, mesh, c):
        u = jax.random.normal(jax.random.PRNGKey(c), (300,))
        cs = jax.random.normal(jax.random.PRNGKey(c + 100), (c, 300))
        d, i, b = ops.assign_and_lerp(u, cs, 0.25, mesh=mesh)
        ds, is_, bs = ops.assign_and_lerp(u, cs, 0.25)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(ds))
        assert int(i) == int(is_)
        np.testing.assert_array_equal(np.asarray(b), np.asarray(bs))

    def test_assign_and_lerp_padded_rows_never_win(self, mesh):
        # a zero padding row would be L1-closest to a near-zero upload if the
        # mask were missing; the argmin must stay inside the real C rows
        u = jnp.full((256,), 1e-3)
        cs = jnp.stack([jnp.full((256,), 50.0), jnp.full((256,), -40.0), jnp.full((256,), 30.0)])
        d, i, b = ops.assign_and_lerp(u, cs, 0.5, mesh=mesh)
        assert 0 <= int(i) < 3
        assert int(i) == 2  # 30.0 is nearest
        assert np.all(np.isfinite(np.asarray(d)))

    def test_chi2_feedback_all_bitwise_g_vs_single_device(self, mesh):
        sizes = [2, 1, 9, 4]
        m, s = sum(sizes), len(sizes)
        k = jax.random.PRNGKey(7)
        f_pred = jax.random.uniform(k, (m, 6)) * 100
        f_true = jax.random.uniform(jax.random.PRNGKey(8), (m, 6)) * 100 + 1.0
        s_soft = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(9), (m, 6)), axis=-1)
        seg_ids = jnp.asarray(np.repeat(np.arange(s), sizes), np.int32)
        g, seg = ops.chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments=s, mesh=mesh)
        g1, seg1 = ops.chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments=s)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g1))  # per-member: bitwise
        # segment sums psum across shards: fp tolerance, not bitwise
        np.testing.assert_allclose(np.asarray(seg), np.asarray(seg1), rtol=1e-5, atol=1e-6)

    def test_chi2_feedback_rows_bitwise_vs_single_device(self, mesh):
        """The dissolve/expand probe path: per-row scores under the sharded
        launch are bitwise-identical to the single-device launch, including
        row counts that do not divide the shard count."""
        for m in (3, 11, 16):
            f_pred = jax.random.uniform(jax.random.PRNGKey(m), (m, 6)) * 100
            f_true = jax.random.uniform(jax.random.PRNGKey(m + 1), (m, 6)) * 100 + 1.0
            s_soft = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(m + 2), (m, 6)), axis=-1)
            got = np.asarray(ops.chi2_feedback(f_pred, f_true, s_soft, mesh=mesh))
            want = np.asarray(ops.chi2_feedback(f_pred, f_true, s_soft))
            assert got.shape == (m,)
            np.testing.assert_array_equal(got, want)
            np.testing.assert_allclose(
                got, np.asarray(ref.chi2_feedback_ref(f_pred, f_true, s_soft)), rtol=1e-5
            )


# ---------------------------------------------------------- sharded storage
@multi_device
class TestShardedPlane:
    def _assert_row_sharded(self, plane, arr):
        assert arr.sharding.is_equivalent_to(plane._sharding, arr.ndim)

    def test_capacity_rounds_to_shard_multiple(self, mesh, tiny_params):
        shards = mesh.shape["plane"]
        plane = ParameterPlane(tiny_params, capacity=shards + 1, mesh=mesh)
        assert plane.capacity % shards == 0
        self._assert_row_sharded(plane, plane._buf)

    def test_flush_preserves_sharding(self, mesh, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=16, mesh=mesh)
        r0, r1 = plane.alloc(), plane.alloc()
        plane.write(r0, jnp.full((plane.dim,), 2.0))
        plane.write(r1, jnp.full((plane.dim,), 3.0))
        plane.flush()  # multi-row donated scatter
        self._assert_row_sharded(plane, plane._buf)
        plane.write(r0, jnp.full((plane.dim,), 4.0))
        plane.flush()  # single-row dynamic_update_slice
        self._assert_row_sharded(plane, plane._buf)
        np.testing.assert_array_equal(np.asarray(plane.row(r0)), 4.0)

    def test_grow_preserves_sharding_rows_and_staged_writes(self, mesh, tiny_params):
        shards = mesh.shape["plane"]
        plane = ParameterPlane(tiny_params, capacity=shards, mesh=mesh)
        kept = plane.alloc(jnp.full((plane.dim,), 5.0))
        plane.flush()
        staged = plane.alloc()
        plane.write(staged, jnp.full((plane.dim,), 6.0))  # dirty across _grow
        extra = [plane.alloc() for _ in range(shards)]  # forces _grow
        assert plane.capacity == 2 * shards
        assert plane.capacity % shards == 0
        self._assert_row_sharded(plane, plane._buf)
        got = plane.rows((kept, staged, extra[0]))
        np.testing.assert_array_equal(np.asarray(got[0]), 5.0)
        np.testing.assert_array_equal(np.asarray(got[1]), 6.0)
        np.testing.assert_array_equal(np.asarray(got[2]), 0.0)

    def test_recycled_row_zeroed_under_sharding(self, mesh, tiny_params):
        plane = ParameterPlane(tiny_params, capacity=8, mesh=mesh)
        row = plane.alloc(jnp.full((plane.dim,), 9.0))
        plane.flush()
        plane.free(row)
        again = plane.alloc()
        assert again == row
        np.testing.assert_array_equal(np.asarray(plane.row(again)), 0.0)
        np.testing.assert_array_equal(np.asarray(plane.rows((again,))[0]), 0.0)
        self._assert_row_sharded(plane, plane.matrix())

    def test_rows_on_mesh_view_is_cached_replicated_and_patched(self, mesh, tiny_params):
        """The mesh-replicated view (sharded-launch operand form) must be
        cached and incrementally patched like the local view — a sharded
        launch must not re-broadcast the whole matrix every call — and the
        two domains must coexist under distinct cache keys."""
        plane = ParameterPlane(tiny_params, capacity=16, mesh=mesh)
        r = [plane.alloc(jnp.full((plane.dim,), float(i))) for i in range(4)]
        v1 = plane.rows(tuple(r), on_mesh=True)
        assert v1.sharding.is_equivalent_to(plane._replicated, v1.ndim)
        assert (tuple(r), "mesh") in plane._views
        plane.write(r[1], jnp.full((plane.dim,), 42.0))
        v2 = plane.rows(tuple(r), on_mesh=True)  # patched, still replicated
        np.testing.assert_array_equal(np.asarray(v2[1]), 42.0)
        np.testing.assert_array_equal(np.asarray(v2[0]), 0.0)
        assert v2.sharding.is_equivalent_to(plane._replicated, v2.ndim)
        vl = plane.rows(tuple(r))  # local-domain view: same values
        np.testing.assert_array_equal(np.asarray(vl), np.asarray(v2))
        assert (tuple(r), "local") in plane._views

    def test_rows_shard_domain_is_row_sharded_and_patched(self, mesh, tiny_params):
        """The shard-local gather (on_mesh="shard"): a fleet-scale row set
        read off a mesh-committed plane must land SHARDED over the row axis
        — never funneled through one local device — and patch incrementally
        under its own cache key like the other domains."""
        from jax.sharding import NamedSharding, PartitionSpec

        plane = ParameterPlane(tiny_params, capacity=16, mesh=mesh)
        r = [plane.alloc(jnp.full((plane.dim,), float(i))) for i in range(8)]
        want = NamedSharding(mesh, PartitionSpec("plane", None))
        v1 = plane.rows(tuple(r), on_mesh="shard")
        assert v1.sharding.is_equivalent_to(want, v1.ndim)
        assert (tuple(r), "shard") in plane._views
        plane.write(r[2], jnp.full((plane.dim,), 7.0))
        v2 = plane.rows(tuple(r), on_mesh="shard")  # patched, still sharded
        np.testing.assert_array_equal(np.asarray(v2[2]), 7.0)
        np.testing.assert_array_equal(np.asarray(v2[0]), 0.0)
        assert v2.sharding.is_equivalent_to(want, v2.ndim)
        # values equal the local-domain view; uncached take() agrees too
        np.testing.assert_array_equal(np.asarray(plane.rows(tuple(r))), np.asarray(v2))
        t = plane.take(tuple(r), on_mesh="shard")
        assert t.sharding.is_equivalent_to(want, t.ndim)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(v2))
        assert (tuple(r), "shard") in plane._views  # take never touches the cache

    def test_sharded_rows_feed_pairwise_kernel_without_localizing(self, mesh, tiny_params):
        """End to end: a shard-gathered row batch passes straight into the
        sharded pairwise kernel (ops._to_mesh_rows passes it through) and
        scores bitwise-identically to the single-device launch."""
        plane = ParameterPlane(tiny_params, capacity=16, mesh=mesh)
        rows = [plane.alloc(jnp.asarray(np.random.default_rng(i).standard_normal(plane.dim),
                                        jnp.float32)) for i in range(8)]
        centers = jnp.asarray(np.random.default_rng(99).standard_normal((3, plane.dim)), jnp.float32)
        U_shard = plane.rows(tuple(rows), on_mesh="shard")
        got = np.asarray(ops.l1_distance_pairwise(U_shard, centers, mesh=mesh, axis="plane"))
        want = np.asarray(ops.l1_distance_pairwise(plane.rows(tuple(rows)), centers))
        np.testing.assert_array_equal(got, want)

    def test_dim_axis_falls_back_when_not_divisible(self, tiny_params):
        # tiny_params has 187 params: prime-ish, never divisible by a model
        # axis of 2+ — the plane must fall back to row-only sharding
        if len(jax.devices()) < 4 or len(jax.devices()) % 2:
            pytest.skip("needs an even device count >= 4")
        from repro.launch.mesh import make_plane_mesh

        m2 = make_plane_mesh(len(jax.devices()) // 2, dim_shards=2)
        plane = ParameterPlane(tiny_params, capacity=8, mesh=m2)
        from jax.sharding import PartitionSpec

        assert plane._sharding.spec == PartitionSpec("plane", None)
        row = plane.alloc(jnp.full((plane.dim,), 1.5))
        np.testing.assert_array_equal(np.asarray(plane.rows((row,))[0]), 1.5)


# ----------------------------------------------------- in-process trajectory
@multi_device
class TestShardedClusteringParity:
    def _scenario(self, mesh, monkeypatch):
        from repro.core.clustering import DynamicClustering

        monkeypatch.delenv("REPRO_PLANE_MESH", raising=False)
        monkeypatch.setenv("REPRO_PLANE_MESH_MIN_ROWS", "0")  # force sharded compute
        cl = DynamicClustering(3, mix_rate=0.25, backend="plane", mesh=mesh)
        rng = np.random.default_rng(11)
        anchors = {0: 0.0, 1: 30.0, 2: 90.0}
        events = []
        for _ in range(40):
            client = int(rng.integers(0, 9))
            anchor = anchors[client % 3] + float(rng.normal() * 2.0)
            update = {"w": jnp.full((31,), anchor)}
            cid, created = cl.assign(f"c{client}", update)
            cl.aggregate(cid, update)
            events.append((f"c{client}", cid, created))
        return cl, events

    def test_sharded_matches_single_device_plane(self, mesh, monkeypatch):
        sharded, ev_sharded = self._scenario(mesh, monkeypatch)
        single, ev_single = self._scenario(False, monkeypatch)  # explicit unsharded
        assert sharded.plane.mesh is mesh and single.plane.mesh is None
        assert ev_sharded == ev_single
        assert sharded.assignment == single.assignment
        assert sharded.nearest_pair() == single.nearest_pair()
        for cid in single.clusters:
            np.testing.assert_allclose(
                np.asarray(sharded.plane.row(sharded.clusters[cid]._row)),
                np.asarray(single.plane.row(single.clusters[cid]._row)),
                rtol=1e-6, atol=1e-6,
            )


# ------------------------------------------------- forced-8-device parity
_PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.pop("REPRO_PLANE_MESH", None)
    os.environ["REPRO_PLANE_MESH_MIN_ROWS"] = "0"  # force sharded compute
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.server import EchoPFLServer
    from repro.launch.mesh import make_plane_mesh

    assert len(jax.devices()) == 8

    def vec(x):
        return {"w": jnp.full((24,), float(x))}

    def feedback_fn(client_id, center):
        err = 80.0 if client_id in ("c4", "c5") else 1.0
        f_pred = np.asarray([50.0 + err, 50.0 - err, 1.0])
        f_true = np.asarray([50.0, 50.0, 1.0])
        s_soft = np.asarray([0.9, 0.08, 0.02])
        return f_pred, f_true, s_soft

    def run(mesh):
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=1, refine_every=8,
                            feedback_fn=feedback_fn, local_train_fn=lambda p: p,
                            plane_backend="plane", plane_mesh=mesh, seed=0)
        for i in range(40):
            srv.handle_upload(f"c{i % 6}", vec(40.0 * (i % 2) + 0.01 * i), 0, 8,
                              t=float(i))
        return srv

    single = run(False)  # explicit unsharded, immune to inherited env knobs
    sharded = run(make_plane_mesh(8))
    assert single.clustering.plane.mesh is None
    assert sharded.clustering.plane.mesh is not None
    assert sharded.clustering.plane._buf.sharding.spec[0] == "plane"

    # trajectory identity: every protocol decision matches
    assert sharded.clustering.assignment == single.clustering.assignment
    assert sharded.events == single.events
    ss, sg = sharded.stats(), single.stats()
    for key in ("clusters", "merges", "expansions", "staleness", "broadcasts",
                "rnn_broadcasts", "decisions", "plane_rows"):
        assert ss[key] == sg[key], (key, ss[key], sg[key])
    assert ss["expansions"] > 0  # scenario must exercise refinement
    for cid, c in single.clustering.clusters.items():
        a = sharded.clustering.clusters[cid]
        for x, y in zip(jax.tree_util.tree_leaves(a.center),
                        jax.tree_util.tree_leaves(c.center)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)
    print("PARITY-OK")
    """
)


def test_sharded_server_trajectory_parity_on_forced_8_device_host():
    """Acceptance: the sharded plane (forced 8-device host mesh) reproduces
    the single-device server trajectory on the same seed — assignments,
    merges, expansions, and broadcast decisions identical; centers within
    fp tolerance. Runs in a subprocess because the device count is fixed
    at jax init."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p
    )
    proc = subprocess.run(
        [sys.executable, "-c", _PARITY_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PARITY-OK" in proc.stdout
