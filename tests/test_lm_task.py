"""REPRO_TASK=lm: LoRA/head-delta personalization over a frozen LM base.

Covers the task surface (zero-init delta == base model, loss decreases,
head-only freezing), delta-only payload billing through ``model_bytes``
(the FrozenBase wrapper contributes zero bytes), loop/fleet backend
agreement, and end-to-end runs through both ``run_sync`` (FedAvg) and the
coalesced async event loop (EchoPFL)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.lm_task import (
    FrozenBase,
    LMClientData,
    default_lm_task,
    make_lm_data,
    run_lm_experiment,
)
from repro.fl.simulator import model_bytes
from repro.fl.tasks import PersonalizationTask, get_task
from repro.models.model import forward as model_forward

TASK = default_lm_task()
SMALL = dict(seq_len=16, n_train=4, n_test=2, local_epochs=1, eval_interval=60.0)


def _data(n_clients=2, seed=0):
    return make_lm_data(
        n_clients, vocab_size=TASK.cfg.vocab_size,
        n_train=4, n_test=2, seq_len=16, seed=seed,
    )


def test_is_personalization_task():
    assert isinstance(TASK, PersonalizationTask)
    assert get_task("lm") is TASK  # singleton: stable jit-cache key


def test_initial_delta_is_exact_zero_update():
    """LoRA b-factors init to zero, so merged(init delta) must equal the
    frozen base bitwise — every client starts at the plane origin."""
    delta = TASK.init_params(jax.random.PRNGKey(3))
    tokens = jnp.asarray(_data()[0].tokens_train)
    base_logits, _, _ = model_forward(TASK.cfg, TASK.base.params, {"tokens": tokens})
    merged_logits, _, _ = model_forward(TASK.cfg, TASK.merged(delta), {"tokens": tokens})
    assert jnp.array_equal(base_logits, merged_logits)


def test_local_train_reduces_loss():
    d = _data()[0]
    delta = TASK.init_params(jax.random.PRNGKey(0))
    tok, lab = jnp.asarray(d.tokens_train), jnp.asarray(d.labels_train)
    mask = jnp.ones((d.n,), jnp.float32)
    first = float(TASK._nll(delta, tok, lab, mask))
    p = delta
    for _ in range(4):
        p, loss = TASK._scan_train(
            p, tok, lab, mask, jnp.float32(0.5), jnp.int32(5), jnp.float32(0.0),
            max_epochs=5,
        )
    assert float(loss) < first - 0.2
    assert np.isfinite(float(loss))


def test_head_only_freezes_block_lora():
    d = _data()[0]
    delta = TASK.init_params(jax.random.PRNGKey(0))
    trained, _ = TASK._scan_train(
        jax.tree_util.tree_map(jnp.asarray, delta),
        jnp.asarray(d.tokens_train), jnp.asarray(d.labels_train),
        jnp.ones((d.n,), jnp.float32),
        jnp.float32(0.5), jnp.int32(2), jnp.float32(1.0), max_epochs=2,
    )
    # wq LoRA untouched, head LoRA moved
    for slot in delta["wq"]:
        assert jnp.array_equal(trained["wq"][slot]["a"], delta["wq"][slot]["a"])
        assert jnp.array_equal(trained["wq"][slot]["b"], delta["wq"][slot]["b"])
    assert not jnp.array_equal(trained["head_b"], delta["head_b"])


def test_feedback_inputs_shapes_and_mass():
    d = _data()[0]
    delta = TASK.init_params(jax.random.PRNGKey(0))
    J = TASK.buckets
    f_pred, f_true, s_soft = TASK.feedback_inputs(delta, d, J)
    assert f_pred.shape == f_true.shape == s_soft.shape == (J,)
    # f_pred / f_true are COUNT histograms over the same n*S positions
    assert np.isclose(f_pred.sum(), d.n * d.tokens_train.shape[1])
    assert np.isclose(f_true.sum(), d.n * d.tokens_train.shape[1])
    # s_soft is a mean softmax over buckets -> sums to 1
    assert np.isclose(s_soft.sum(), 1.0, atol=1e-4)


def test_latent_clusters_share_distribution_not_samples():
    data = make_lm_data(8, vocab_size=TASK.cfg.vocab_size, latent_clusters=4,
                        n_train=8, n_test=2, seq_len=32, seed=0)
    J = TASK.buckets
    hists = np.stack([d.label_histogram(J) for d in data])
    hists /= hists.sum(axis=1, keepdims=True)
    # same latent cluster (0 and 4) -> near-identical bucket distribution
    same = np.abs(hists[0] - hists[4]).sum()
    cross = np.abs(hists[0] - hists[1]).sum()
    assert same < cross, (same, cross)
    # ...but not the same sequences
    assert not np.array_equal(data[0].tokens_train, data[4].tokens_train)


# ---------------------------------------------------------------------------
# delta-aware payload accounting
# ---------------------------------------------------------------------------


def test_frozen_base_bills_zero_bytes():
    """FrozenBase is a static pytree: payloads that carry it are billed at
    delta size only — the wire never pays for the frozen base."""
    delta = TASK.init_params(jax.random.PRNGKey(0))
    delta_bytes = model_bytes(delta)
    assert model_bytes(TASK.base) == 0
    assert model_bytes({"base": TASK.base, "delta": delta}) == delta_bytes
    # sanity: the delta is orders of magnitude smaller than the base
    base_bytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(TASK.base.params))
    assert delta_bytes < base_bytes / 3


def test_sim_bills_uploads_at_delta_size():
    delta_bytes = model_bytes(TASK.init_params(jax.random.PRNGKey(0)))
    _, _, _, rep = run_lm_experiment("fedavg", num_clients=4, rounds=2, **SMALL)
    assert rep.up_events > 0
    assert rep.up_bytes == rep.up_events * delta_bytes


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------


def test_run_sync_fedavg():
    task, clients, strat, rep = run_lm_experiment(
        "fedavg", num_clients=4, rounds=2, **SMALL)
    assert rep.extra["task"] == "lm"
    assert rep.up_events == 8  # 4 clients x 2 rounds
    assert 0.0 <= rep.final_acc <= 1.0
    assert len(rep.curve) > 0


def test_run_async_echopfl_coalesced(monkeypatch):
    monkeypatch.setenv("REPRO_ASYNC_COALESCE", "1")
    task, clients, strat, rep = run_lm_experiment(
        "echopfl", num_clients=4, max_time=200.0, num_clusters=2, **SMALL)
    assert rep.up_events > 0
    assert rep.extra["task"] == "lm"
    assert 0.0 <= rep.final_acc <= 1.0


def test_loop_fleet_backend_agree():
    """The batched fleet launches and the per-client loop implement the
    same task arithmetic."""
    runs = {}
    for backend in ("loop", "fleet"):
        _, _, _, rep = run_lm_experiment(
            "fedavg", num_clients=4, rounds=2, seed=1,
            client_backend=backend, **SMALL)
        runs[backend] = rep
    assert runs["loop"].up_events == runs["fleet"].up_events
    assert np.isclose(runs["loop"].final_acc, runs["fleet"].final_acc, atol=1e-5)


def test_repro_task_env_dispatch(monkeypatch):
    """run_experiment reroutes to the LM driver under REPRO_TASK=lm."""
    monkeypatch.setenv("REPRO_TASK", "lm")
    from repro.fl.experiment import run_experiment
    task, clients, strat, rep = run_experiment(
        "image_recognition", "fedavg", num_clients=4, rounds=2,
        local_epochs=1, eval_interval=60.0)
    assert rep.extra["task"] == "lm"
    assert task is TASK
