"""Event-coalesced async pipeline (REPRO_ASYNC_COALESCE): parity of the
coalesced loop against the per-event loop, sequential-equivalence of the
batched server ingest (``EchoPFLServer.handle_uploads``), the fused
ingest-chain kernel, and knob parsing.

This file is part of ci.sh's PARITY_TESTS, so every assertion here runs
under both kernel backends (REPRO_KERNELS=ref and =pallas): the coalesced
trajectory claims must not depend on which kernel implementation computes
the distances and blends.
"""
import numpy as np
import pytest

from repro.core.server import EchoPFLServer
from repro.fl.experiment import build_clients, build_strategy
from repro.fl.network import NetworkModel
from repro.fl.simulator import Simulator, default_async_coalesce


def _run(window, *, backend="fleet", strategy="echopfl", seed=3, max_time=420.0,
         num_clients=6, max_uploads=None, churn=None, **strategy_kw):
    task, clients, init = build_clients("har", num_clients, seed=seed, samples_per_client=48)
    strat = build_strategy(strategy, init, clients, seed=seed, **strategy_kw)
    sim = Simulator(
        clients, strat, network=NetworkModel(), seed=seed,
        client_backend=backend, coalesce_window=window, churn=churn,
    )
    kw = {"max_time": max_time}
    if max_uploads:
        kw["max_uploads"] = max_uploads
    return sim.run_async(**kw), sim


def _assert_bitwise(a, b):
    """Full report identity: curves, bytes, events, duration, counters."""
    assert [t for t, _ in a.curve] == [t for t, _ in b.curve]
    assert [x for _, x in a.curve] == [x for _, x in b.curve]
    assert (a.up_bytes, a.down_bytes, a.up_events, a.down_events) == (
        b.up_bytes, b.down_bytes, b.up_events, b.down_events
    )
    assert a.duration == b.duration
    assert a.per_client_acc == b.per_client_acc
    for key in ("uploads", "clusters", "merges", "expansions", "broadcasts",
                "rnn_broadcasts", "decisions", "staleness"):
        if key in a.extra or key in b.extra:
            assert a.extra.get(key) == b.extra.get(key), key


def _assert_window_parity(a, b, acc_atol=0.05):
    """The window > 0 contract: the virtual-time trajectory, upload counts
    and uplink billing are exact; model values (hence accuracies and the
    RNN's broadcast decisions) are allclose, not bitwise — a window is one
    superstep, and mid-window downlinks no longer retroactively rebase the
    training rounds that already finished inside it."""
    assert [t for t, _ in a.curve] == [t for t, _ in b.curve]
    assert a.duration == b.duration
    assert a.extra["uploads"] == b.extra["uploads"]
    assert (a.up_bytes, a.up_events) == (b.up_bytes, b.up_events)
    assert a.extra["staleness"] == b.extra["staleness"]
    np.testing.assert_allclose(
        [x for _, x in a.curve], [x for _, x in b.curve], atol=acc_atol
    )
    for cid in a.per_client_acc:
        np.testing.assert_allclose(a.per_client_acc[cid], b.per_client_acc[cid], atol=acc_atol)


# --------------------------------------------------------- simulator parity
class TestCoalescedLoopParity:
    def test_degenerate_window_is_bitwise_identical(self):
        """One event per window: the coalesced loop must replay the
        per-event loop exactly — times, accuracies, bytes, counters."""
        r0, _ = _run(0.0)
        r1, _ = _run(1e-9)
        _assert_bitwise(r0, r1)

    def test_benchmark_window_trajectory_parity(self):
        r0, _ = _run(0.0)
        r2, sim = _run(45.0)
        _assert_window_parity(r0, r2)
        # the window actually coalesced: batched arrival groups formed
        groups = sim.coalesced_groups.get("upload_done", [])
        assert groups and max(groups) > 1

    def test_loop_and_fleet_backends_agree_under_coalescing(self):
        """PR 3's loop-vs-fleet parity must survive coalescing: both client
        backends share the superstep semantics, so their coalesced
        trajectories match each other exactly in time/bytes and closely in
        values."""
        rf, _ = _run(45.0, backend="fleet")
        rl, _ = _run(45.0, backend="loop")
        assert [t for t, _ in rf.curve] == [t for t, _ in rl.curve]
        assert (rf.up_bytes, rf.down_bytes, rf.up_events, rf.down_events) == (
            rl.up_bytes, rl.down_bytes, rl.up_events, rl.down_events
        )
        np.testing.assert_allclose(
            [x for _, x in rf.curve], [x for _, x in rl.curve], atol=5e-6
        )

    def test_max_uploads_cap_matches_per_event(self):
        # degenerate window: the cap cuts at the identical event, bitwise
        r0, _ = _run(0.0, max_uploads=40, max_time=1e9)
        r1, _ = _run(1e-9, max_uploads=40, max_time=1e9)
        _assert_bitwise(r0, r1)
        assert r0.extra["uploads"] == 40
        # real window: the ingest cap still lands exactly, at the same
        # virtual time (in-window generated arrivals deliver next superstep,
        # so the cap may cut before a couple of in-flight uplinks — billed,
        # not ingested — that the per-event loop would have ingested)
        r2, _ = _run(45.0, max_uploads=40, max_time=1e9)
        assert r2.extra["uploads"] == 40
        assert r2.duration == r0.duration
        assert r2.up_events >= r2.extra["uploads"]

    def test_churn_parity_degenerate(self):
        """Offline windows re-push upload_starts through the coalesced path
        too; the degenerate window must stay bitwise, churn delays equal."""
        churn = {0: [(50.0, 260.0)], 3: [(10.0, 500.0)]}
        r0, _ = _run(0.0, churn=churn)
        r1, _ = _run(1e-9, churn=churn)
        _assert_bitwise(r0, r1)
        assert r0.extra["churn_delays"] == r1.extra["churn_delays"] > 0

    def test_churn_parity_real_window(self):
        """Churn resumes and next-round schedules draw from ONE shared
        device RNG. Compute times are pre-drawn at collection time in
        global event order, so the stream matches the per-event loop's
        except where a resume interleaves with an arrival GENERATED inside
        the same window (delivered next superstep) — under churn the
        virtual-time grid therefore stays on the same eval schedule and
        the protocol completes equivalently, but upload times may shift by
        up to a window."""
        churn = {0: [(50.0, 120.0)], 3: [(10.0, 200.0)]}
        r0, _ = _run(0.0, churn=churn)
        r2, _ = _run(45.0, churn=churn)
        assert [t for t, _ in r0.curve] == [t for t, _ in r2.curve]  # eval grid
        assert r0.duration == r2.duration
        assert abs(r0.extra["uploads"] - r2.extra["uploads"]) <= 2
        assert r2.extra["churn_delays"] > 0
        # 6 clients x 16 test samples: one shifted broadcast moves a
        # personalized accuracy by whole 1/16 steps — coarse tolerance
        np.testing.assert_allclose(
            [x for _, x in r0.curve], [x for _, x in r2.curve], atol=0.25
        )

    def test_strategy_without_batched_ingest_falls_back(self):
        """FedAsyn windows ingest through its scan-chain handle_uploads,
        bitwise the per-upload path (degenerate window pins it)."""
        r0, _ = _run(0.0, strategy="fedasyn")
        r1, _ = _run(1e-9, strategy="fedasyn")
        _assert_bitwise(r0, r1)
        r2, _ = _run(60.0, strategy="fedasyn")
        assert [t for t, _ in r0.curve] == [t for t, _ in r2.curve]
        assert r0.extra["uploads"] == r2.extra["uploads"]

    def test_env_knob_parsing(self, monkeypatch):
        for spec, want in (("off", 0.0), ("0", 0.0), ("", 0.0), ("none", 0.0),
                           ("30", 30.0), ("2.5", 2.5)):
            monkeypatch.setenv("REPRO_ASYNC_COALESCE", spec)
            assert default_async_coalesce() == want
        monkeypatch.delenv("REPRO_ASYNC_COALESCE")
        assert default_async_coalesce() == 0.0


# ------------------------------------------------------ batched server ingest
def _noisy_stream(clients, init, rounds=12, seed=0):
    rng = np.random.default_rng(seed)
    stream = []
    for r in range(rounds):
        for c in clients:
            upload = [
                {k: np.asarray(v) + np.float32(0.05 + 0.01 * r)
                     * rng.standard_normal(np.shape(v)).astype(np.float32)
                 for k, v in layer.items()}
                for layer in init
            ]
            stream.append((c.client_id, upload, 0, 48, float(r)))
    return stream


def _build_server(seed=3, **kw):
    task, clients, init = build_clients("har", 6, seed=seed, samples_per_client=48)
    strat = build_strategy("echopfl", init, clients, seed=seed, **kw)
    return clients, init, strat


def _payload_vec(params):
    return np.concatenate([np.ravel(np.asarray(x)) for l in params for x in l.values()])


class TestHandleUploadsSequentialEquivalence:
    def _assert_servers_equal(self, sA, sB, outA, outB):
        assert sA.clustering.assignment == sB.clustering.assignment
        for cid in sA.clustering.clusters:
            ca, cb = sA.clustering.clusters[cid], sB.clustering.clusters[cid]
            assert ca.version == cb.version
            if sA.clustering.plane is not None:
                va = np.asarray(ca._plane.row(ca._row))
                vb = np.asarray(cb._plane.row(cb._row))
                assert np.array_equal(va, vb), f"cluster {cid} center diverged"
        for cid in sA.predictors:
            assert sA.predictors[cid].records == sB.predictors[cid].records
            assert sA.predictors[cid].decisions == sB.predictors[cid].decisions
            assert sA.predictors[cid].broadcasts == sB.predictors[cid].broadcasts
        assert sA.events == sB.events
        assert sA.staleness.snapshot() == sB.staleness.snapshot()
        assert sA.client_versions == sB.client_versions
        assert len(outA) == len(outB)
        for a, b in zip(outA, outB):
            assert [(d.client_id, d.version, d.cluster_id, d.reason) for d in a] == [
                (d.client_id, d.version, d.cluster_id, d.reason) for d in b
            ]
            for da, db in zip(a, b):
                assert np.array_equal(_payload_vec(da.params), _payload_vec(db.params))

    def test_batched_ingest_is_bitwise_sequential(self):
        """handle_uploads = N handle_upload calls, exactly: identical
        centers (bitwise), staleness, predictor records/decisions, events,
        and downlink payloads — across seeding fallback, intra-batch
        broadcasts, and refine boundaries (refine_every=20 with batches of
        6 puts the boundary mid-batch)."""
        clients, init, sA = _build_server()
        _, _, sB = _build_server()
        stream = _noisy_stream(clients, init)
        outA = [sA.handle_upload(*u) for u in stream]
        outB = []
        for i in range(0, len(stream), 6):
            outB.extend(sB.handle_uploads(stream[i : i + 6]))
        assert sA._uploads == sB._uploads == len(stream)
        self._assert_servers_equal(sA, sB, outA, outB)

    def test_duplicate_client_in_batch_splits_segment(self):
        clients, init, sA = _build_server()
        _, _, sB = _build_server()
        stream = _noisy_stream(clients, init, rounds=3)
        # a batch where client 0 appears twice, with state between
        dup = stream[:6] + [stream[6]] + stream[7:12]
        outA = [sA.handle_upload(*u) for u in dup]
        outB = sB.handle_uploads(dup)
        self._assert_servers_equal(sA, sB, outA, outB)

    def test_partial_finetune_members_stay_pinned(self):
        """A pf member's upload must aggregate into its own cluster without
        an argmin move, batched exactly like sequential."""
        clients, init, sA = _build_server()
        _, _, sB = _build_server()
        stream = _noisy_stream(clients, init, rounds=4)
        warm = stream[:12]
        for u in warm:
            sA.handle_upload(*u)
        sB.handle_uploads(warm)
        for s in (sA, sB):  # impose pf mode on two members of cluster 0
            cl = s.clustering.clusters[0]
            pinned = sorted(cl.members)[:2]
            cl.partial_finetune.update(pinned)
            cl.pf_round = s._refine_round + 10  # not lifted during the test
        rest = stream[12:30]
        outA = [sA.handle_upload(*u) for u in rest]
        outB = sB.handle_uploads(rest)
        self._assert_servers_equal(sA, sB, outA, outB)

    def test_pytree_backend_falls_back_per_upload(self):
        clients, init, sA = _build_server(plane_backend="pytree")
        _, _, sB = _build_server(plane_backend="pytree")
        stream = _noisy_stream(clients, init, rounds=4)
        outA = [sA.handle_upload(*u) for u in stream]
        outB = sB.handle_uploads(stream)
        self._assert_servers_equal(sA, sB, outA, outB)

    def test_broadcast_disabled(self):
        clients, init, sA = _build_server(enable_broadcast=False)
        _, _, sB = _build_server(enable_broadcast=False)
        stream = _noisy_stream(clients, init, rounds=6)
        outA = [sA.handle_upload(*u) for u in stream]
        outB = []
        for i in range(0, len(stream), 9):
            outB.extend(sB.handle_uploads(stream[i : i + 9]))
        self._assert_servers_equal(sA, sB, outA, outB)

    def test_minimal_window_pairs_bitwise(self):
        """Degenerate (size-1 chain) windows: batches of two distinct
        clients make every predictor sub-window carry at most one or two
        steps per cluster — the smallest launches the fused chain emits —
        and must still replay the serial trajectory bitwise."""
        clients, init, sA = _build_server()
        _, _, sB = _build_server()
        stream = _noisy_stream(clients, init, rounds=8)
        outA = [sA.handle_upload(*u) for u in stream]
        outB = []
        for i in range(0, len(stream), 2):
            outB.extend(sB.handle_uploads(stream[i : i + 2]))
        self._assert_servers_equal(sA, sB, outA, outB)

    def test_predictor_batch_on_off_trajectories_identical(self, monkeypatch):
        """REPRO_PREDICTOR_BATCH on vs off over identical coalesced windows:
        the fused RNN chain launch must reproduce the per-upload serial
        learn/decide trajectory bitwise — including the final RNN weights."""
        import jax

        monkeypatch.setenv("REPRO_PREDICTOR_BATCH", "0")
        clients, init, sOff = _build_server()
        stream = _noisy_stream(clients, init)
        outOff = []
        for i in range(0, len(stream), 6):
            outOff.extend(sOff.handle_uploads(stream[i : i + 6]))
        monkeypatch.setenv("REPRO_PREDICTOR_BATCH", "1")
        _, _, sOn = _build_server()
        outOn = []
        for i in range(0, len(stream), 6):
            outOn.extend(sOn.handle_uploads(stream[i : i + 6]))
        self._assert_servers_equal(sOff, sOn, outOff, outOn)
        assert set(sOff.predictors) == set(sOn.predictors)
        for cid in sOn.predictors:
            for a, b in zip(
                jax.tree_util.tree_leaves(sOff.predictors[cid].params),
                jax.tree_util.tree_leaves(sOn.predictors[cid].params),
            ):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (
                    f"predictor {cid} RNN weights diverged"
                )


# ------------------------------------------------------ predictor chain
class TestPredictorChainKernel:
    def test_degenerate_window_bitwise_vs_serial(self):
        """L=1 chain (one cluster, one upload) against the serial
        `_rnn_sgd` + `_rnn_want` dispatches: params and decision bitwise."""
        import jax
        import jax.numpy as jnp

        from repro.core.broadcast import _rnn_sgd, _rnn_want, build_seq, init_rnn
        from repro.kernels import ops as K

        params = init_rnn(jax.random.PRNGKey(5))
        k = 10
        records = [0.5, 1.25, 0.75]
        seq_pre = build_seq(records, k)
        seq_post = build_seq(records + [2.0], k)
        p_serial, _ = _rnn_sgd(params, jnp.asarray(seq_pre), jnp.asarray(1), jnp.asarray(1e-2))
        want_serial = bool(_rnn_want(p_serial, jnp.asarray(seq_post)))
        lab_t = np.asarray([[1, 1]], np.int32)  # label 1 under any anchor
        fb_t = np.zeros((1, 2), bool)
        new_params, wants = K.predictor_chain(
            params, seq_pre[None], seq_post[None],
            lab_t, fb_t, [True], [True], [False], 0, 1e-2,
        )
        assert bool(np.asarray(wants)[0]) == want_serial
        for a, b in zip(
            jax.tree_util.tree_leaves(p_serial),
            jax.tree_util.tree_leaves(new_params),
        ):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_front_padded_ragged_k_bitwise(self):
        """Predictors carry different Top-K lengths; each cluster's chain
        front-pads its window to the launch K and masks the RNN hidden
        state before `start`. Valid steps must see exactly the serial
        operands — params and decisions bitwise vs the exact-k dispatches,
        including mixed gated/pad steps in one scan."""
        import jax
        import jax.numpy as jnp

        from repro.core.broadcast import _rnn_sgd, _rnn_want, build_seq, init_rnn
        from repro.kernels import ops as K

        ks = [10, 16, 12]
        keys = jax.random.split(jax.random.PRNGKey(7), len(ks))
        params_list = [init_rnn(key) for key in keys]
        rng = np.random.default_rng(11)
        labels = rng.integers(0, 2, (len(ks), 2)).astype(np.int32)
        for b, k in enumerate(ks):
            Kp = 1 << (k - 1).bit_length()  # pow2 bucket, like the planner
            recs = [float(x) for x in rng.uniform(0.1, 3.0, k)]
            p = params_list[b]
            pre_b, post_b, wants_b = [], [], []
            for step in range(2):
                s_pre = build_seq(recs, k)
                recs = (recs + [float(rng.uniform(0.1, 3.0))])[-k:]
                s_post = build_seq(recs, k)
                p, _ = _rnn_sgd(p, jnp.asarray(s_pre), jnp.asarray(labels[b, step]), jnp.asarray(1e-2))
                wants_b.append(bool(_rnn_want(p, jnp.asarray(s_post))))
                pad = np.zeros((Kp - k, 1), np.float32)
                pre_b.append(np.concatenate([pad, s_pre]))
                post_b.append(np.concatenate([pad, s_post]))
            # pow2-pad the 2 real steps to 4 with both gates off: the pad
            # steps must be a bitwise identity rewrite
            pre_p = np.concatenate([np.stack(pre_b), np.zeros((2, Kp, 1), np.float32)])
            post_p = np.concatenate([np.stack(post_b), np.zeros((2, Kp, 1), np.float32)])
            # anchor-independent label tables: every "last fired" column
            # carries the step's serial label, so fires can't perturb them
            lab_p = np.zeros((4, 5), np.int32)
            lab_p[0, :] = labels[b, 0]
            lab_p[1, :] = labels[b, 1]
            fb_p = np.zeros((4, 5), bool)
            gates = np.asarray([True, True, False, False])
            fgates = np.zeros(4, bool)
            new_params, wants = K.predictor_chain(
                params_list[b], pre_p, post_p, lab_p, fb_p,
                gates, gates, fgates, Kp - k, 1e-2
            )
            assert [bool(x) for x in np.asarray(wants)[:2]] == wants_b
            assert not np.asarray(wants)[2:].any()
            for x, y in zip(
                jax.tree_util.tree_leaves(p),
                jax.tree_util.tree_leaves(new_params),
            ):
                assert np.array_equal(np.asarray(x), np.asarray(y)), f"cluster {b} params drift"


# ----------------------------------------------------------- ingest chain
class TestIngestChainKernel:
    def test_chain_matches_sequential_assign_and_lerp(self, rng):
        """The fused scan replays N sequential assign+blend steps bitwise:
        distances against the live (already-blended) centers, argmin with
        hysteresis, the canonical two-op blend."""
        import jax.numpy as jnp

        from repro.kernels import ops as K

        dim, C, S, beta, margin = 256, 4, 8, 0.25, 0.1
        centers = jnp.asarray(rng.standard_normal((C, dim)), jnp.float32)
        U = jnp.asarray(rng.standard_normal((S, dim)), jnp.float32)
        prev = [-1, 0, 2, -1, 1, 3, 0, 2]
        forced = [-1, -1, 1, -1, -1, -1, -1, 3]
        cids, blended, change, gb, ga = K.ingest_chain(
            U, centers, centers * 0.9, prev, forced, [True] * S,
            beta=beta, switch_margin=margin,
        )
        cmat = np.asarray(centers, np.float32).copy()
        bmat = np.asarray(centers * 0.9, np.float32)
        for j in range(S):
            dists, _, kern_blend = K.assign_and_lerp(U[j], jnp.asarray(cmat), beta)
            dists = np.asarray(dists)
            cid = int(np.argmin(dists))
            if forced[j] >= 0:
                cid = forced[j]
            elif prev[j] >= 0 and prev[j] != cid:
                if dists[cid] > (1.0 - margin) * dists[prev[j]]:
                    cid = prev[j]
            assert int(cids[j]) == cid, j
            new = np.asarray(kern_blend) if cid == int(np.argmin(dists)) and forced[j] < 0 else None
            got = np.asarray(blended[j])
            if new is not None:
                assert np.array_equal(got, new), j  # winner: the kernel blend
            np.testing.assert_allclose(
                float(change[j]), np.abs(got - cmat[cid]).sum(), rtol=1e-6
            )
            np.testing.assert_allclose(
                float(gb[j]), np.abs(cmat[cid] - bmat[cid]).sum(), rtol=1e-6
            )
            np.testing.assert_allclose(
                float(ga[j]), np.abs(got - bmat[cid]).sum(), rtol=1e-6
            )
            cmat[cid] = got

    def test_padded_rows_are_inert(self, rng):
        import jax.numpy as jnp

        from repro.kernels import ops as K

        dim, C = 64, 3
        centers = jnp.asarray(rng.standard_normal((C, dim)), jnp.float32)
        U = jnp.asarray(rng.standard_normal((4, dim)), jnp.float32)
        # rows 2..3 invalid: identical outputs for rows 0..1, centers only
        # advanced by the valid rows
        full = K.ingest_chain(U[:2], centers, centers, [-1, -1], [-1, -1], [True, True], beta=0.5)
        padded = K.ingest_chain(U, centers, centers, [-1] * 4, [-1] * 4,
                                [True, True, False, False], beta=0.5)
        for a, b in zip(full, padded):
            assert np.array_equal(np.asarray(a[:2]), np.asarray(b[:2]))

    def test_padded_centers_never_win(self, rng):
        import jax.numpy as jnp

        from repro.kernels import ops as K

        dim = 64
        centers = jnp.asarray(rng.standard_normal((2, dim)), jnp.float32)
        zpad = jnp.zeros((2, dim), jnp.float32)  # pad rows are all-zero
        u = jnp.zeros((1, dim), jnp.float32)  # nearest to a zero row by construction
        cids, *_ = K.ingest_chain(
            u, jnp.concatenate([centers, zpad]), jnp.concatenate([centers, zpad]),
            [-1], [-1], [True], beta=0.5, num_centers=2,
        )
        assert int(cids[0]) < 2
