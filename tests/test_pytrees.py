"""Hypothesis property tests for the pytree math the protocol is built on."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.common import pytrees as P

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def tree_strategy(draw):
    shapes = draw(
        st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=4)
    )
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    return {
        f"layer{i}": {"w": jnp.asarray(rng.normal(size=s), jnp.float32)}
        for i, s in enumerate(shapes)
    }


trees = st.composite(lambda draw: tree_strategy(draw))()


@given(trees)
def test_flatten_unflatten_roundtrip(t):
    vec = P.tree_flat_vector(t)
    back = P.tree_unflatten_vector(vec, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@given(trees)
def test_flat_vector_length_is_param_count(t):
    assert P.tree_flat_vector(t).shape[0] == P.tree_num_params(t)


@given(trees, st.floats(0, 1))
def test_lerp_endpoints_and_midpoint(t, alpha):
    zeros = P.tree_zeros_like(t)
    mid = P.tree_lerp(zeros, t, alpha)
    for a, b in zip(jax.tree_util.tree_leaves(mid), jax.tree_util.tree_leaves(t)):
        np.testing.assert_allclose(np.asarray(a), alpha * np.asarray(b), rtol=1e-5, atol=1e-6)


@given(trees)
def test_l1_metric_properties(t):
    """Symmetry, identity, and triangle inequality of the Eq. 1 distance."""
    shifted = P.tree_scale(t, 1.5)
    third = P.tree_add(t, P.tree_scale(t, -0.25))
    d_ab = float(P.tree_l1(t, shifted))
    d_ba = float(P.tree_l1(shifted, t))
    assert np.isclose(d_ab, d_ba, rtol=1e-6)
    assert float(P.tree_l1(t, t)) == 0.0
    d_ac = float(P.tree_l1(t, third))
    d_cb = float(P.tree_l1(third, shifted))
    assert d_ab <= d_ac + d_cb + 1e-4


@given(trees)
def test_weighted_mean_convexity(t):
    """Weighted mean of {t, 3t} with weights (w, 1-w) stays within hull."""
    t3 = P.tree_scale(t, 3.0)
    m = P.tree_weighted_mean([t, t3], [1.0, 3.0])
    for leaf, l1, l3 in zip(
        jax.tree_util.tree_leaves(m), jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t3)
    ):
        lo = np.minimum(np.asarray(l1), np.asarray(l3)) - 1e-5
        hi = np.maximum(np.asarray(l1), np.asarray(l3)) + 1e-5
        assert (np.asarray(leaf) >= lo).all() and (np.asarray(leaf) <= hi).all()


@given(trees)
def test_weighted_mean_of_identical_is_identity(t):
    m = P.tree_weighted_mean([t, t, t], [1, 5, 2])
    for a, b in zip(jax.tree_util.tree_leaves(m), jax.tree_util.tree_leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


@given(trees, st.floats(-2, 2))
def test_axpy_definition(t, alpha):
    y = P.tree_scale(t, 0.5)
    out = P.tree_axpy(alpha, t, y)
    for o, x, yy in zip(
        jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(y)
    ):
        np.testing.assert_allclose(
            np.asarray(o), alpha * np.asarray(x) + np.asarray(yy), rtol=1e-5, atol=1e-5
        )


@given(trees)
def test_l2_vs_numpy(t):
    vec = np.asarray(P.tree_flat_vector(t))
    np.testing.assert_allclose(float(P.tree_l2(t)), np.linalg.norm(vec), rtol=1e-5)
