"""Parity sweeps for the batched server-plane kernels: interpret-mode Pallas
and the jit'd ops wrappers (under both REPRO_KERNELS settings) against the
pure-jnp oracles in ref.py — including ragged cluster sizes and the
single-member-cluster edge case."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.assign_lerp import assign_and_lerp
from repro.kernels.chi2_feedback import chi2_feedback_segmented
from repro.kernels.l1_pairwise import l1_distance_pairwise


# ------------------------------------------------------------- l1 pairwise
@pytest.mark.parametrize("m,c,n", [(1, 1, 1), (3, 5, 100), (9, 2, 700), (17, 9, 300), (8, 8, 8192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l1_pairwise_matches_ref(m, c, n, dtype):
    xs = jax.random.normal(jax.random.PRNGKey(m * 13 + n), (m, n), dtype)
    cs = jax.random.normal(jax.random.PRNGKey(c * 7 + n), (c, n), dtype)
    got = np.asarray(l1_distance_pairwise(xs, cs, interpret=True))
    want = np.asarray(ref.l1_distance_pairwise_ref(xs, cs))
    np.testing.assert_allclose(got, want, rtol=3e-3 if dtype == jnp.bfloat16 else 1e-5)


def test_l1_pairwise_crosses_block_boundaries():
    xs = jax.random.normal(jax.random.PRNGKey(0), (5, 700))
    cs = jax.random.normal(jax.random.PRNGKey(1), (3, 700))
    got = np.asarray(
        l1_distance_pairwise(xs, cs, block_m=2, block_c=2, block_n=128, interpret=True)
    )
    want = np.asarray(ref.l1_distance_pairwise_ref(xs, cs))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_l1_pairwise_self_diagonal_is_zero():
    vs = jax.random.normal(jax.random.PRNGKey(2), (6, 256))
    d = np.asarray(l1_distance_pairwise(vs, vs, interpret=True))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-5)
    np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-5)

    # agreement with the one-vs-many streaming kernel, row by row
    from repro.kernels.l1_distance import l1_distance

    for i in range(6):
        np.testing.assert_allclose(
            d[i], np.asarray(l1_distance(vs[i], vs, interpret=True)), rtol=1e-5, atol=1e-5
        )


# ------------------------------------------------------------ assign + lerp
@pytest.mark.parametrize("c,n", [(1, 100), (5, 300), (8, 4096), (3, 70000)])
@pytest.mark.parametrize("beta", [0.0, 0.25, 1.0])
def test_assign_and_lerp_matches_ref(c, n, beta):
    u = jax.random.normal(jax.random.PRNGKey(n + c), (n,))
    cs = jax.random.normal(jax.random.PRNGKey(n - c), (c, n))
    d, i, b = assign_and_lerp(u, cs, beta, interpret=True)
    dr, ir, br = ref.assign_and_lerp_ref(u, cs, beta)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), rtol=1e-5)
    assert int(i) == int(ir)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-5, atol=1e-6)


def test_assign_and_lerp_blends_only_the_argmin_center():
    u = jnp.full((256,), 2.0)
    cs = jnp.stack([jnp.zeros(256), jnp.full((256,), 1.9), jnp.full((256,), 100.0)])
    d, i, b = assign_and_lerp(u, cs, 0.5, interpret=True)
    assert int(i) == 1
    np.testing.assert_allclose(np.asarray(b), 0.5 * 1.9 + 0.5 * 2.0, rtol=1e-6)
    assert float(d[0]) == pytest.approx(2.0 * 256, rel=1e-6)


# --------------------------------------------------------- segmented chi2
def _feedback_batch(m, j, seed=0):
    k = jax.random.PRNGKey(seed)
    f_pred = jax.random.uniform(k, (m, j)) * 100
    f_true = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m, j)) * 100 + 1.0
    s_soft = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(seed + 2), (m, j)), axis=-1)
    return f_pred, f_true, s_soft


@pytest.mark.parametrize(
    "sizes", [[1], [3, 1, 7], [5, 5], [2, 1, 1, 9, 4]],
    ids=["single-member", "ragged", "even", "very-ragged"],
)
def test_chi2_segmented_matches_ref(sizes):
    m, s = sum(sizes), len(sizes)
    f_pred, f_true, s_soft = _feedback_batch(m, 6)
    seg_ids = jnp.asarray(np.repeat(np.arange(s), sizes), jnp.int32)
    onehot = (seg_ids[:, None] == jnp.arange(s)[None, :]).astype(jnp.float32)
    g, seg_sum = chi2_feedback_segmented(f_pred, f_true, s_soft, onehot, interpret=True)
    g_ref, seg_ref = ref.chi2_feedback_segmented_ref(f_pred, f_true, s_soft, onehot)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(seg_sum), np.asarray(seg_ref), rtol=2e-5, atol=1e-6)
    # segment sums really are the per-cluster totals of g
    want = np.asarray([np.asarray(g_ref)[seg_ids == i].sum() for i in range(s)])
    np.testing.assert_allclose(np.asarray(seg_sum), want, rtol=1e-4, atol=1e-5)


def test_chi2_segmented_crosses_m_blocks():
    m, s = 600, 3  # crosses the 256-row block boundary
    f_pred, f_true, s_soft = _feedback_batch(m, 4, seed=9)
    seg_ids = jnp.asarray(np.arange(m) % s, jnp.int32)
    onehot = (seg_ids[:, None] == jnp.arange(s)[None, :]).astype(jnp.float32)
    g, seg_sum = chi2_feedback_segmented(f_pred, f_true, s_soft, onehot, interpret=True)
    g_ref, seg_ref = ref.chi2_feedback_segmented_ref(f_pred, f_true, s_soft, onehot)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(seg_sum), np.asarray(seg_ref), rtol=1e-4, atol=1e-4)


# ------------------------------------------- ops wrappers, both backends
@pytest.fixture(params=["ref", "pallas"])
def force_backend(request, monkeypatch):
    monkeypatch.setattr(ops, "_FORCE", request.param)
    return request.param


def test_ops_l1_pairwise_both_backends(force_backend):
    xs = jax.random.normal(jax.random.PRNGKey(3), (4, 500))
    cs = jax.random.normal(jax.random.PRNGKey(4), (6, 500))
    got = np.asarray(ops.l1_distance_pairwise(xs, cs))
    np.testing.assert_allclose(got, np.asarray(ref.l1_distance_pairwise_ref(xs, cs)), rtol=1e-5)


def test_ops_assign_and_lerp_both_backends(force_backend):
    u = jax.random.normal(jax.random.PRNGKey(5), (300,))
    cs = jax.random.normal(jax.random.PRNGKey(6), (4, 300))
    d, i, b = ops.assign_and_lerp(u, cs, 0.25)
    dr, ir, br = ref.assign_and_lerp_ref(u, cs, 0.25)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), rtol=1e-5)
    assert int(i) == int(ir)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-5, atol=1e-6)


def test_ops_chi2_feedback_all_both_backends(force_backend):
    sizes = [4, 1, 6]
    m, s = sum(sizes), len(sizes)
    f_pred, f_true, s_soft = _feedback_batch(m, 5, seed=20)
    seg_ids = jnp.asarray(np.repeat(np.arange(s), sizes), jnp.int32)
    g, seg_sum = ops.chi2_feedback_all(f_pred, f_true, s_soft, seg_ids, num_segments=s)
    onehot = (seg_ids[:, None] == jnp.arange(s)[None, :]).astype(jnp.float32)
    g_ref, seg_ref = ref.chi2_feedback_segmented_ref(f_pred, f_true, s_soft, onehot)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(seg_sum), np.asarray(seg_ref), rtol=1e-4, atol=1e-5)
