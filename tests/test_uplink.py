"""REPRO_UPLINK: fleet-batched compressed uplinks with exact byte billing.

Covers the batched codec primitives (batch == B independent single-row
codecs, EF residual identities, ragged int8 round-trips with pad-blind
scales), the :class:`UplinkCodec` state machine (anchor advancement, fused
cohort == per-client encodes, checkpoint roundtrips incl. the pre-attach
pending replay), exact payload byte accounting through the simulator on
both the async and sync loops (``up_bytes == up_events * payload_bytes``),
and the parity discipline: ``REPRO_UPLINK=none`` is bitwise the default
trajectory, compressed runs agree loop-vs-fleet and coalesced-vs-per-event.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.network import NetworkModel
from repro.fl.uplink import (
    UplinkCodec,
    UplinkConfig,
    default_uplink,
    resolve_uplink,
    seed_template,
    uplink_config_from_env,
)
from repro.optim.compression import (
    ef_topk_batch,
    ef_topk_step,
    ErrorFeedbackState,
    int8_compress,
    int8_compress_batch,
    int8_decompress,
    int8_decompress_batch,
    payload_bytes,
    topk_compress,
    topk_compress_batch,
    topk_scatter_batch,
    wire_bytes,
)


def _mat(b=3, n=40, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))


# --------------------------------------------------------------------------
# batched codec primitives
# --------------------------------------------------------------------------


class TestBatchedCodecs:
    def test_topk_batch_matches_single(self):
        mat = _mat()
        idx, vals = topk_compress_batch(mat, 7)
        for j in range(mat.shape[0]):
            p = topk_compress(mat[j], 7)
            np.testing.assert_array_equal(np.asarray(idx[j]), np.asarray(p.indices))
            np.testing.assert_array_equal(np.asarray(vals[j]), np.asarray(p.values))

    def test_topk_scatter_roundtrip(self):
        mat = _mat()
        idx, vals = topk_compress_batch(mat, mat.shape[1])  # keep everything
        np.testing.assert_array_equal(
            np.asarray(topk_scatter_batch(idx, vals, mat.shape[1])), np.asarray(mat)
        )

    def test_ef_batch_matches_single_step(self):
        mat, res = _mat(seed=1), _mat(seed=2)
        _, _, sent, new_r = ef_topk_batch(mat, res, 5)
        for j in range(mat.shape[0]):
            payload, state = ef_topk_step(mat[j], ErrorFeedbackState(res[j]), 5)
            np.testing.assert_array_equal(
                np.asarray(sent[j]),
                np.asarray(jnp.zeros(mat.shape[1]).at[payload.indices].set(payload.values)),
            )
            np.testing.assert_array_equal(np.asarray(new_r[j]), np.asarray(state.residual))

    def test_ef_residual_identity(self):
        """sent + new_residual == mat + residual BITWISE: kept coordinates
        subtract to exact zero, dropped ones pass through untouched — the
        invariant that makes EF lossless in the long run."""
        mat, res = _mat(seed=3), _mat(seed=4)
        _, _, sent, new_r = ef_topk_batch(mat, res, 5)
        np.testing.assert_array_equal(np.asarray(sent + new_r), np.asarray(mat + res))

    def test_ef_accumulates_everything(self):
        """Over rounds, cumulative sent == cumulative input - final residual:
        nothing is permanently lost to sparsification."""
        n = 32
        rng = np.random.default_rng(7)
        res = jnp.zeros((1, n))
        total_in = np.zeros(n, np.float64)
        total_sent = np.zeros(n, np.float64)
        for r in range(6):
            mat = jnp.asarray(rng.normal(size=(1, n)).astype(np.float32))
            _, _, sent, res = ef_topk_batch(mat, res, 4)
            total_in += np.asarray(mat[0], np.float64)
            total_sent += np.asarray(sent[0], np.float64)
        np.testing.assert_allclose(
            total_sent + np.asarray(res[0], np.float64), total_in, atol=1e-5
        )

    @pytest.mark.parametrize("n,chunk", [(40, 8), (41, 8), (7, 16), (100, 33)])
    def test_int8_ragged_roundtrip_error_bound(self, n, chunk):
        """Quantization error stays within half a scale step per coordinate,
        including the final ragged chunk."""
        rng = np.random.default_rng(n)
        mat = jnp.asarray(rng.normal(size=(2, n)).astype(np.float32))
        q, scales = int8_compress_batch(mat, chunk)
        dec = int8_decompress_batch(q, scales, chunk)
        n_chunks = -(-n // chunk)
        assert q.shape == (2, n) and scales.shape == (2, n_chunks)
        per_coord_bound = np.repeat(np.asarray(scales), chunk, axis=1)[:, :n]
        assert np.all(np.abs(np.asarray(dec - mat)) <= 0.5 * per_coord_bound + 1e-7)

    def test_int8_scales_ignore_padding(self):
        """The ragged final chunk's scale comes from its REAL entries only:
        a vector whose tail chunk holds one small value must get a small
        tail scale regardless of how much padding fills the chunk."""
        v = jnp.asarray([4.0, -2.0, 1.0, 3.0, 0.25], jnp.float32)  # chunk=4: tail holds 0.25
        p = int8_compress(v, chunk=4)
        np.testing.assert_allclose(
            np.asarray(p.scales), [4.0 / 127.0 + 1e-12, 0.25 / 127.0 + 1e-12], rtol=1e-6
        )
        # and the round-trip recovers the tail value at tail precision
        dec = int8_decompress(p)
        assert abs(float(dec[4]) - 0.25) <= 0.5 * float(p.scales[1]) + 1e-9

    def test_int8_batch_matches_single(self):
        mat = _mat(b=3, n=41, seed=9)
        q, scales = int8_compress_batch(mat, 8)
        for j in range(mat.shape[0]):
            p = int8_compress(mat[j], chunk=8)
            np.testing.assert_array_equal(np.asarray(q[j]), np.asarray(p.q))
            np.testing.assert_array_equal(np.asarray(scales[j]), np.asarray(p.scales))

    @pytest.mark.parametrize(
        "mode,n,kw",
        [("topk", 100, dict(k=10)), ("topk", 5, dict(k=10)),
         ("int8", 100, dict(chunk=32)), ("int8", 96, dict(chunk=32)), ("int8", 1, dict(chunk=512))],
    )
    def test_wire_bytes_matches_emitted_payload(self, mode, n, kw):
        vec = jnp.asarray(np.random.default_rng(0).normal(size=n).astype(np.float32))
        payload = (
            topk_compress(vec, kw["k"]) if mode == "topk" else int8_compress(vec, kw["chunk"])
        )
        assert wire_bytes(mode, n, **kw) == payload_bytes(payload)


# --------------------------------------------------------------------------
# config / knobs
# --------------------------------------------------------------------------


class TestKnobs:
    def test_env_parsing(self, monkeypatch):
        monkeypatch.delenv("REPRO_UPLINK", raising=False)
        assert default_uplink() == "none"
        assert uplink_config_from_env().mode == "none"
        monkeypatch.setenv("REPRO_UPLINK", " TopK ")
        monkeypatch.setenv("REPRO_UPLINK_K", "0.25")
        monkeypatch.setenv("REPRO_UPLINK_CHUNK", "64")
        cfg = uplink_config_from_env()
        assert (cfg.mode, cfg.k, cfg.chunk) == ("topk", 0.25, 64)
        # constructor arg wins over env for the mode, keeps env geometry
        assert resolve_uplink("int8").mode == "int8"
        assert resolve_uplink("int8").chunk == 64
        assert resolve_uplink(None).mode == "topk"
        assert resolve_uplink(UplinkConfig(mode="int8", chunk=7)).chunk == 7

    def test_invalid_mode_raises(self):
        with pytest.raises(ValueError):
            UplinkConfig(mode="gzip")
        with pytest.raises(ValueError):
            UplinkConfig(k=0.0)
        with pytest.raises(ValueError):
            UplinkConfig(chunk=0)

    def test_resolve_k(self):
        cfg = UplinkConfig(mode="topk", k=0.1)
        assert cfg.resolve_k(100) == 10
        assert cfg.resolve_k(3) == 1
        assert UplinkConfig(mode="topk", k=17).resolve_k(100) == 17
        assert UplinkConfig(mode="topk", k=17).resolve_k(5) == 5
        assert UplinkConfig(chunk=512).resolve_chunk(36) == 36


# --------------------------------------------------------------------------
# UplinkCodec
# --------------------------------------------------------------------------


def _template(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
    }


def _models(cids, seed=1):
    rng = np.random.default_rng(seed)
    out = {}
    for c in cids:
        out[c] = {
            "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
        }
    return out


def _codec(mode="topk", cids=(0, 1, 2, 3), **kw):
    cfg = UplinkConfig(mode=mode, **kw)
    codec = UplinkCodec(_template(), list(cids), cfg)
    codec.seed({c: _template() for c in cids})
    return codec


def tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestUplinkCodec:
    def test_none_mode_rejected(self):
        with pytest.raises(ValueError):
            UplinkCodec(_template(), [0], UplinkConfig(mode="none"))

    def test_unseeded_client_raises(self):
        cfg = UplinkConfig(mode="topk")
        codec = UplinkCodec(_template(), [0, 1], cfg)
        codec.seed({0: _template()})
        with pytest.raises(ValueError):
            codec.encode(1, _models([1])[1])

    @pytest.mark.parametrize("mode", ["topk", "int8"])
    def test_cohort_matches_per_client(self, mode):
        """A fused B=3 cohort must be bitwise the three per-client B=1
        encodes (distinct clients' codec rows are independent)."""
        ca, cb = _codec(mode), _codec(mode)
        models = _models([0, 1, 2])
        mat = jnp.stack([ca.spec.flatten(models[c]) for c in (0, 1, 2)])
        recs, nbytes = ca.encode_rows([0, 1, 2], mat)
        assert nbytes == ca.nbytes
        for c in (0, 1, 2):
            rec, nb = cb.encode(c, models[c])
            assert nb == nbytes
            tree_equal(rec, recs[c])
        # the states advanced identically too: next round still agrees
        models2 = _models([0, 1, 2], seed=5)
        mat2 = jnp.stack([ca.spec.flatten(models2[c]) for c in (0, 1, 2)])
        recs2, _ = ca.encode_rows([0, 1, 2], mat2)
        for c in (0, 1, 2):
            rec, _ = cb.encode(c, models2[c])
            tree_equal(rec, recs2[c])

    @pytest.mark.parametrize("mode", ["topk", "int8"])
    def test_anchor_advances_to_reconstruction(self, mode):
        codec = _codec(mode)
        rec, _ = codec.encode(2, _models([2])[2])
        anchor = codec.plane.to_pytree(codec._anchor_row[codec.index[2]])
        tree_equal(rec, anchor)

    def test_identity_when_k_is_dim(self):
        """topk with k == dim transmits the whole delta: the residual is
        exactly zero and the reconstruction anchor + (m - anchor) recovers
        the trained model to 1 ulp (float add/sub, not bitwise)."""
        codec = _codec("topk", k=10_000)  # clamps to dim
        m = _models([1])[1]
        rec, nbytes = codec.encode(1, m)
        resid = codec.plane.to_pytree(codec._resid_row[codec.index[1]])
        assert all(not np.any(np.asarray(x)) for x in jax.tree_util.tree_leaves(resid))
        for x, y in zip(jax.tree_util.tree_leaves(rec), jax.tree_util.tree_leaves(m)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-7)
        assert nbytes == codec.dim * 8

    def test_launches_flat_in_cohort_size(self):
        codec = _codec("topk", cids=list(range(8)))
        models = _models(range(8))
        for cohort in ([0], [1, 2], [3, 4, 5], [6, 7]):
            mat = jnp.stack([codec.spec.flatten(models[c]) for c in cohort])
            codec.encode_rows(cohort, mat)
        assert codec.launches == 4  # one per cohort, regardless of B

    @pytest.mark.parametrize("mode", ["topk", "int8"])
    def test_nbytes_static_and_exact(self, mode):
        codec = _codec(mode, chunk=16)
        assert codec.nbytes == payload_bytes(codec.payload_template())
        want = (
            codec.k * 8 if mode == "topk" else codec.dim + (-(-codec.dim // codec.chunk)) * 4
        )
        assert codec.nbytes == want

    def test_seed_skips_already_seeded(self):
        codec = _codec("topk", cids=[0, 1])
        rec, _ = codec.encode(0, _models([0])[0])
        codec.seed({0: _template(seed=9), 1: _template(seed=9)})  # must NOT clobber 0
        anchor = codec.plane.to_pytree(codec._anchor_row[codec.index[0]])
        tree_equal(rec, anchor)

    @pytest.mark.parametrize("mode", ["topk", "int8"])
    def test_state_roundtrip(self, mode):
        c1 = _codec(mode)
        models = _models([0, 1, 2, 3])
        mat = jnp.stack([c1.spec.flatten(models[c]) for c in (0, 1, 2, 3)])
        c1.encode_rows([0, 1, 2, 3], mat)
        tree, meta = c1.state_dict()
        assert meta["mode"] == mode and meta["clients"] == ["0", "1", "2", "3"]

        c2 = UplinkCodec(_template(), [0, 1, 2, 3], UplinkConfig(mode=mode))
        c2.load_state(tree, meta)
        # restored codec continues bitwise where c1 would
        models2 = _models([0, 1, 2, 3], seed=11)
        mat2 = jnp.stack([c1.spec.flatten(models2[c]) for c in (0, 1, 2, 3)])
        r1, _ = c1.encode_rows([0, 1, 2, 3], mat2)
        r2, _ = c2.encode_rows([0, 1, 2, 3], mat2)
        for a, b in zip(r1, r2):
            tree_equal(a, b)

    def test_state_restore_unknown_clients_skipped(self):
        c1 = _codec("topk", cids=[0, 1])
        c1.encode(0, _models([0])[0])
        tree, meta = c1.state_dict()
        c2 = UplinkCodec(_template(), [1, 7], UplinkConfig(mode="topk"))
        c2.load_state(tree, meta)  # client 0 dropped, 7 unseeded
        with pytest.raises(ValueError):
            c2.encode(7, _models([7])[7])
        c2.encode(1, _models([1])[1])  # 1 restored fine

    def test_mode_mismatch_raises(self):
        tree, meta = _codec("int8").state_dict()
        c2 = UplinkCodec(_template(), [0], UplinkConfig(mode="topk"))
        with pytest.raises(ValueError):
            c2.load_state(tree, meta)

    def test_seed_template_structure(self):
        tree, meta = _codec("topk").state_dict()
        tpl = seed_template(meta, _template())
        assert set(tpl) == {"anchors", "residuals"}
        assert set(tpl["anchors"]) == {"0", "1", "2", "3"}
        assert set(seed_template(_codec("int8").state_dict()[1], _template())) == {"anchors"}

    def test_server_checkpoint_carries_codec(self, tmp_path):
        """Codec rows ride the EchoPFL server checkpoint: state_dict gains
        an "uplink" section, state_template covers it, and a load_state
        BEFORE the next run's codec exists replays at attach time."""
        from repro.checkpoint.checkpointer import restore_pytree, save_pytree
        from repro.core.server import EchoPFLServer

        init = _template()
        srv = EchoPFLServer(init, num_initial_clusters=2, seed=0)
        codec = _codec("topk")
        srv.attach_uplink_codec(codec)
        models = _models([0, 1, 2, 3])
        for c in (0, 1, 2):
            rec, _ = codec.encode(c, models[c])
            srv.handle_upload(c, rec, 0, 16, t=float(c))
        tree, meta = srv.state_dict()
        assert "uplink" in tree and meta["uplink"]["mode"] == "topk"
        save_pytree(str(tmp_path / "srv"), tree, extra=meta)

        srv2 = EchoPFLServer(init, num_initial_clusters=2, seed=0)
        raw_meta = restore_pytree(str(tmp_path / "srv"), like=None)[1]
        template = srv2.state_template(raw_meta)
        assert "uplink" in template
        tree_r, meta_r = restore_pytree(str(tmp_path / "srv"), like=template)
        srv2.load_state(tree_r, meta_r)  # no codec yet: stashes pending
        codec2 = UplinkCodec(_template(), [0, 1, 2, 3], UplinkConfig(mode="topk"))
        codec2.seed({c: _template(seed=9) for c in (0, 1, 2, 3)})  # pre-seed
        srv2.attach_uplink_codec(codec2)  # replay clobbers the fresh seed
        t1, m1 = codec.state_dict()
        t2, m2 = codec2.state_dict()
        assert m1 == m2
        tree_equal(t1, t2)


# --------------------------------------------------------------------------
# simulator integration: billing + parity
# --------------------------------------------------------------------------


def _run(uplink, *, strategy="echopfl", backend="fleet", window=0.0, seed=3,
         num_clients=5, max_time=300.0, **kw):
    from repro.fl.experiment import build_clients, build_strategy
    from repro.fl.simulator import Simulator

    task, clients, init = build_clients("har", num_clients, seed, samples_per_client=48)
    strat = build_strategy(strategy, init, clients, seed=seed, **kw)
    sim = Simulator(
        clients, strat, network=NetworkModel(), eval_interval=60.0, seed=seed,
        coalesce_window=window, client_backend=backend, uplink=uplink,
    )
    return sim.run(max_time=max_time), sim


def _assert_bitwise(a, b):
    assert a.curve == b.curve
    assert a.per_client_acc == b.per_client_acc
    assert (a.up_bytes, a.down_bytes, a.up_events, a.down_events) == (
        b.up_bytes, b.down_bytes, b.up_events, b.down_events)
    assert a.duration == b.duration


class TestSimulatorUplink:
    def test_none_mode_is_bitwise_identity(self, monkeypatch):
        monkeypatch.delenv("REPRO_UPLINK", raising=False)
        r0, s0 = _run(None)
        r1, s1 = _run("none")
        monkeypatch.setenv("REPRO_UPLINK", "none")
        r2, s2 = _run(None)
        assert s0._codec is None and s1._codec is None and s2._codec is None
        _assert_bitwise(r0, r1)
        _assert_bitwise(r0, r2)
        assert r0.up_raw_bytes == r0.up_bytes
        assert "uplink_ratio" not in r0.summary()

    @pytest.mark.parametrize("mode", ["topk", "int8"])
    def test_async_billing_exact(self, mode):
        """Every async upload bills exactly payload_bytes of the emitted
        payload shape; dense-equivalent bytes tracked alongside."""
        rep, sim = _run(mode)
        codec = sim._codec
        assert rep.up_events > 0
        assert rep.up_bytes == rep.up_events * payload_bytes(codec.payload_template())
        from repro.fl.simulator import model_bytes

        dense = model_bytes(sim.strategy.init_params)
        assert rep.up_raw_bytes == rep.up_events * dense
        s = rep.summary()
        assert s["uplink_ratio"] == round(rep.up_bytes / rep.up_raw_bytes, 4)
        assert rep.extra["uplink"]["payload_bytes"] == codec.nbytes
        # every upload ran through a fused encode launch (B=1 per event here)
        assert codec.launches == rep.up_events

    @pytest.mark.parametrize("mode", ["topk", "int8"])
    def test_sync_billing_exact(self, mode):
        rep, sim = _run(mode, strategy="fedavg", max_time=240.0)
        codec = sim._codec
        assert rep.up_events > 0
        assert rep.up_bytes == rep.up_events * payload_bytes(codec.payload_template())

    def test_compressed_degenerate_window_bitwise(self):
        r0, _ = _run("topk", window=0.0)
        r1, _ = _run("topk", window=1e-9)
        _assert_bitwise(r0, r1)

    def test_compressed_window_parity(self):
        """Real coalescing windows keep exact event counts/bytes/eval grid
        under compression; values agree to eval tolerance."""
        r0, _ = _run("topk", window=0.0)
        r2, _ = _run("topk", window=60.0)
        assert [t for t, _ in r0.curve] == [t for t, _ in r2.curve]
        assert r0.up_events == r2.up_events
        assert r0.up_bytes == r2.up_bytes
        assert r0.duration == r2.duration
        np.testing.assert_allclose(
            [x for _, x in r0.curve], [x for _, x in r2.curve], atol=0.25)

    def test_compressed_coalesced_uses_fused_cohorts(self):
        """With a real window the codec encodes whole cohorts: fewer fused
        launches than upload events, same exact billing."""
        rep, sim = _run("topk", window=60.0)
        assert rep.up_events > sim._codec.launches  # cohorts actually batched
        assert rep.up_bytes == rep.up_events * sim._codec.nbytes

    def test_compressed_loop_fleet_agree(self):
        rf, _ = _run("topk")
        rl, _ = _run("topk", backend="loop")
        assert rf.up_events == rl.up_events
        assert rf.up_bytes == rl.up_bytes
        assert [t for t, _ in rf.curve] == [t for t, _ in rl.curve]
        np.testing.assert_allclose(
            [x for _, x in rf.curve], [x for _, x in rl.curve], atol=0.25)

    def test_compressed_fedasyn_coalesced(self):
        """The ported FedAsyn ingests compressed cohorts too — billing stays
        exact through its scan-chain handle_uploads."""
        r0, s0 = _run("int8", strategy="fedasyn", window=0.0)
        r2, s2 = _run("int8", strategy="fedasyn", window=60.0)
        assert r0.up_bytes == r0.up_events * s0._codec.nbytes
        assert r2.up_bytes == r2.up_events * s2._codec.nbytes
        assert r0.up_events == r2.up_events

    def test_lm_delta_billing_compressed(self):
        """The PR 7 LoRA-delta stress case: ~9KB deltas compress per upload
        at exactly wire_bytes of the delta's flat dim."""
        from repro.common.pytrees import flatten_spec
        from repro.fl.lm_task import default_lm_task, run_lm_experiment

        task = default_lm_task()
        dim = flatten_spec(task.init_params(jax.random.PRNGKey(0))).dim
        k = UplinkConfig(mode="topk").resolve_k(dim)
        _, _, _, rep = run_lm_experiment(
            "fedavg", num_clients=4, rounds=2, seq_len=16, n_train=4, n_test=2,
            local_epochs=1, eval_interval=60.0, uplink="topk",
        )
        assert rep.up_events > 0
        assert rep.up_bytes == rep.up_events * wire_bytes("topk", dim, k=k)
        assert rep.up_raw_bytes > rep.up_bytes
