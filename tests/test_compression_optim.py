"""Gradient compression (uplink) + custom optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim.compression import (
    ErrorFeedbackState,
    ef_topk_step,
    int8_compress,
    int8_decompress,
    payload_bytes,
    topk_compress,
    topk_decompress,
)
from repro.optim.optimizers import adamw, apply_updates, clip_by_global_norm, momentum, sgd
from repro.optim.adafactor import adafactor

vecs = st.integers(0, 2**16).map(
    lambda seed: jnp.asarray(np.random.default_rng(seed).normal(size=257), jnp.float32)
)


# --------------------------------------------------------------- compression
@given(vecs, st.integers(1, 257))
@settings(deadline=None, max_examples=20)
def test_topk_keeps_largest_magnitudes(v, k):
    payload = topk_compress(v, k)
    dense = np.asarray(topk_decompress(payload))
    vv = np.asarray(v)
    kept = np.flatnonzero(dense)
    assert len(kept) <= k
    if k < len(vv):
        thresh = np.sort(np.abs(vv))[-k]
        assert (np.abs(vv[kept]) >= thresh - 1e-6).all()
    np.testing.assert_allclose(dense[kept], vv[kept])


@given(vecs)
@settings(deadline=None, max_examples=20)
def test_error_feedback_is_lossless_over_time(v):
    """EF invariant: sum(sent) + residual == sum(inputs) — nothing dropped
    by top-k is ever permanently lost."""
    state = ErrorFeedbackState(residual=jnp.zeros_like(v))
    total_sent = jnp.zeros_like(v)
    for _ in range(5):
        payload, state = ef_topk_step(v, state, k=32)
        total_sent = total_sent + topk_decompress(payload)
    np.testing.assert_allclose(
        np.asarray(total_sent + state.residual), np.asarray(5 * v), rtol=2e-4, atol=2e-4
    )


@given(vecs)
@settings(deadline=None, max_examples=20)
def test_int8_roundtrip_error_bound(v):
    payload = int8_compress(v, chunk=64)
    back = np.asarray(int8_decompress(payload))
    vv = np.asarray(v)
    scale = np.abs(vv).reshape(-1)  # per chunk bound: max/127 * 0.5
    chunk_max = np.max(np.abs(np.pad(vv, (0, (-len(vv)) % 64)).reshape(-1, 64)), axis=1)
    bound = np.repeat(chunk_max / 127.0 * 0.5 + 1e-6, 64)[: len(vv)]
    assert (np.abs(back - vv) <= bound + 1e-5).all()


def test_payload_bytes_accounting():
    v = jnp.arange(1000, dtype=jnp.float32)
    t = topk_compress(v, 100)
    assert payload_bytes(t) == 100 * 8
    q = int8_compress(v, chunk=256)
    assert payload_bytes(q) == 1000 + 4 * 4  # 4 chunks
    assert payload_bytes(q) < 4 * v.size  # beats raw fp32


# ----------------------------------------------------------------- optimizers
QUAD_TARGET = jnp.asarray([1.5, -2.0, 0.5, 3.0])


def _train(opt, steps=120):
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - QUAD_TARGET) ** 2))(params)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state

    for _ in range(steps):
        params, state = step(params, state)
    return np.asarray(params["w"])


@pytest.mark.parametrize(
    "opt,tol",
    [
        (sgd(0.1), 1e-2),
        (momentum(0.05, 0.9), 1e-2),
        (adamw(0.1, weight_decay=0.0), 5e-2),
        (adafactor(0.3), 0.25),
    ],
    ids=["sgd", "momentum", "adamw", "adafactor"],
)
def test_optimizers_minimize_quadratic(opt, tol):
    w = _train(opt)
    np.testing.assert_allclose(w, np.asarray(QUAD_TARGET), atol=tol, rtol=0.05)


def test_adamw_decoupled_weight_decay():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.asarray([0.0])}, state, params)
    assert float(updates["w"][0]) < 0  # pure decay pulls toward zero


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-6)
    small = {"a": jnp.asarray([0.3, 0.4])}
    np.testing.assert_allclose(
        np.asarray(clip_by_global_norm(small, 1.0)["a"]), [0.3, 0.4], rtol=1e-6
    )


def test_adafactor_memory_is_sublinear():
    """Factored second moment: for a (m, n) weight the state holds m + n
    accumulators, not m*n — the reason the 400B configs fit."""
    opt = adafactor(1e-3)
    params = {"w": jnp.zeros((256, 128))}
    state = opt.init(params)
    leaves = jax.tree_util.tree_leaves(state)
    total = sum(l.size for l in leaves if hasattr(l, "size"))
    assert total <= 256 + 128 + 1  # factored (rows + cols + step), not rows*cols
