"""Launch layer: input specs, sharding construction, the loop-aware HLO cost
model, and a 1-device end-to-end lower+compile of a reduced cell."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY, SHAPES
from repro.configs.base import reduced_config
from repro.launch import specs as SP
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.hlo_cost import HloCostModel, analyze
from repro.launch.mesh import batch_axes, make_smoke_mesh
from repro.launch.shardings import batch_shardings, param_shardings


def test_input_specs_are_abstract():
    cfg = ARCH_REGISTRY["llama3-405b"]  # 405B: would OOM if actually allocated
    spec = SP.input_specs(cfg, SHAPES["train_4k"])
    for leaf in jax.tree_util.tree_leaves(spec):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert spec["batch"]["tokens"].shape == (256, 4096)


def test_embeds_input_archs_get_embedding_specs():
    for name in ("pixtral-12b", "hubert-xlarge"):
        cfg = ARCH_REGISTRY[name]
        spec = SP.input_specs(cfg, SHAPES["train_4k"])
        assert "embeds" in spec["batch"]
        assert spec["batch"]["embeds"].shape[-1] == cfg.d_model


def test_param_count_specs_match_analytic():
    """eval_shape param count within 2% of the analytic formula (catches
    drift between config math and actual model structure)."""
    for name in ("llama3.2-1b", "gemma2-2b", "command-r-35b"):
        cfg = ARCH_REGISTRY[name]
        exact = SP.model_param_count(cfg)
        analytic = cfg.param_count()
        assert abs(exact - analytic) / exact < 0.02, name


def test_effective_microbatches_divisibility():
    cfg = ARCH_REGISTRY["llama3-405b"]
    shape = SHAPES["train_4k"]  # global_batch 256
    import dataclasses

    for want, dp in [(8, 16), (7, 16), (1, 256), (3, 8)]:
        cfg2 = dataclasses.replace(cfg, train=dataclasses.replace(cfg.train, microbatches=want))
        n = SP.effective_microbatches(cfg2, shape, dp)
        assert shape.global_batch % n == 0
        assert (shape.global_batch // n) % dp == 0
        assert n <= max(want, 1)


def test_smoke_mesh_cell_compiles():
    """Reduced config through the *production* sharding path on the 1-device
    mesh: in_shardings with named axes must lower + compile."""
    from repro.models.steps import make_train_step

    cfg = reduced_config(ARCH_REGISTRY["llama3.2-1b"])
    mesh = make_smoke_mesh()
    state = SP.state_specs(cfg, jnp.float32)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
    }
    state_sh = state._replace(
        params=param_shardings(cfg, mesh, state.params),
        opt_state=param_shardings(cfg, mesh, state.opt_state),
        step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    )
    import dataclasses

    shape = dataclasses.replace(SHAPES["train_4k"], global_batch=4, seq_len=16)
    batch_sh = batch_shardings(cfg, shape, mesh, batch)
    with mesh:
        lowered = jax.jit(make_train_step(cfg), in_shardings=(state_sh, batch_sh)).lower(
            state, batch
        )
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_batch_axes():
    assert batch_axes(make_smoke_mesh()) == ("data",)


# ------------------------------------------------------------- HLO cost model
def test_hlo_cost_matches_xla_on_scan_free_program():
    """On a program with no while loops, the loop-aware model should be in
    the same ballpark as XLA's own cost_analysis for flops."""

    def f(a, b):
        return jnp.tanh(a @ b).sum()

    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0]
    xla_flops = cost["flops"]
    got = analyze(compiled.as_text())
    assert got["flops"] >= 2 * 64 * 128 * 32  # at least the matmul
    assert got["flops"] <= max(xla_flops * 1.5, got["flops"])  # same ballpark


def test_hlo_cost_multiplies_scan_trips():
    """A scanned matmul must count body FLOPs x trip count — the whole point
    of the loop-aware model (XLA counts the body once)."""

    def f(x, ws):
        def body(carry, w):
            return jnp.tanh(carry @ w), 0

        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.zeros((32, 32), jnp.float32)
    ws = jnp.zeros((20, 32, 32), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    got = analyze(compiled.as_text())
    one_layer = 2 * 32 * 32 * 32
    assert got["flops"] >= 20 * one_layer * 0.9, got["flops"]


def test_parse_collective_bytes_on_synthetic_hlo():
    hlo = """
HloModule test
ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %ag = f32[512]{0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[128]{0} all-reduce(%p0), to_apply=%add
  %done = f32[128]{0} copy(%ar)
  ROOT %out = f32[128]{0} add(%done, %p0)
}
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 512 * 4
    assert out["all-reduce"] == 128 * 4
    assert out["count"] == 2


def test_hlo_cost_collectives_bucketed():
    mesh = make_smoke_mesh()

    def f(x):
        return jax.lax.psum(x, "data")

    from jax.experimental.shard_map import shard_map

    x = jnp.ones((4, 8), jnp.float32)
    sm = shard_map(
        f, mesh=mesh,
        in_specs=jax.sharding.PartitionSpec("data"),
        out_specs=jax.sharding.PartitionSpec("data"),
    )
    compiled = jax.jit(sm).lower(x).compile()
    got = analyze(compiled.as_text())
    assert isinstance(got["collectives"], dict)
