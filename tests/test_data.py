"""Federated data substrate: partitioners + synthetic task generators."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.partition import dirichlet_partition, shard_partition
from repro.data.synthetic import TASKS, make_task


@given(
    st.integers(2, 10),  # num_clients
    st.integers(1, 3),   # classes per client
    st.integers(0, 999),
)
@settings(deadline=None, max_examples=15)
def test_shard_partition_class_budget(num_clients, cpc, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 6, size=600)
    parts = shard_partition(labels, num_clients, cpc, rng)
    assert len(parts) == num_clients
    for part in parts:
        assert len(part) > 0
        assert len(np.unique(labels[part])) <= cpc


@given(st.integers(2, 8), st.floats(0.1, 5.0), st.integers(0, 999))
@settings(deadline=None, max_examples=15)
def test_dirichlet_partition_covers_disjointly(num_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, size=800)
    parts = dirichlet_partition(labels, num_clients, alpha, rng)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(np.unique(allidx))  # disjoint
    for p in parts:
        assert len(p) >= 8  # min_size guarantee


@pytest.mark.parametrize("name", sorted(TASKS))
def test_make_task_structure(name):
    rng = np.random.default_rng(0)
    task = make_task(name, num_clients=12, rng=rng, latent_clusters=3, samples_per_client=40)
    spec = TASKS[name]
    assert task.num_clients == 12
    seen_clusters = set()
    for c in task.clients:
        assert c.x_train.shape[1] == spec["dim"]
        assert c.n > 0 and len(c.y_test) > 0
        # non-IID: each client's label support is a small subset
        assert len(np.unique(c.y_train)) <= spec["classes_per_client"]
        seen_clusters.add(c.latent_cluster)
    assert len(seen_clusters) > 1


def test_same_cluster_shares_label_subset():
    """The paper's regime: a latent cluster is a device group sharing a class
    subset (with unbalanced within-class proportions)."""
    rng = np.random.default_rng(1)
    task = make_task("image_recognition", 16, rng, latent_clusters=4, samples_per_client=64)
    by_cluster: dict[int, set] = {}
    for c in task.clients:
        by_cluster.setdefault(c.latent_cluster, set()).update(np.unique(c.y_train).tolist())
    subsets = list(by_cluster.values())
    for s in subsets:
        assert len(s) <= TASKS["image_recognition"]["classes_per_client"]
    assert len({frozenset(s) for s in subsets}) > 1  # distinct subsets across clusters


def test_shift_client_changes_latent_cluster():
    rng = np.random.default_rng(2)
    task = make_task("har", 8, rng, latent_clusters=3, samples_per_client=40)
    victim = 0
    old = task.clients[victim]
    old_cluster = old.latent_cluster
    new_cluster = (old_cluster + 1) % 3
    task.shift_client(victim, new_cluster, rng)
    fresh = task.clients[victim]
    assert fresh.latent_cluster == new_cluster
    assert fresh.x_train.shape == old.x_train.shape
    assert not np.allclose(fresh.x_train, old.x_train)  # resampled under new transform


def test_label_histogram():
    rng = np.random.default_rng(3)
    task = make_task("har", 4, rng, latent_clusters=2, samples_per_client=50)
    c = task.clients[0]
    h = c.label_histogram(6)
    assert h.sum() == len(c.y_train)
    assert h.shape == (6,)
