"""Deterministic fault injection (REPRO_FAULTS): schedule determinism
across backends and async paths, exact retry billing, duplicate and
reorder fences, death-driven plane-row reclamation, the drop-straggler
policy, and mid-run server kill+restore.

The determinism contract under test: every fault decision is keyed by
(seed, kind, client, per-(kind, client) counter), never by a shared
stream — so the loop/fleet backends and the per-event/coalesced loops,
which consult the injector at different wall points, draw the identical
schedule. With faults disabled the simulator never constructs an
injector and clean trajectories stay bitwise-identical (the rest of the
test suite, which runs faults-off, is itself that regression)."""
import math
import os

import numpy as np
import pytest

from repro.fl.experiment import build_clients, build_strategy
from repro.fl.faults import (
    FaultConfig,
    FaultInjector,
    FaultPlan,
    ServerRestartPlan,
    default_fault_config,
    faults_enabled,
    resolve_faults,
)
from repro.fl.network import NetworkModel
from repro.fl.simulator import Simulator, model_bytes


def _run(*, backend="fleet", window=0.0, seed=3, fault_cfg=None, restart=None,
         max_time=600.0, num_clients=6, churn=None, uplink=None, strategy="echopfl"):
    task, clients, init = build_clients("har", num_clients, seed=seed, samples_per_client=48)
    strat = build_strategy(strategy, init, clients, seed=seed)
    faults = None
    if fault_cfg is not None or restart is not None:
        faults = FaultPlan(config=fault_cfg or FaultConfig(), restart=restart)
    sim = Simulator(
        clients, strat, network=NetworkModel(), seed=seed, client_backend=backend,
        coalesce_window=window, churn=churn, uplink=uplink, faults=faults,
    )
    return sim.run_async(max_time=max_time), sim, init


def _assert_bitwise(a, b):
    assert a.curve == b.curve
    assert a.per_client_acc == b.per_client_acc
    assert (a.up_bytes, a.down_bytes, a.up_events, a.down_events) == (
        b.up_bytes, b.down_bytes, b.up_events, b.down_events
    )
    assert a.up_retry_bytes == b.up_retry_bytes
    assert a.duration == b.duration
    assert a.extra.get("faults") == b.extra.get("faults")
    assert a.extra.get("staleness") == b.extra.get("staleness")
    assert a.extra.get("uploads") == b.extra.get("uploads")


_CHAOS = dict(seed=7, crash_rate=0.1, loss_rate=0.25, dup_rate=0.15, reorder_rate=0.15)


# ------------------------------------------------------------- determinism
class TestScheduleDeterminism:
    def test_injector_draws_are_order_independent(self):
        """The same (kind, client) query sequence yields the same schedule
        regardless of how queries to different clients interleave."""
        a = FaultInjector(FaultPlan(config=FaultConfig(**_CHAOS)))
        b = FaultInjector(FaultPlan(config=FaultConfig(**_CHAOS)))
        seq_a = [a.crash(0), a.crash(0), a.crash(1), a.upload_plan(0), a.upload_plan(1)]
        # interleaved differently — per-(kind, client) counters don't care
        b_c1 = b.crash(1)
        b_u1 = b.upload_plan(1)
        b_c0a, b_c0b = b.crash(0), b.crash(0)
        b_u0 = b.upload_plan(0)
        assert seq_a == [b_c0a, b_c0b, b_c1, b_u0, b_u1]

    def test_chaos_degenerate_window_is_bitwise_identical(self):
        """One event per window: the coalesced loop replays the per-event
        loop exactly even under active fault injection — the chaos
        extension of the existing parity suite."""
        cfg = FaultConfig(**_CHAOS)
        r0, _, _ = _run(fault_cfg=cfg)
        r1, _, _ = _run(fault_cfg=cfg, window=1e-9)
        _assert_bitwise(r0, r1)
        assert r0.extra["faults"]["crashes"] > 0
        assert r0.extra["faults"]["retried_uploads"] > 0

    def test_chaos_schedule_identical_loop_vs_fleet(self):
        cfg = FaultConfig(**_CHAOS)
        rf, _, _ = _run(fault_cfg=cfg, backend="fleet")
        rl, _, _ = _run(fault_cfg=cfg, backend="loop")
        assert rf.extra["faults"] == rl.extra["faults"]
        assert (rf.up_bytes, rf.up_events, rf.up_retry_bytes) == (
            rl.up_bytes, rl.up_events, rl.up_retry_bytes
        )
        assert rf.extra["staleness"] == rl.extra["staleness"]
        for cid in rf.per_client_acc:
            np.testing.assert_allclose(
                rf.per_client_acc[cid], rl.per_client_acc[cid], atol=0.05
            )

    def test_chaos_real_window_schedule_parity(self):
        """At a real coalescing window the crash/retry schedule (driven by
        per-client round counters) still matches the per-event loop; only
        trajectory-dependent consults (dups/reorders per delivery) may
        differ where the trajectories themselves diverge."""
        cfg = FaultConfig(seed=11, crash_rate=0.1, loss_rate=0.25, dup_rate=0.0, reorder_rate=0.0)
        r0, _, _ = _run(fault_cfg=cfg)
        r1, _, _ = _run(fault_cfg=cfg, window=45.0)
        f0, f1 = r0.extra["faults"], r1.extra["faults"]
        assert f0["crashes"] == f1["crashes"]
        assert f0["crash_downtime_s"] == f1["crash_downtime_s"]
        assert r0.extra["uploads"] == r1.extra["uploads"]
        # accuracy time-shifts through the superstep transient (see
        # docs/knobs.md "Coalescing and accuracy snapshots"); with a
        # 48-sample/client task one eval sample is ~0.09, so pin the
        # population mean tightly and individuals to ~1.5 samples
        a0 = np.array([r0.per_client_acc[c] for c in r0.per_client_acc])
        a1 = np.array([r1.per_client_acc[c] for c in r0.per_client_acc])
        assert abs(a0.mean() - a1.mean()) <= 0.05
        np.testing.assert_allclose(a0, a1, atol=0.15)


# ------------------------------------------------------------ retry billing
class TestRetryBilling:
    def test_every_retry_bills_real_bytes(self):
        cfg = FaultConfig(seed=5, crash_rate=0.0, loss_rate=0.35, dup_rate=0.0, reorder_rate=0.0)
        rep, sim, init = _run(fault_cfg=cfg)
        f = rep.extra["faults"]
        nbytes = model_bytes(init)
        assert f["upload_failures"] > 0
        # each upload with k >= 1 failures sends k extra full payloads
        assert rep.up_retry_bytes == f["upload_failures"] * nbytes
        assert rep.up_bytes == rep.up_events * nbytes
        assert f["retry_delay_s"] > 0.0
        assert "up_retry_MB" in rep.summary()

    def test_retry_delay_feeds_staleness(self):
        """Backoff delay holds an upload's arrival back, so other members'
        aggregations land first and version-based staleness grows: a lossy
        run must record at least as much total staleness pressure."""
        base = FaultConfig(seed=5, crash_rate=0.0, loss_rate=0.0, dup_rate=0.0, reorder_rate=0.0)
        lossy = FaultConfig(
            seed=5, crash_rate=0.0, loss_rate=0.45, dup_rate=0.0, reorder_rate=0.0,
            backoff_base=30.0, backoff_cap=240.0,
        )
        r0, _, _ = _run(fault_cfg=base, max_time=900.0)
        r1, _, _ = _run(fault_cfg=lossy, max_time=900.0)
        assert r1.extra["faults"]["retry_delay_s"] > 0
        # fewer rounds fit in the horizon when every third upload re-sends
        assert r1.extra["uploads"] < r0.extra["uploads"]


# -------------------------------------------------------- duplicates/reorder
class TestDeliveryFences:
    def test_duplicates_absorbed_idempotently(self):
        """Duplicate deliveries bill real bytes but are fenced out of
        ingest: the server-side trajectory (accuracy curve, uploads,
        staleness, broadcast behavior) is identical to a clean run."""
        clean = FaultConfig(seed=9, crash_rate=0.0, loss_rate=0.0, dup_rate=0.0, reorder_rate=0.0)
        dups = FaultConfig(seed=9, crash_rate=0.0, loss_rate=0.0, dup_rate=0.5, reorder_rate=0.0)
        r0, _, _ = _run(fault_cfg=clean)
        r1, _, _ = _run(fault_cfg=dups)
        f = r1.extra["faults"]
        assert f["dups_injected"] > 0
        assert f["dups_absorbed"] <= f["dups_injected"]
        assert r1.curve == r0.curve
        assert r1.per_client_acc == r0.per_client_acc
        assert r1.extra["uploads"] == r0.extra["uploads"]
        assert r1.extra["staleness"] == r0.extra["staleness"]
        assert r1.extra["broadcasts"] == r0.extra["broadcasts"]
        # ... but the retransmissions crossed the wire for real
        assert r1.up_events == r0.up_events + f["dups_injected"]
        assert r1.up_bytes > r0.up_bytes
        assert r1.up_retry_bytes == (r1.up_bytes - r0.up_bytes)

    def test_reordered_downlinks_never_roll_back(self):
        cfg = FaultConfig(seed=4, crash_rate=0.0, loss_rate=0.0, dup_rate=0.0, reorder_rate=0.9)
        rep, sim, _ = _run(fault_cfg=cfg)
        f = rep.extra["faults"]
        assert f["reorders_injected"] > 0
        assert f["stale_downlinks_absorbed"] > 0
        # fences are per-recipient monotone: installed seq never decreased
        assert all(
            sim._dl_high[cid] <= sim._dl_seq[cid] for cid in sim._dl_high
        )
        assert rep.final_acc > 0.3  # protocol still converges under heavy reorder


# ----------------------------------------------------- churn, crashes, death
class TestChurnAndDeath:
    @pytest.mark.parametrize("window", [0.0, 30.0])
    def test_dropout_rejoin_regression(self, window):
        """The `_next_online` claim (async protocol absorbs dropout AND
        rejoin): no upload from a churned client arrives inside its
        offline window, and it resumes uploading after returning."""
        churn = {1: [(60.0, 300.0)]}
        task, clients, init = build_clients("har", 6, seed=3, samples_per_client=48)
        strat = build_strategy("echopfl", init, clients, seed=3)
        seen: list[tuple] = []
        orig = strat.handle_upload

        def spy(cid, params, bv, n, t):
            seen.append((cid, t))
            return orig(cid, params, bv, n, t)

        strat.handle_upload = spy
        sim = Simulator(clients, strat, seed=3, churn=churn, coalesce_window=window)
        rep = sim.run_async(max_time=900.0)
        assert rep.extra["churn_delays"] >= 1
        in_window = [t for cid, t in seen if cid == 1 and 60.0 <= t < 300.0]
        after = [t for cid, t in seen if cid == 1 and t >= 300.0]
        assert not in_window, "churned client uploaded while offline"
        assert after, "churned client never rejoined"

    def test_crashes_rejoin_through_next_online(self):
        cfg = FaultConfig(seed=2, crash_rate=0.3, death_rate=0.0,
                          loss_rate=0.0, dup_rate=0.0, reorder_rate=0.0)
        rep, sim, _ = _run(fault_cfg=cfg, max_time=900.0)
        f = rep.extra["faults"]
        assert f["crashes"] > 0 and f["deaths"] == 0
        assert f["crash_downtime_s"] > 0
        assert not sim._dead  # everyone came back
        assert rep.extra["uploads"] > 0

    def test_death_reclaims_plane_rows(self):
        """When a cluster's members all go permanently dark the server
        reclaims the cluster: no leaked rows in the plane free-list."""
        cfg = FaultConfig(seed=3, crash_rate=0.25, death_rate=0.8,
                          loss_rate=0.0, dup_rate=0.0, reorder_rate=0.0)
        rep, sim, _ = _run(fault_cfg=cfg, num_clients=8, max_time=1500.0)
        f = rep.extra["faults"]
        assert f["deaths"] > 0
        assert f["evicted_clients"] == f["deaths"]
        strat = sim.strategy
        plane = strat.clustering.plane
        if plane is not None:  # REPRO_PLANE=pytree leg has no rows to leak
            expected = 2 * len(strat.clustering.clusters) + len(strat._upload_rows)
            assert plane.num_allocated == expected
        assert all(cid not in strat._upload_rows for cid in sim._dead)
        assert all(cid not in strat.clustering.assignment for cid in sim._dead)
        # dead clients keep their last model for evaluation
        assert set(rep.per_client_acc) == set(sim.clients)

    def test_drop_policy_retires_stragglers(self):
        """REPRO_FAULT_POLICY=drop: hitting the retry cap abandons the
        upload and the client — the baseline EchoPFL's retry discipline
        is benchmarked against."""
        cfg = FaultConfig(seed=6, crash_rate=0.0, loss_rate=0.6, max_retries=2,
                          dup_rate=0.0, reorder_rate=0.0, policy="drop")
        rep, sim, _ = _run(fault_cfg=cfg, max_time=900.0)
        f = rep.extra["faults"]
        assert f["dropped_uploads"] > 0
        assert f["dropped_clients"] == len(sim._dead) > 0
        assert f["policy"] == "drop"


# ----------------------------------------------------------- server restart
class TestServerKillRestore:
    def test_kill_restore_matches_uninterrupted(self, tmp_path):
        """Mid-run kill+restore through the checkpointer (coalesced path,
        active top-k codec, faults on) finishes with the uninterrupted
        run's exact ledger: bytes, events, staleness, curves, accuracies."""
        cfg = FaultConfig(seed=5, crash_rate=0.05, loss_rate=0.2, dup_rate=0.1, reorder_rate=0.1)

        def run(restart):
            task, clients, init = build_clients("har", 8, seed=0, samples_per_client=48)
            strat = build_strategy("echopfl", init, clients, seed=0)
            plan = None
            if restart:
                factory = lambda: build_strategy("echopfl", init, clients, seed=0)
                plan = ServerRestartPlan(
                    at_uploads=30, directory=str(tmp_path / "ck"), strategy_factory=factory
                )
            sim = Simulator(
                clients, strat, seed=0, coalesce_window=30.0, uplink="topk",
                faults=FaultPlan(config=cfg, restart=plan),
            )
            rep = sim.run_async(max_time=900.0)
            return rep, sim

        base, _ = run(False)
        killed, sim = run(True)
        assert killed.extra["faults"]["server_restarts"] == 1
        assert sim.strategy.uplink_codec is sim._codec  # codec re-attached
        fb = {k: v for k, v in base.extra["faults"].items() if k != "server_restarts"}
        fk = {k: v for k, v in killed.extra["faults"].items() if k != "server_restarts"}
        assert fb == fk
        assert killed.curve == base.curve
        assert killed.per_client_acc == base.per_client_acc
        assert (killed.up_bytes, killed.down_bytes, killed.up_events, killed.down_events) == (
            base.up_bytes, base.down_bytes, base.up_events, base.down_events
        )
        assert killed.extra["staleness"] == base.extra["staleness"]
        assert killed.extra["uploads"] == base.extra["uploads"]
        assert killed.extra["broadcasts"] == base.extra["broadcasts"]


# -------------------------------------------------------------- evict unit
class TestEvictClients:
    def test_evict_frees_rows_and_reclaims_empty_clusters(self):
        from repro.core.server import EchoPFLServer
        from repro.fl.experiment import build_clients

        import jax

        task, clients, init = build_clients("har", 4, seed=0, samples_per_client=48)
        srv = EchoPFLServer(init, num_initial_clusters=2, refine_every=1000)
        for i, c in enumerate(clients):
            # two well-separated upload groups (clients carry no trained
            # model outside a simulation run)
            up = jax.tree_util.tree_map(lambda x, i=i: x + (i % 2) * 0.5 + i * 0.01, init)
            srv.handle_upload(c.client_id, up, 0, 48, float(i))
        plane = srv.clustering.plane
        if plane is None:
            pytest.skip("pytree backend has no plane rows")
        before = plane.num_allocated
        victims = next(
            cid for cid in sorted(srv.clustering.clusters)
            if srv.clustering.clusters[cid].members
        )
        members = sorted(srv.clustering.clusters[victims].members)
        res = srv.evict_clients(members)
        assert res["evicted"] == members
        assert victims in res["reclaimed"]
        assert victims not in srv.clustering.clusters
        assert victims not in srv.predictors
        # 2 cluster rows + one upload row per member returned to the free-list
        assert plane.num_allocated == before - 2 - len(members)
        # idempotent: evicting again is a no-op
        res2 = srv.evict_clients(members)
        assert res2["evicted"] == [] and res2["reclaimed"] == []

    def test_evict_unknown_client_is_noop(self):
        from repro.core.server import EchoPFLServer
        from repro.fl.experiment import build_clients

        task, clients, init = build_clients("har", 2, seed=0, samples_per_client=48)
        srv = EchoPFLServer(init, num_initial_clusters=2)
        res = srv.evict_clients(["nope"])
        assert res == {"evicted": [], "reclaimed": []}


# ------------------------------------------------------------ knob parsing
class TestKnobs:
    def test_resolve_off_and_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_FAULTS", raising=False)
        assert resolve_faults(None) is None
        assert resolve_faults("off") is None
        monkeypatch.setenv("REPRO_FAULTS", "1")
        assert faults_enabled()
        plan = resolve_faults(None)
        assert isinstance(plan, FaultPlan)
        assert resolve_faults("off") is None  # explicit off beats the env
        monkeypatch.setenv("REPRO_FAULT_SEED", "42")
        monkeypatch.setenv("REPRO_FAULT_LOSS", "0.33")
        monkeypatch.setenv("REPRO_FAULT_POLICY", "drop")
        cfg = default_fault_config()
        assert (cfg.seed, cfg.loss_rate, cfg.policy) == (42, 0.33, "drop")

    def test_invalid_specs_raise(self):
        with pytest.raises(ValueError):
            resolve_faults("sometimes")
        with pytest.raises(ValueError):
            FaultConfig(policy="maybe")
        with pytest.raises(ValueError):
            FaultConfig(max_retries=-1)

    def test_faults_off_runs_have_no_fault_machinery(self, monkeypatch):
        monkeypatch.delenv("REPRO_FAULTS", raising=False)
        task, clients, init = build_clients("har", 2, seed=0, samples_per_client=48)
        strat = build_strategy("echopfl", init, clients, seed=0)
        sim = Simulator(clients, strat, seed=0)
        assert sim._faults is None


# ----------------------------------------------------- network validation
class TestNetworkValidation:
    def test_negative_bytes_rejected(self):
        net = NetworkModel()
        with pytest.raises(ValueError):
            net.upload(-1, 0.0)
        with pytest.raises(ValueError):
            net.upload(10, 0.0, raw_nbytes=-5)
        with pytest.raises(ValueError):
            net.download(-1, 0.0)
        with pytest.raises(ValueError):
            net.download_bulk(-1, 3, 0.0)

    def test_bulk_count_must_be_positive(self):
        net = NetworkModel()
        for count in (0, -2):
            with pytest.raises(ValueError):
                net.download_bulk(100, count, 0.0)
        assert net.down_bytes == 0 and net.down_events == 0  # nothing billed

    def test_retry_flag_accumulates(self):
        net = NetworkModel()
        net.upload(100, 0.0)
        net.upload(100, 1.0, retry=True)
        net.upload(50, 2.0, retry=True)
        assert net.up_retry_bytes == 150
        assert net.up_bytes == 250
        assert net.up_events == 3
