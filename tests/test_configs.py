"""Assigned-architecture configs: exact paper constants, param-count sanity,
and the shape-support (skip) rules from the brief."""
import pytest

from repro.configs import ARCH_REGISTRY, SHAPES, get_config, supports_shape

EXPECTED = {
    # name: (layers, d_model, heads, kv_heads, vocab, family)
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 102400, "moe"),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 49155, "moe"),
    "command-r-35b": (40, 8192, 64, 8, 256000, "dense"),
    "gemma2-2b": (26, 2304, 8, 4, 256000, "dense"),
    "llama3-405b": (126, 16384, 128, 8, 128256, "dense"),
    "llama3.2-1b": (16, 2048, 32, 8, 128256, "dense"),
    "pixtral-12b": (40, 5120, 32, 8, 131072, "vlm"),
    "hubert-xlarge": (48, 1280, 16, 16, 504, "audio"),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536, "hybrid"),
    "xlstm-1.3b": (48, 2048, 4, 4, 50304, "ssm"),
}

# analytic total parameter targets (billions) with tolerance
PARAM_TARGETS = {
    "llama3-405b": (405e9, 0.10),
    "deepseek-v2-lite-16b": (15.7e9, 0.15),
    "command-r-35b": (35e9, 0.15),
    "gemma2-2b": (2.6e9, 0.25),       # incl. its 256k-vocab embeddings
    "llama3.2-1b": (1.24e9, 0.10),
    "pixtral-12b": (12e9, 0.25),      # backbone only (frontend is a stub)
    "jamba-1.5-large-398b": (398e9, 0.15),
    # our framework uses SwiGLU FFNs throughout; the original HuBERT uses a
    # 2-matrix GELU MLP, so the same (d_model, d_ff) gives ~1.26B not 0.96B
    "hubert-xlarge": (1.26e9, 0.10),
    # xLSTM block conventions (proj factors, per-head qkv) differ across
    # implementations; the brief's config is unverified-tier — we pin ours
    "xlstm-1.3b": (1.96e9, 0.10),
    "granite-moe-3b-a800m": (3.3e9, 0.35),
}


def test_all_ten_archs_registered():
    # tiny_lm is the CI-sized frozen base for the REPRO_TASK=lm workload,
    # not an assigned architecture — it rides the registry for get_config()
    # but stays out of the 10-arch paper matrix
    assert set(EXPECTED) == set(ARCH_REGISTRY) - {"tiny_lm"}


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_paper_constants(name):
    layers, d_model, heads, kv, vocab, family = EXPECTED[name]
    cfg = get_config(name)
    assert cfg.num_layers == layers
    assert cfg.d_model == d_model
    assert cfg.num_heads == heads
    assert cfg.num_kv_heads == kv
    assert cfg.vocab_size == vocab
    assert cfg.family == family


@pytest.mark.parametrize("name", sorted(PARAM_TARGETS))
def test_param_count_in_band(name):
    target, tol = PARAM_TARGETS[name]
    n = get_config(name).param_count()
    assert abs(n - target) / target < tol, f"{name}: {n/1e9:.2f}B vs {target/1e9:.0f}B"


def test_moe_configs():
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6 and ds.moe.num_shared == 2
    assert ds.mla is not None and ds.mla.kv_lora_rank == 512
    gr = get_config("granite-moe-3b-a800m")
    assert gr.moe.num_experts == 40 and gr.moe.top_k == 8
    ja = get_config("jamba-1.5-large-398b")
    assert ja.moe.num_experts == 16 and ja.moe.top_k == 2
    # active params strictly below total for MoE
    for name in ("deepseek-v2-lite-16b", "granite-moe-3b-a800m", "jamba-1.5-large-398b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < cfg.param_count()


def test_jamba_interleave_ratio():
    """Mamba:attn = 7:1 (one attention layer per 8-layer period)."""
    cfg = get_config("jamba-1.5-large-398b")
    mixers = [l.mixer for l in cfg.pattern]
    assert mixers.count("attn") == 1
    assert mixers.count("mamba") == 7


def test_gemma2_alternates_local_global():
    cfg = get_config("gemma2-2b")
    mixers = [l.mixer for l in cfg.all_layers]
    assert "attn_local" in mixers and "attn" in mixers
    assert cfg.final_logit_softcap is not None


def test_skip_rules():
    # encoder-only: no decode shapes
    enc = get_config("hubert-xlarge")
    assert not supports_shape(enc, SHAPES["decode_32k"])[0]
    assert not supports_shape(enc, SHAPES["long_500k"])[0]
    assert supports_shape(enc, SHAPES["train_4k"])[0]
    assert supports_shape(enc, SHAPES["prefill_32k"])[0]
    # full attention: no 500k decode
    for name in ("llama3-405b", "command-r-35b", "gemma2-2b", "llama3.2-1b",
                 "pixtral-12b", "deepseek-v2-lite-16b", "granite-moe-3b-a800m"):
        ok, reason = supports_shape(get_config(name), SHAPES["long_500k"])
        assert not ok and reason
    # SSM/hybrid: 500k decode runs
    for name in ("jamba-1.5-large-398b", "xlstm-1.3b"):
        assert supports_shape(get_config(name), SHAPES["long_500k"])[0]


def test_total_cell_count():
    """40 nominal cells; 31 runnable + 9 documented skips (7 full-attention
    long_500k + hubert's decode_32k and long_500k)."""
    runnable = skipped = 0
    for arch in ARCH_REGISTRY.values():
        if arch.name == "tiny_lm":  # not part of the 40-cell paper matrix
            continue
        for shape in SHAPES.values():
            ok, _ = supports_shape(arch, shape)
            runnable += ok
            skipped += not ok
    assert runnable + skipped == 40
    assert skipped == 9


def test_vocab_padding_is_tp16_friendly():
    for cfg in ARCH_REGISTRY.values():
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
