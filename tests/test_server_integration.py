"""Integration tests: the EchoPFL server protocol end-to-end, the simulator,
baselines, elastic membership, and the paper's qualitative claims in-small."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server import Downlink, EchoPFLServer
from repro.fl.experiment import build_clients, build_strategy, run_experiment
from repro.fl.network import NetworkModel
from repro.fl.simulator import Simulator, model_bytes


def vec(x, n=8):
    return {"w": jnp.full((n,), float(x))}


class TestServerProtocol:
    def test_no_update_is_ever_dropped(self):
        """Challenge #2: every upload aggregates — the cluster version grows
        by exactly one per upload, regardless of staleness."""
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=2, seed=0)
        for i in range(20):
            srv.handle_upload(i % 5, vec(i % 2 * 10 + 0.01 * i), base_version=0, n_samples=8, t=float(i))
        total_version = sum(c.version for c in srv.clustering.clusters.values())
        # merges also bump versions; uploads alone guarantee >= 20
        assert total_version >= 20
        assert srv.staleness.count == 20

    def test_uploader_always_gets_unicast(self):
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=2, seed=0)
        out = srv.handle_upload("c1", vec(1.0), 0, 8, t=0.0)
        assert any(d.client_id == "c1" and d.reason == "unicast" for d in out)

    def test_broadcast_goes_to_cluster_peers_only(self):
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=2, seed=0, refine_every=10**9)
        # two well-separated groups
        for t in range(30):
            srv.handle_upload(f"a{t % 3}", vec(0.0 + 0.1 * t), 0, 8, t=float(t))
            srv.handle_upload(f"b{t % 3}", vec(100.0 + 0.1 * t), 0, 8, t=float(t))
        bcast = [e for e in srv.events if e["kind"] == "broadcast"]
        assert bcast, "no broadcast fired in 60 uploads"
        # recipients of each broadcast share one cluster
        a_cluster = srv.clustering.assignment["a0"]
        b_cluster = srv.clustering.assignment["b0"]
        assert a_cluster != b_cluster

    def test_ablation_flags(self):
        srv = EchoPFLServer(vec(0.0), enable_clustering=False, enable_broadcast=False, seed=0)
        for i in range(10):
            out = srv.handle_upload(i, vec(i * 10.0), 0, 8, t=float(i))
            assert all(d.reason == "unicast" for d in out)
        assert len(srv.clustering.clusters) == 1   # single global "cluster"
        assert srv.stats()["broadcasts"] == 0
        assert srv.stats()["decisions"] == 0

    def test_merge_triggers_forced_broadcast(self):
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=1, hm=1.0, refine_every=6, seed=0,
                            local_train_fn=lambda p: p)
        # make two far clusters via expansion-ish uploads, then exceed capacity
        for i in range(12):
            srv.handle_upload(i % 4, vec((i % 2) * 50.0), 0, 8, t=float(i))
        merge_events = [e for e in srv.events if e["kind"] == "merge"]
        if merge_events:  # if capacity forced a merge, a broadcast must follow
            bcast = [e for e in srv.events if e["kind"] == "broadcast"]
            assert bcast

    def test_stats_keys_stable(self):
        srv = EchoPFLServer(vec(0.0), seed=0)
        srv.handle_upload(0, vec(1.0), 0, 8, t=0.0)
        s = srv.stats()
        for k in ("clusters", "merges", "expansions", "staleness", "broadcasts",
                  "rnn_broadcasts", "decisions"):
            assert k in s


class TestSimulatorAccounting:
    def test_model_bytes_respects_leaf_dtype(self):
        """Regression: 4 bytes/element was hardcoded, so compressed or
        quantized payloads (int8, fp16) were billed as if fp32."""
        params = {
            "w": jnp.zeros((10, 4), jnp.float32),  # 160 B
            "q": jnp.zeros((8,), jnp.int8),  # 8 B
            "h": jnp.zeros((6,), jnp.float16),  # 12 B
            "scalar": 1.0,  # non-array leaf: 4 B word
        }
        assert model_bytes(params) == 160 + 8 + 12 + 4

    def test_network_rejects_unknown_direction(self):
        """Regression: peak()/series() silently treated any unrecognized
        direction string (e.g. "downstream") as "up"."""
        net = NetworkModel()
        net.upload(100, t=0.0)
        net.download(400, t=0.0)
        assert net.peak("down") == 400.0
        assert net.peak("up") == 100.0
        assert net.series("up") == {0: 100.0}
        with pytest.raises(ValueError):
            net.peak("downstream")
        with pytest.raises(ValueError):
            net.series("UP")

    def test_run_sync_zero_rounds_returns_zero_round_report(self):
        """Regression: rounds=0 raised UnboundLocalError on the round
        counter instead of returning an empty report."""
        task, clients, init = build_clients("har", 4, seed=0)
        strat = build_strategy("fedavg", init, clients, seed=0)
        report = Simulator(clients, strat, seed=0).run_sync(rounds=0)
        assert report.extra["rounds"] == 0
        assert report.up_events == 0


class TestServerStateAndStaleness:
    def test_staleness_from_broadcast_anchor_when_base_merged_away(self):
        """Regression for the server.py staleness rule: a client whose base
        branch no longer exists (merged away) is measured from the current
        cluster's last_broadcast_version — the merge broadcast refreshed
        every member, so only post-broadcast aggregations count as stale."""
        srv = EchoPFLServer(vec(0.0), num_initial_clusters=1, seed=0,
                            enable_broadcast=False, refine_every=10**9)
        for i in range(5):
            srv.handle_upload("a", vec(1.0 + i), 0, 8, t=float(i))
        cid = srv.clustering.assignment["a"]
        cluster = srv.clustering.clusters[cid]
        # pretend "a" trained from a branch that has since been merged away,
        # and that the merge broadcast happened 2 aggregations ago
        srv.client_versions["a"] = (999, 3)
        cluster.last_broadcast_version = cluster.version - 2
        expected = cluster.version - cluster.last_broadcast_version  # pre-upload
        before = srv.staleness.total
        srv.handle_upload("a", vec(9.0), 0, 8, t=10.0)
        assert srv.staleness.total - before == expected

    def test_state_dict_round_trips_bit_exact(self):
        """state_dict -> load_state -> state_dict must reproduce the
        plane-backed server exactly: every center/anchor/RNN leaf bit-equal
        and the json meta identical."""
        import jax

        def build():
            return EchoPFLServer(vec(0.0), num_initial_clusters=2, seed=0,
                                 refine_every=7, local_train_fn=lambda p: p)

        srv = build()
        for i in range(30):
            srv.handle_upload(i % 6, vec((i % 2) * 40.0 + 0.1 * i), 0, 8, t=float(i))
        tree1, meta1 = srv.state_dict()

        restored = build()
        restored.load_state(tree1, meta1)
        tree2, meta2 = restored.state_dict()
        assert meta1 == meta2
        assert jax.tree_util.tree_structure(tree1) == jax.tree_util.tree_structure(tree2)
        for a, b in zip(jax.tree_util.tree_leaves(tree1), jax.tree_util.tree_leaves(tree2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and the restored server behaves identically on the next upload
        d1 = srv.handle_upload(0, vec(3.0), 0, 8, t=100.0)
        d2 = restored.handle_upload(0, vec(3.0), 0, 8, t=100.0)
        assert [(d.client_id, d.version, d.cluster_id, d.reason) for d in d1] == \
               [(d.client_id, d.version, d.cluster_id, d.reason) for d in d2]

    def test_load_state_restores_last_uploads(self):
        """Regression: last_uploads/_upload_rows were dropped on restore, so
        an elastically-restarted server ran its dissolve/expand refinement
        without last-upload geometry until every client re-uploaded."""
        import jax

        def build():
            return EchoPFLServer(vec(0.0), num_initial_clusters=2, seed=0,
                                 refine_every=10**9)

        srv = build()
        for i in range(8):
            srv.handle_upload(i % 4, vec((i % 2) * 30.0 + 0.1 * i), 0, 8, t=float(i))
        tree, meta = srv.state_dict()
        assert len(meta["upload_clients"]) == 4

        restored = build()
        restored.load_state(tree, meta)
        plane = restored.clustering.plane
        if plane is None:
            assert set(restored.last_uploads) == set(srv.last_uploads)
            for cid, up in srv.last_uploads.items():
                for a, b in zip(jax.tree_util.tree_leaves(up),
                                jax.tree_util.tree_leaves(restored.last_uploads[cid])):
                    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            assert set(restored._upload_rows) == set(srv._upload_rows)
            for cid, row in srv._upload_rows.items():
                np.testing.assert_array_equal(
                    np.asarray(srv.clustering.plane.row(row)),
                    np.asarray(plane.row(restored._upload_rows[cid])),
                )
        # a second load must not leak plane rows (pre-restore rows freed)
        before = None if plane is None else plane.num_allocated
        restored.load_state(tree, meta)
        if plane is not None:
            assert restored.clustering.plane.num_allocated == before


class TestPlaneBackendParity:
    def _run(self, backend):
        """Tiny full-protocol run with feedback-driven refinement: clients
        c4/c5 are hard outliers (huge chi2), so expansion must fire and seed
        the child from their uploads."""
        def feedback_fn(client_id, center):
            err = 80.0 if client_id in ("c4", "c5") else 1.0
            f_pred = np.asarray([50.0 + err, 50.0 - err, 1.0])
            f_true = np.asarray([50.0, 50.0, 1.0])
            s_soft = np.asarray([0.9, 0.08, 0.02])
            return f_pred, f_true, s_soft

        srv = EchoPFLServer(vec(0.0), num_initial_clusters=1, refine_every=8,
                            feedback_fn=feedback_fn, local_train_fn=lambda p: p,
                            plane_backend=backend, seed=0)
        for i in range(40):
            srv.handle_upload(f"c{i % 6}", vec(40.0 * (i % 2) + 0.01 * i), 0, 8, t=float(i))
        assert srv.stats()["expansions"] > 0  # the scenario must exercise expand
        return srv

    def test_server_refine_trajectory_matches_pytree_path(self):
        """The refine loop (feedback -> reassign -> expand -> merge) must
        take identical decisions on both storage backends — including
        expansion children seeded from the peeled members' *uploads*
        (plane rows), not from the parent center."""
        plane_srv = self._run("plane")
        tree_srv = self._run("pytree")
        assert plane_srv.clustering.assignment == tree_srv.clustering.assignment
        ps, ts = plane_srv.stats(), tree_srv.stats()
        for key in ("clusters", "merges", "expansions", "staleness", "broadcasts"):
            assert ps[key] == ts[key], key
        for cid, tc in tree_srv.clustering.clusters.items():
            pc = plane_srv.clustering.clusters[cid]
            import jax
            for a, b in zip(jax.tree_util.tree_leaves(pc.center),
                            jax.tree_util.tree_leaves(tc.center)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


@pytest.mark.slow
class TestSimulatorEndToEnd:
    def test_deterministic_given_seed(self):
        r1 = run_experiment("har", "echopfl", num_clients=8, max_time=600, seed=3)[3]
        r2 = run_experiment("har", "echopfl", num_clients=8, max_time=600, seed=3)[3]
        assert r1.final_acc == r2.final_acc
        assert r1.up_bytes == r2.up_bytes

    def test_comm_accounting_consistency(self):
        task, clients, strat, report = run_experiment(
            "har", "echopfl", num_clients=8, max_time=900, seed=0
        )
        nbytes = model_bytes(strat.init_params)
        # every upload and download is one whole model
        assert report.up_bytes == report.up_events * nbytes
        assert report.down_bytes == report.down_events * nbytes
        assert report.down_events > report.up_events  # broadcast-heavy (asymmetry)

    def test_echopfl_beats_fedavg_on_clusterable_data(self):
        accs = {}
        for name in ("echopfl", "fedavg"):
            accs[name] = run_experiment(
                "image_recognition", name, num_clients=10, max_time=1500, seed=0
            )[3].final_acc
        assert accs["echopfl"] > accs["fedavg"] + 0.1

    def test_broadcast_reduces_staleness(self):
        """The paper's central mechanism: on-demand broadcast pulls Q_max
        (and the O(sqrt(QmaxQavg)) proxy) down vs the no-broadcast ablation."""
        q = {}
        for flag in (True, False):
            _, _, strat, _ = run_experiment(
                "har", "echopfl", num_clients=10, max_time=1200, seed=0,
                enable_broadcast=flag,
            )
            q[flag] = strat.stats()["staleness"]["convergence_proxy"]
        assert q[True] < q[False]

    def test_elastic_churn_absorbed(self):
        """Clients dropping out mid-run and rejoining neither crash the
        protocol nor prevent convergence (fault tolerance)."""
        task, clients, init = build_clients("har", 8, seed=0)
        strat = build_strategy("echopfl", init, clients, seed=0)
        churn = {0: [(100.0, 500.0)], 1: [(50.0, 900.0), (1000.0, 1200.0)]}
        sim = Simulator(clients, strat, eval_interval=120, churn=churn, seed=0)
        report = sim.run(max_time=1500)
        assert report.extra["churn_delays"] >= 2
        assert report.final_acc > 0.4
        # the churned clients still participated
        assert 0 in strat.clustering.assignment
        assert 1 in strat.clustering.assignment

    def test_sync_strategies_round_barrier(self):
        _, _, strat, report = run_experiment("har", "fedavg", num_clients=6, rounds=5, seed=0,
                                             max_time=10**9)
        assert report.extra["rounds"] == 5
        assert strat.version == 5


@pytest.mark.slow
class TestBaselineContracts:
    @pytest.mark.parametrize("name", ["fedavg", "fedasyn", "fedsea", "clusterfl", "oort", "standalone"])
    def test_baseline_runs_and_reports(self, name):
        _, _, strat, report = run_experiment(
            "har", name, num_clients=6, max_time=600, rounds=4, seed=0
        )
        assert 0.0 <= report.final_acc <= 1.0
        assert report.up_bytes > 0

    def test_fedasyn_tracks_staleness(self):
        _, _, strat, _ = run_experiment("har", "fedasyn", num_clients=6, max_time=600, seed=0)
        assert strat.stats()["staleness"]["n"] > 0

    def test_oort_selects_subset(self):
        _, _, strat, _ = run_experiment("har", "oort", num_clients=10, rounds=4, seed=0,
                                        max_time=10**9)
        assert strat.stats()["selected_last_round"] < 10
