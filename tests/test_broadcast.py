"""Unit tests for the on-demand broadcast predictor (paper Sec. 5)."""
import jax
import numpy as np
import pytest

from repro.core.broadcast import (
    HIDDEN,
    NUM_LAYERS,
    BroadcastPredictor,
    init_rnn,
    predictor_for_expansion,
    predictor_for_merge,
    pretrain_rnn,
    rnn_logits,
)


@pytest.fixture(scope="module")
def rnn_params():
    return init_rnn(jax.random.PRNGKey(0))


def test_rnn_shape_contract(rnn_params):
    import jax.numpy as jnp

    logits = rnn_logits(rnn_params, jnp.ones((10, 1)))
    assert logits.shape == (2,)
    assert rnn_params["wh0"].shape == (HIDDEN, HIDDEN)
    assert len([k for k in rnn_params if k.startswith("wh")]) == NUM_LAYERS


class TestPredictor:
    def test_observe_keeps_topk_window(self, rnn_params):
        p = BroadcastPredictor(params=rnn_params, k=5)
        for i in range(12):
            p.observe(float(i))
        assert len(p.records) == 5
        assert p.records == [7.0, 8.0, 9.0, 10.0, 11.0]

    def test_cold_start_rule(self, rnn_params):
        p = BroadcastPredictor(params=rnn_params, k=5)
        p.observe(1.0)
        assert p.decide(accumulated_gap=100.0)       # big gap -> broadcast
        p2 = BroadcastPredictor(params=rnn_params, k=5)
        p2.observe(1.0)
        assert not p2.decide(accumulated_gap=0.001)  # tiny gap -> hold

    def test_inactive_suppresses_exactly_one_decision(self, rnn_params):
        p = BroadcastPredictor(params=rnn_params, k=5, active=False)
        for c in (1.0, 2.0, 3.0):
            p.observe(c)
        assert p.decide(accumulated_gap=1e9) is False  # suppressed once
        assert p.active

    def test_learn_reduces_loss_on_repeated_label(self, rnn_params):
        p = BroadcastPredictor(params=rnn_params, k=8)
        for c in (5.0, 4.0, 3.0, 2.0):
            p.observe(c)
        losses = [p.learn(1) for _ in range(25)]
        assert losses[-1] < losses[0]

    def test_growing_changes_trigger_trained_predictor(self):
        """After pretraining, growing change sequences (staleness building
        up) should broadcast more often than decaying ones."""
        params = pretrain_rnn(jax.random.PRNGKey(1), num_states=300)
        grow, decay = 0, 0
        for trial in range(5):
            pg = BroadcastPredictor(params=params, k=10)
            pd = BroadcastPredictor(params=params, k=10)
            base = 0.5 + 0.2 * trial
            for i in range(10):
                pg.observe(base * 1.35**i)
                pd.observe(base * 0.55**i)
            grow += pg.decide(0.0)
            decay += pd.decide(0.0)
        assert grow > decay


class TestMaintenance:
    def test_expansion_resets_records_inherits_weights(self, rnn_params):
        parent = BroadcastPredictor(params=rnn_params, k=6)
        for c in (1.0, 2.0, 3.0):
            parent.observe(c)
        child = predictor_for_expansion(parent, change_of_new_client=9.0)
        assert child.records == [9.0]           # reset to the new client
        assert child.params is parent.params    # inherit RNN weights
        assert child.active is False            # broadcast deactivated
        seq = np.asarray(child._seq())
        assert seq.shape == (6, 1)
        assert (seq[:-1] == 0).all()            # zero-padded history

    def test_merge_resamples_by_variance(self, rnn_params):
        a = BroadcastPredictor(params=rnn_params, k=6)
        b = BroadcastPredictor(params=init_rnn(jax.random.PRNGKey(1)), k=6)
        for c in (1.0, 1.1, 0.9, 1.05):
            a.observe(c)          # low variance
        for c in (0.1, 5.0, 0.2, 8.0):
            b.observe(c)          # high variance -> contributes more records
        merged = predictor_for_merge(a, b)
        assert merged.k == 6
        assert len(merged.records) <= 6
        from_b = sum(1 for r in merged.records if r in b.records)
        from_a = sum(1 for r in merged.records if r in a.records)
        assert from_b >= from_a
        # RNN weights are the distilled (averaged) pair
        for k in rnn_params:
            np.testing.assert_allclose(
                np.asarray(merged.params[k]),
                0.5 * (np.asarray(a.params[k]) + np.asarray(b.params[k])),
                rtol=1e-6,
            )

    def test_merge_of_empty_predictors(self, rnn_params):
        a = BroadcastPredictor(params=rnn_params, k=4)
        b = BroadcastPredictor(params=rnn_params, k=4)
        merged = predictor_for_merge(a, b)
        assert merged.records == []

    def test_merge_one_side_empty(self, rnn_params):
        """The np.var guard: a single-record (or empty) side has no variance
        and must not crash or dominate the resample."""
        a = BroadcastPredictor(params=rnn_params, k=4)
        for c in (1.0, 3.0, 2.0):
            a.observe(c)
        b = BroadcastPredictor(params=rnn_params, k=4)
        merged = predictor_for_merge(a, b)
        assert all(r in a.records for r in merged.records)
        merged_rev = predictor_for_merge(b, a)  # symmetric orientation
        assert all(r in a.records for r in merged_rev.records)

    def test_merge_of_singleton_records(self, rnn_params):
        """Both sides singleton: len(records) == 1 skips np.var entirely
        (variance of one sample is 0 by convention here), so the zero-total
        split falls back to an even allocation."""
        a = BroadcastPredictor(params=rnn_params, k=4)
        a.observe(7.0)
        b = BroadcastPredictor(params=rnn_params, k=4)
        b.observe(2.0)
        merged = predictor_for_merge(a, b)
        assert sorted(merged.records) == [2.0, 7.0]
        assert merged.scale == max(a.scale, b.scale)

    def test_expansion_child_suppresses_exactly_one_decision(self, rnn_params):
        """Sec. 5.2.2: a freshly-expanded cluster's center is already fresh,
        so its predictor must hold exactly one broadcast decision."""
        parent = BroadcastPredictor(params=rnn_params, k=5)
        for c in (1.0, 2.0, 4.0):
            parent.observe(c)
        child = predictor_for_expansion(parent, change_of_new_client=8.0)
        assert child.decide(accumulated_gap=1e9) is False  # suppressed once
        assert child.active
        assert child.decide(accumulated_gap=1e9) is True  # fallback resumes
        assert child.decisions == 2 and child.broadcasts == 1
