"""The loop-aware HLO cost model's billing rules on synthetic HLO: in-place
dynamic-update-slice, window billing for scan-xs slicing, S^2 filtering,
and trip-count multiplication — the §Perf instrument's unit tests."""
import numpy as np

from repro.launch.hlo_cost import HloCostModel, analyze

DUS_HLO = """
HloModule m
ENTRY %main (p0: f32[1024,256], p1: f32[8,256]) -> f32[1024,256] {
  %p0 = f32[1024,256]{1,0} parameter(0)
  %p1 = f32[8,256]{1,0} parameter(1)
  %c = s32[] constant(16)
  ROOT %dus = f32[1024,256]{1,0} dynamic-update-slice(%p0, %p1, %c, %c)
}
"""


def test_dus_billed_in_place():
    out = analyze(DUS_HLO)
    # 2 x update region (8x256x4B) + index scalars, NOT the 1MB carried buffer
    assert out["bytes"] == 2 * 8 * 256 * 4 + 8


DS_HLO = """
HloModule m
ENTRY %main (p0: f32[4096,512]) -> f32[16,512] {
  %p0 = f32[4096,512]{1,0} parameter(0)
  %c = s32[] constant(0)
  ROOT %ds = f32[16,512]{1,0} dynamic-slice(%p0, %c, %c), dynamic_slice_sizes={16,512}
}
"""


def test_dynamic_slice_billed_by_window():
    out = analyze(DS_HLO)
    assert out["bytes"] == 2 * 16 * 512 * 4  # window in + out, not 8MB source


FUSION_SLICE_HLO = """
HloModule m
%fused (param_0: f32[4096,512], param_1: s32[]) -> f32[16,512] {
  %param_0 = f32[4096,512]{1,0} parameter(0)
  %param_1 = s32[] parameter(1)
  %ds = f32[16,512]{1,0} dynamic-slice(%param_0, %param_1, %param_1), dynamic_slice_sizes={16,512}
  ROOT %t = f32[16,512]{1,0} tanh(%ds)
}
ENTRY %main (p0: f32[4096,512], i0: s32[]) -> f32[16,512] {
  %p0 = f32[4096,512]{1,0} parameter(0)
  %i0 = s32[] parameter(1)
  ROOT %f = f32[16,512]{1,0} fusion(%p0, %i0), kind=kLoop, calls=%fused
}
"""


def test_fusion_param_window_billing():
    out = analyze(FUSION_SLICE_HLO)
    # input billed at the slice (16x512) + 4B index scalar, plus the result
    assert out["bytes"] == (16 * 512 + 16 * 512) * 4 + 4


WHILE_HLO = """
HloModule m
%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %y = f32[64,64]{1,0} add(%x, %x)
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %r = (s32[], f32[64,64]) tuple(%i2, %y)
}
%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (x: f32[64,64]) -> (s32[], f32[64,64]) {
  %x = f32[64,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[64,64]) tuple(%z, %x)
  ROOT %w = (s32[], f32[64,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_while_trip_multiplication():
    out = analyze(WHILE_HLO)
    one_iter = 64 * 64 + 1  # elementwise add + loop-counter increment
    assert out["flops"] == 10 * one_iter
    # add: result + 2 operands (x as both args), + 12B counter math, x10 trips
    assert out["bytes"] == 10 * (3 * 64 * 64 * 4 + 12)


S2_HLO = """
HloModule m
ENTRY %main (q: f32[2,4096,4096], w: f32[4096,128]) -> f32[2,4096,128] {
  %q = f32[2,4096,4096]{2,1,0} parameter(0)
  %w = f32[4096,128]{1,0} parameter(1)
  %s = f32[2,4096,4096]{2,1,0} tanh(%q)
  ROOT %o = f32[2,4096,128]{2,1,0} dot(%s, %w), lhs_contracting_dims={2}, rhs_contracting_dims={0}
}
"""


def test_s2_filter_skips_trailing_shapes():
    full = analyze(S2_HLO)
    filt = analyze(S2_HLO, skip_trailing=frozenset({(4096, 4096)}))
    s2_bytes = 2 * 4096 * 4096 * 4
    # tanh billed result+operand, dot billed lhs: 3 S^2 tensors disappear
    assert full["bytes"] - filt["bytes"] == 3 * s2_bytes
    assert filt["skipped_bytes_once"] >= 3 * s2_bytes  # + unbilled param scans
    assert filt["flops"] == full["flops"]  # filter touches bytes only


COLLECTIVE_HLO = """
HloModule m
ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add, replica_groups={}
  ROOT %ag = f32[1024]{0} all-gather(%ar), dimensions={0}, replica_groups={}
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""


def test_collectives_bucketed_by_opcode():
    out = analyze(COLLECTIVE_HLO)
    assert out["collectives"]["all-reduce"] == 1024 * 4
    assert out["collectives"]["all-gather"] == 1024 * 4
    assert out["collective_count"] == 2
