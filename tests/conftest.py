"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the 1 real CPU device (the 512-device override
belongs exclusively to launch/dryrun.py, per the brief)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
