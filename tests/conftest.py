"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the 1 real CPU device (the 512-device override
belongs exclusively to launch/dryrun.py, per the brief)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_params():
    """Session-scoped tiny MLP-shaped pytree (ragged leaf shapes, 187 params).

    Shared by the plane/kernel/server tests so the flatten spec and its jit
    caches are built once per session instead of once per test."""
    import jax
    import jax.numpy as jnp

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    return {
        "dense1": {
            "w": jax.random.normal(k1, (16, 8), jnp.float32),
            "b": jnp.zeros((8,), jnp.float32),
        },
        "dense2": {
            "w": jax.random.normal(k2, (8, 4), jnp.float32),
            "b": jnp.zeros((4,), jnp.float32),
        },
        "head": {"w": jax.random.normal(k3, (4, 3), jnp.float32), "scale": jnp.ones(())},
    }


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
