"""Flash-attention BACKWARD kernels (dq / dkv) vs jax.grad of the jnp
oracle, across GQA ratios, masking modes, softcap, and dv != dk (MLA)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_with_lse
from repro.kernels.flash_attention_bwd import flash_attention_bwd

CASES = [
    # B, H, KV, S, hd, dv, causal, window, softcap
    (1, 4, 2, 64, 32, 32, True, None, None),
    (2, 4, 1, 48, 16, 16, True, None, None),       # extreme GQA 4:1
    (1, 2, 2, 64, 32, 32, False, None, None),      # encoder (non-causal)
    (1, 4, 2, 64, 32, 32, True, 16, None),         # sliding window
    (1, 4, 4, 64, 32, 32, True, None, 30.0),       # softcap chain rule
    (1, 4, 4, 64, 48, 24, True, None, None),       # dv != dk (MLA-style)
    (1, 8, 2, 100, 64, 64, True, None, None),      # ragged (non-pow2) seq
]


def _inputs(case, seed=0):
    B, H, KV, S, hd, dv, causal, window, softcap = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, dv), jnp.float32)
    kw = dict(causal=causal, window=window, softcap=softcap)
    return q, k, v, kw


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_bwd_kernels_match_reference_grads(case):
    q, k, v, kw = _inputs(case)

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q, k, v, **kw)
        return jnp.sum(o * jnp.cos(o))  # nontrivial cotangent

    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)

    o, lse = flash_attention_with_lse(q, k, v, interpret=True, **kw)
    do = jax.grad(lambda o_: jnp.sum(o_ * jnp.cos(o_)))(o)
    got = flash_attention_bwd(q, k, v, o, lse, do, interpret=True, **kw)

    for g, w, name in zip(got, want, ("dq", "dk", "dv")):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=3e-4, rtol=3e-4, err_msg=name
        )


def test_custom_vjp_end_to_end_matches_ref_ad():
    """ops.attention (kernel fwd+bwd via custom_vjp) inside a bigger graph."""
    q, k, v, kw = _inputs((1, 4, 2, 64, 32, 32, True, None, None), seed=3)
    w = jax.random.normal(jax.random.PRNGKey(9), (32, 32))

    def net(fn):
        def loss(q, k, v):
            o = fn(q, k, v, **kw)
            return jnp.sum(jnp.tanh(o @ w))
        return loss

    g_kernel = jax.grad(net(lambda *a, **kws: K.attention(*a, **kws)), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(net(lambda *a, **kws: ref.flash_attention_ref(*a, **kws)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-4)


def test_lse_definition():
    """lse rows equal logsumexp of the masked score rows."""
    q, k, v, kw = _inputs((1, 2, 2, 32, 16, 16, True, None, None), seed=1)
    _, lse = flash_attention_with_lse(q, k, v, interpret=True, **kw)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (16**-0.5)
    mask = jnp.tril(jnp.ones((32, 32), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_shard_map_path_single_device_mesh():
    """ops.attention under a registered 1x1 mesh equals the direct path."""
    from repro.launch.mesh import make_smoke_mesh
    from repro.models import dist

    q, k, v, kw = _inputs((2, 4, 2, 32, 16, 16, True, None, None), seed=2)
    direct = K.attention(q, k, v, **kw)
    with dist.use_mesh(make_smoke_mesh()):
        meshed = K.attention(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(meshed), atol=1e-6)


def test_decode_consistency_with_kernel_path():
    """1-token decode (reference path) is consistent with the kernel's
    full-sequence output at the last position."""
    q, k, v, kw = _inputs((1, 4, 2, 33, 32, 32, True, None, None), seed=4)
    full = K.attention(q, k, v, **kw)
    last = ref.flash_attention_ref(q[:, :, -1:], k, v, causal=True, q_pos0=32)
    np.testing.assert_allclose(
        np.asarray(full[:, :, -1:]), np.asarray(last), atol=2e-5, rtol=2e-5
    )
