"""Unit tests for data-aware dynamic clustering (paper Sec. 4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import Cluster, DynamicClustering


def vec(*xs):
    return {"w": jnp.asarray(xs, jnp.float32)}


def make_clustering(num_initial=2, **kw):
    return DynamicClustering(num_initial, **kw)


class TestOnArrivalAssignment:
    def test_first_c_arrivals_seed_centers(self):
        cl = make_clustering(num_initial=3)
        for i in range(3):
            cid, created = cl.assign(f"client{i}", vec(float(i * 10)))
            assert created
        assert len(cl.clusters) == 3

    def test_later_arrival_joins_nearest_center(self):
        cl = make_clustering(num_initial=2)
        cl.assign("a", vec(0.0))
        cl.assign("b", vec(100.0))
        cid, created = cl.assign("c", vec(99.0))
        assert not created
        assert cid == cl.assignment["b"]

    def test_hysteresis_blocks_marginal_switches(self):
        cl = make_clustering(num_initial=2)
        cl.assign("a", vec(0.0))
        cl.assign("b", vec(100.0))
        cl.assign("c", vec(10.0))      # joins cluster of "a"
        home = cl.assignment["c"]
        # next upload is barely closer to the other center: should stay
        cid, _ = cl.assign("c", vec(52.0))
        assert cid == home
        # decisively closer: switches
        cid, _ = cl.assign("c", vec(95.0))
        assert cid == cl.assignment["b"]

    def test_partial_finetune_members_stay_put(self):
        cl = make_clustering(num_initial=2)
        cl.assign("a", vec(0.0))
        cl.assign("b", vec(100.0))
        cl.assign("c", vec(1.0))
        cid = cl.assignment["c"]
        cl.clusters[cid].partial_finetune.add("c")
        got, _ = cl.assign("c", vec(100.0))  # would switch without the pin
        assert got == cid


class TestAggregation:
    def test_mix_rate_lerp(self):
        cl = make_clustering(num_initial=1, mix_rate=0.25)
        cl.assign("a", vec(0.0, 0.0))
        cid = cl.assignment["a"]
        cl.aggregate(cid, vec(4.0, 8.0))
        np.testing.assert_allclose(np.asarray(cl.clusters[cid].center["w"]), [1.0, 2.0])
        assert cl.clusters[cid].version == 1

    def test_no_staleness_decay(self):
        """Challenge #2: stale updates aggregate at full weight — the lerp
        coefficient does not depend on any staleness argument."""
        cl = make_clustering(num_initial=1, mix_rate=0.5)
        cl.assign("a", vec(0.0))
        cid = cl.assignment["a"]
        before = float(cl.clusters[cid].center["w"][0])
        cl.aggregate(cid, vec(10.0))  # no staleness parameter exists at all
        after = float(cl.clusters[cid].center["w"][0])
        assert after == before + 0.5 * (10.0 - before)


class TestMerge:
    def test_merge_pair_moves_members_and_lifts_pf(self):
        cl = make_clustering(num_initial=2)
        cl.assign("a", vec(0.0))
        cl.assign("b", vec(100.0))
        cl.assign("c", vec(99.0))
        ca, cb = cl.assignment["a"], cl.assignment["b"]
        cl.clusters[cb].partial_finetune.add("c")
        merged = cl.merge_pair(ca, cb, lambda p: p)
        assert merged == cb  # larger cluster is main
        assert cl.clusters[merged].members == {"a", "b", "c"}
        assert not cl.clusters[merged].partial_finetune
        assert ca not in cl.clusters
        assert cl.merges == 1

    def test_merge_identical_centers_is_identity(self):
        cl = make_clustering(num_initial=2)
        cl.assign("a", vec(1.0, 2.0, 3.0))
        cl.assign("b", vec(1.0, 2.0, 3.0))
        ca, cb = cl.assignment["a"], cl.assignment["b"]
        merged = cl.merge_pair(ca, cb, lambda p: p)
        np.testing.assert_allclose(
            np.asarray(cl.clusters[merged].center["w"]), [1.0, 2.0, 3.0], atol=1e-6
        )

    def test_should_merge_is_strict_capacity(self):
        cl = make_clustering(num_initial=2, hm=2.0)
        for i in range(4):
            cl._new_cluster(vec(float(i)))
        assert not cl.should_merge()  # at hm*C: stable
        cl._new_cluster(vec(9.0))
        assert cl.should_merge()  # above hm*C: merge

    def test_nearest_pair_guard(self):
        cl = make_clustering(num_initial=3)
        for i, x in enumerate((0.0, 1.0, 100.0)):
            c = cl._new_cluster(vec(x))
            c.version = 5
        pair = cl.nearest_pair(close_frac=0.5)
        assert pair is not None
        a, b = pair
        xs = sorted(float(cl.clusters[c].center["w"][0]) for c in (a, b))
        assert xs == [0.0, 1.0]
        # all far apart -> no redundant pair
        cl2 = make_clustering(num_initial=3)
        for x in (0.0, 50.0, 100.0):
            c = cl2._new_cluster(vec(x))
            c.version = 5
        assert cl2.nearest_pair(close_frac=0.5) is None
        # disabled guard always returns the nearest
        assert cl2.nearest_pair(close_frac=None) is not None


class TestExpansion:
    def _cluster_with_feedback(self, n=10):
        cl = make_clustering(num_initial=1)
        for i in range(n):
            cl.assign(f"m{i}", vec(0.0))
        cid = cl.assignment["m0"]
        fb = {f"m{i}": 1.0 for i in range(n)}
        return cl, cid, fb

    def test_uniform_feedback_never_splits(self):
        cl, cid, fb = self._cluster_with_feedback()
        assert cl.expand(cid, fb) is None

    def test_poor_fits_peeled_into_new_cluster(self):
        cl, cid, fb = self._cluster_with_feedback()
        fb["m9"] = 100.0
        fb["m8"] = 90.0
        uploads = {m: vec(50.0) for m in fb}
        new = cl.expand(cid, fb, uploads=uploads, refine_round=1)
        assert new is not None
        assert cl.clusters[new].members == {"m8", "m9"}
        assert cl.clusters[new].partial_finetune == {"m8", "m9"}
        # child center is seeded from the peeled members' uploads, not parent
        assert float(cl.clusters[new].center["w"][0]) == 50.0
        assert cl.expansions == 1

    def test_cooldown_blocks_back_to_back_splits(self):
        cl, cid, fb = self._cluster_with_feedback()
        fb["m9"] = 100.0
        assert cl.expand(cid, fb, refine_round=1) is not None
        fb2 = {m: v for m, v in fb.items() if m != "m9"}
        fb2["m8"] = 100.0
        assert cl.expand(cid, fb2, refine_round=2) is None  # cooling down
        assert cl.expand(cid, fb2, refine_round=3) is not None

    def test_peel_cap_stops_serial_churn(self):
        cl, cid, fb = self._cluster_with_feedback()
        cl.peel_counts["m9"] = 3
        fb["m9"] = 100.0
        assert cl.expand(cid, fb, refine_round=1) is None

    def test_tiny_clusters_never_split(self):
        cl = make_clustering(num_initial=1)
        cl.assign("a", vec(0.0))
        cl.assign("b", vec(0.0))
        cid = cl.assignment["a"]
        assert cl.expand(cid, {"a": 1.0, "b": 100.0}) is None


def test_membership_matrix_blocks():
    cl = make_clustering(num_initial=2)
    cl.assign("a", vec(0.0))
    cl.assign("b", vec(100.0))
    cl.assign("c", vec(1.0))
    m = cl.membership_matrix(["a", "b", "c"])
    assert m[0, 2] and m[2, 0] and m[0, 0]
    assert not m[0, 1] and not m[2, 1]
