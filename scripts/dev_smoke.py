"""Dev-loop smoke: reduced config of every arch -> 1 train step + decode."""
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.configs.base import reduced_config
from repro.models import init_cache, init_params, make_serve_step, make_train_step
from repro.models.steps import TrainState, make_optimizer

ok = True
names = sys.argv[1:] or sorted(ARCH_REGISTRY)
for name in names:
    cfg = reduced_config(ARCH_REGISTRY[name])
    try:
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        B, S = 2, 32
        if cfg.embeds_input:
            batch = {
                "embeds": jnp.asarray(np.random.randn(B, S, cfg.d_model), jnp.float32),
                "labels": jnp.asarray(np.random.randint(0, cfg.vocab_size, (B, S))),
            }
        else:
            toks = jnp.asarray(np.random.randint(0, cfg.vocab_size, (B, S + 1)))
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        opt = make_optimizer(cfg)
        state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
        step = jax.jit(make_train_step(cfg))
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), f"loss not finite: {loss}"
        msg = f"train loss={loss:.4f}"
        if not cfg.is_encoder:
            cache = init_cache(cfg, B, ctx_len=8, margin=8)
            serve = jax.jit(make_serve_step(cfg))
            dbatch = {"tokens": jnp.asarray(np.random.randint(0, cfg.vocab_size, (B, 1)))}
            if cfg.embeds_input:
                dbatch = {"tokens": dbatch["tokens"]}
            logits, cache2 = serve(state.params, cache, dbatch)
            assert logits.shape == (B, 1, cfg.padded_vocab), logits.shape
            assert np.isfinite(np.asarray(logits)).all()
            assert int(cache2["len"]) == 9
            msg += f" decode ok"
        print(f"[OK] {name}: {msg}")
    except Exception:
        ok = False
        print(f"[FAIL] {name}")
        traceback.print_exc()
sys.exit(0 if ok else 1)
